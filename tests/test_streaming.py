"""Out-of-core streaming scan: host-tier pages + double-buffered DMA.

The contracts under test:
  * host-tier and device-tier executions are BIT-identical in f32, for
    dense and CSR storage, through udf and rel plans, mesh-less and (in
    the multi-device section, which skips without 8 forced CPU devices)
    on a (data x model) mesh;
  * ``device_budget_bytes`` auto-spills oversized ingests to the host
    tier, with per-tier nbytes accounting and catalog tiers;
  * the streaming executor keeps AT MOST 2 device page buffers in flight
    (the double-buffer invariant, asserted inside the executor and
    reported via ``ScanStats.max_in_flight``) — since the drain moved to
    a dedicated worker thread the probe here exercises the async path by
    default; the disk-tier grid and the drain-accounting contracts live
    in ``tests/test_disk_tier.py``;
  * tier migration (``store.move`` — eviction and promotion) and
    drop + re-page (different ``page_rows``) preserve predictions;
  * ``TensorBlockStore.drop`` sweeps dependent compiled-plan entries in
    registered engines (the stale-plan-after-re-put regression);
  * ``load_libsvm_csr_external(tier="host")`` parses into host pages
    with ``transfer_s == 0`` and no device round-trip;
  * PINNED: the jax-0.4.37 XLA:CPU miscompile of eager ``concatenate``
    over partially replicated operands, which the executor's host result
    buffer retired from the hot path.  When a jax bump fixes it, that
    test fails -> delete it and this note.
"""

import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.reuse import ModelReuseCache
from repro.core.train import TrainConfig, train_forest
from repro.db import loader as ld
from repro.db.executor import (MAX_IN_FLIGHT, ScanSource,
                               StreamingScanExecutor)
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore

N, F, T, PAGE = 384, 16, 24, 32
FUSED = "predicated_pallas_fused"
SPARSE_ALGO = "hummingbird_pallas_fused"


@pytest.fixture(scope="module")
def data_and_forest():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(N, F)).astype(np.float32)
    w = rng.normal(size=F).astype(np.float32)
    y = (x @ w > 0).astype(np.float32)
    forest = train_forest(x, y, TrainConfig(model_type="xgboost",
                                            num_trees=T, max_depth=4))
    xs = x.copy()
    xs[rng.random(x.shape) < 0.7] = np.nan
    return x, xs, forest


def _engine(store):
    return ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                             plan_cache=ModelReuseCache())


def _put_tiered(x, xs, *, mesh=None, page_rows=PAGE):
    """One store holding every (format, tier) combination of the data."""
    store = TensorBlockStore(mesh, default_page_rows=page_rows)
    store.put("dense@dev", x)
    store.put("dense@host", x, tier="host")
    store.put_sparse("csr@dev", xs)
    store.put_sparse("csr@host", xs, tier="host")
    return store


# ---------------------------------------------------------------------------
# tiering: auto-spill, accounting, protocol
# ---------------------------------------------------------------------------


def test_device_budget_auto_spills_to_host():
    """tier="auto" (the default): an ingest that would push the device-
    resident total past device_budget_bytes lands on the host tier."""
    x = np.ones((256, 8), np.float32)
    store = TensorBlockStore(default_page_rows=32,
                             device_budget_bytes=int(x.nbytes * 1.5))
    a = store.put("a", x)                      # fits: device
    b = store.put("b", x)                      # would exceed: spills
    assert a.tier == "device" and b.tier == "host"
    assert isinstance(b.data, np.ndarray)
    assert store.device_nbytes == a.nbytes
    assert store.host_nbytes == b.nbytes
    cat = store.catalog()
    assert cat["a"]["tier"] == "device" and cat["b"]["tier"] == "host"
    # explicit tier overrides the budget in both directions
    assert store.put("c", x, tier="device").tier == "device"
    store2 = TensorBlockStore(default_page_rows=32)   # no budget
    assert store2.put("d", x).tier == "device"
    assert store2.put("e", x, tier="host").tier == "host"
    with pytest.raises(ValueError):
        store2.put("f", x, tier="hbm")


def test_sparse_budget_spill(data_and_forest):
    _, xs, _ = data_and_forest
    store = TensorBlockStore(default_page_rows=PAGE, device_budget_bytes=1)
    ds = store.put_sparse("s", xs)
    assert ds.tier == "host"
    assert isinstance(ds.pages.indptr, np.ndarray)
    assert ds.pages.tier == "host"
    assert store.host_nbytes == ds.nbytes and store.device_nbytes == 0
    assert store.catalog()["s"]["tier"] == "host"


def test_datasets_implement_scan_source(data_and_forest):
    """Both dataset classes satisfy the executor's ScanSource protocol on
    both tiers — callers never branch on where pages live."""
    x, xs, _ = data_and_forest
    store = _put_tiered(x, xs)
    for name in ("dense@dev", "dense@host", "csr@dev", "csr@host"):
        ds = store.get(name)
        assert isinstance(ds, ScanSource), name
        blk = ds.page_slice(0, 2)
        dev = ds.to_device(blk, None)
        for leaf in jax.tree_util.tree_leaves(dev):
            assert isinstance(leaf, jax.Array), (name, type(leaf))


# ---------------------------------------------------------------------------
# bit-identical host-tier vs device-tier predictions (mesh-less half; the
# mesh half of the grid is in the multi-device section below)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("plan", ["udf", "rel"])
@pytest.mark.parametrize("fmt,algo", [("dense", FUSED),
                                      ("csr", SPARSE_ALGO)])
def test_host_tier_bitwise_parity(data_and_forest, plan, fmt, algo):
    x, xs, forest = data_and_forest
    engine = _engine(_put_tiered(x, xs))
    kw = dict(algorithm=algo, plan=plan, batch_pages=3)
    rd = engine.infer(f"{fmt}@dev", forest, **kw)
    rh = engine.infer(f"{fmt}@host", forest, **kw)
    assert rd.tier == "device" and rh.tier == "host"
    assert rh.storage_format == fmt
    assert rh.scan.batches > 1 and rh.scan.bytes_streamed > 0
    assert rd.scan.bytes_streamed == 0          # no-op transfer stage
    assert np.array_equal(np.asarray(rh.predictions),
                          np.asarray(rd.predictions)), "f32 bitwise parity"


def test_unfused_jnp_backend_streams_too(data_and_forest):
    """The executor is algorithm-agnostic: jnp backends stream the same."""
    x, xs, forest = data_and_forest
    engine = _engine(_put_tiered(x, xs))
    rd = engine.infer("dense@dev", forest, algorithm="predicated",
                      plan="udf", batch_pages=2)
    rh = engine.infer("dense@host", forest, algorithm="predicated",
                      plan="udf", batch_pages=2)
    assert np.array_equal(np.asarray(rh.predictions),
                          np.asarray(rd.predictions))


def test_budget_default_batch_pages_runs_out_of_core(data_and_forest):
    """End-to-end acceptance shape: budget below nbytes -> host tier,
    infer() derives a batch size so 2 in-flight buffers fit the budget,
    and streamed predictions match the all-device-resident run."""
    x, xs, forest = data_and_forest
    dev = _engine(_put_tiered(x, xs))
    store = TensorBlockStore(default_page_rows=PAGE,
                             device_budget_bytes=x.nbytes // 4)
    ds = store.put("big", x)
    assert ds.tier == "host" and ds.nbytes >= 4 * (x.nbytes // 4)
    engine = _engine(store)
    for plan in ("udf", "rel"):
        res = engine.infer("big", forest, algorithm=FUSED, plan=plan)
        ref = dev.infer("dense@dev", forest, algorithm=FUSED, plan=plan,
                        batch_pages=res.scan.batch_pages)
        assert res.scan.batches > 1
        # two in-flight page batches fit the budget
        assert 2 * res.scan.batch_pages * ds.page_nbytes \
            <= store.device_budget_bytes
        assert np.array_equal(np.asarray(res.predictions),
                              np.asarray(ref.predictions))


def test_host_tier_without_budget_still_streams(data_and_forest,
                                                monkeypatch):
    """An EXPLICIT host ingest on a budget-less store must not fall back
    to a whole-dataset device_put: the default batch is capped at the
    fixed streaming footprint instead."""
    import repro.db.query as q
    x, xs, forest = data_and_forest
    store = TensorBlockStore(default_page_rows=PAGE)   # no budget
    ds = store.put("h", x, tier="host")
    monkeypatch.setattr(q, "DEFAULT_STREAM_BATCH_BYTES",
                        3 * ds.page_nbytes)
    engine = _engine(store)
    res = engine.infer("h", forest, algorithm=FUSED, plan="udf")
    assert res.scan.batches > 1 and res.scan.batch_pages == 3
    ref = _engine(_put_tiered(x, xs)).infer(
        "dense@dev", forest, algorithm=FUSED, plan="udf", batch_pages=3)
    assert np.array_equal(np.asarray(res.predictions),
                          np.asarray(ref.predictions))


def test_device_pages_handoff_stays_on_device(data_and_forest):
    """put_sparse(pages=<device CSRPages>) must hand the arrays over
    as-is — no device->host->device round-trip on the in-database ingest
    boundary the paper measures."""
    _, xs, _ = data_and_forest
    from repro.db.sparse import csr_pages_from_dense
    pages = csr_pages_from_dense(xs, page_rows=PAGE)
    store = TensorBlockStore(default_page_rows=PAGE)
    ds = store.put_sparse("s", pages=pages, num_rows=xs.shape[0])
    assert ds.tier == "device"
    assert ds.pages.indptr is pages.indptr        # zero-copy handoff
    assert ds.pages.values is pages.values


# ---------------------------------------------------------------------------
# the double-buffer invariant: at most 2 device page buffers in flight
# ---------------------------------------------------------------------------


def test_at_most_two_buffers_in_flight(data_and_forest):
    x, xs, forest = data_and_forest
    engine = _engine(_put_tiered(x, xs))
    res = engine.infer("dense@host", forest, algorithm=FUSED, plan="udf",
                       batch_pages=2)
    assert res.scan.batches >= 3                 # a real pipeline
    assert res.scan.max_in_flight == MAX_IN_FLIGHT == 2
    assert res.scan.prefetch_depth == 2
    # synchronous reference pipeline: one buffer, same predictions
    ser = engine.infer("dense@host", forest, algorithm=FUSED, plan="udf",
                       batch_pages=2, prefetch_depth=1)
    assert ser.scan.max_in_flight == 1
    assert np.array_equal(np.asarray(ser.predictions),
                          np.asarray(res.predictions))


def test_live_device_buffer_count_during_stream():
    """The REAL buffer-count assertion (not just the executor's own
    counter): an unjitted probe stage counts live device arrays of the
    page-block shape mid-stream.  At most 2 ever exist — the block being
    computed plus the one in DMA flight — including for plans that
    thread "x" through to the stage output (the executor must drop the
    whole state, not just its own handle, to keep this true)."""
    from repro.db.operators import Operator, split_into_stages
    F_odd = 17                       # unique shape: nothing else matches
    x = np.arange(256 * F_odd, dtype=np.float32).reshape(256, F_odd)
    store = TensorBlockStore(default_page_rows=16)
    ds = store.put("probe", x, tier="host")
    batch_pages = 2
    block_shape = (batch_pages * ds.page_rows, F_odd)
    seen = []

    def probe(state):
        seen.append(sum(1 for a in jax.live_arrays()
                        if tuple(a.shape) == block_shape
                        and not a.is_deleted()))
        return state

    def udf(state):
        state = dict(state)
        state["pred"] = jnp.sum(state["x"], axis=1)   # keeps "x" threaded
        return state

    stages = split_into_stages(
        [Operator("probe", probe), Operator("udf", udf),
         Operator("write", lambda s: s, breaker=True)], jit=False)
    out, _, stats = StreamingScanExecutor(stages).execute(ds, batch_pages)
    assert stats.batches == len(seen) == 8
    assert max(seen) == 2, f"3+ page buffers were live: {seen}"
    assert seen[-1] == 1             # no prefetch past the last batch
    np.testing.assert_allclose(out, x.sum(axis=1), rtol=1e-6)


def test_executor_rejects_deeper_prefetch():
    """The <=2 invariant is a constructor-level contract, not a tuning
    knob: depths that would put 3+ page buffers in flight are refused."""
    with pytest.raises(ValueError):
        StreamingScanExecutor([], prefetch_depth=3)
    with pytest.raises(ValueError):
        StreamingScanExecutor([], prefetch_depth=0)


def test_single_batch_single_buffer(data_and_forest):
    """Whole-dataset batch: the pipeline degenerates to one buffer."""
    x, xs, forest = data_and_forest
    engine = _engine(_put_tiered(x, xs))
    res = engine.infer("dense@host", forest, algorithm=FUSED, plan="udf")
    assert res.scan.batches == 1 and res.scan.max_in_flight == 1


# ---------------------------------------------------------------------------
# eviction + re-page correctness
# ---------------------------------------------------------------------------


def test_eviction_and_promotion_preserve_predictions(data_and_forest):
    """move() device->host (eviction) and back (promotion): page layout —
    and therefore every prediction — is unchanged, bitwise."""
    x, xs, forest = data_and_forest
    store = _put_tiered(x, xs)
    engine = _engine(store)
    kw = dict(algorithm=FUSED, plan="udf", batch_pages=2)
    ref = engine.infer("dense@dev", forest, **kw)
    evicted = store.move("dense@dev", "host")
    assert evicted.tier == "host" and isinstance(evicted.data, np.ndarray)
    r_h = engine.infer("dense@dev", forest, **kw)
    assert r_h.tier == "host"
    promoted = store.move("dense@dev", "device")
    assert promoted.tier == "device"
    r_d = engine.infer("dense@dev", forest, **kw)
    for r in (r_h, r_d):
        assert np.array_equal(np.asarray(r.predictions),
                              np.asarray(ref.predictions))
    # CSR eviction too
    ref_s = engine.infer("csr@dev", forest, algorithm=SPARSE_ALGO,
                         plan="udf", batch_pages=2)
    store.move("csr@dev", "host")
    r_s = engine.infer("csr@dev", forest, algorithm=SPARSE_ALGO,
                       plan="udf", batch_pages=2)
    assert r_s.tier == "host"
    assert np.array_equal(np.asarray(r_s.predictions),
                          np.asarray(ref_s.predictions))


def test_repage_after_drop(data_and_forest):
    """Drop + re-put with a DIFFERENT page_rows (re-page): the new page
    layout batches differently but predictions are unchanged."""
    x, xs, forest = data_and_forest
    store = TensorBlockStore(default_page_rows=PAGE)
    store.put("d", x)
    engine = _engine(store)
    ref = engine.infer("d", forest, algorithm=FUSED, plan="udf")
    store.drop("d")
    store.put("d", x, page_rows=PAGE // 2, tier="host")
    res = engine.infer("d", forest, algorithm=FUSED, plan="udf",
                       batch_pages=3)
    assert res.tier == "host" and res.scan.batches > 1
    assert np.array_equal(np.asarray(res.predictions),
                          np.asarray(ref.predictions))


# ---------------------------------------------------------------------------
# drop -> dependent plan invalidation (stale-plan-after-re-put regression)
# ---------------------------------------------------------------------------


def test_drop_invalidates_dependent_plans(data_and_forest):
    """Regression: drop used to only delete the catalog reference — the
    compiled plans keyed on the dataset's batch signature stayed resident
    (pinning their device buffers) and a re-put with the same shape
    silently served the old executable as a "reuse hit".  drop must sweep
    dependent plan entries in every registered engine, so the first query
    after re-put honestly rebuilds."""
    x, xs, forest = data_and_forest
    store = _put_tiered(x, xs)
    engine = _engine(store)
    kw = dict(algorithm=FUSED, model_id="m-drop")
    engine.infer("dense@dev", forest, plan="udf", **kw)
    engine.infer("dense@dev", forest, plan="rel+reuse", **kw)
    engine.infer("dense@host", forest, plan="udf", **kw)
    assert len(engine.plan_cache) == 3
    n = store.drop("dense@dev")
    assert n == 2, "both of the dropped dataset's plans must be swept"
    assert len(engine.plan_cache) == 1           # dense@host survives
    # model materializations are dataset-independent: they survive
    assert len(engine.cache) == 1
    # re-put (same shape): NOT a stale plan hit — a fresh executable
    store.put("dense@dev", x)
    r = engine.infer("dense@dev", forest, plan="udf", **kw)
    assert not r.plan_reuse_hit
    # steady state re-established
    assert engine.infer("dense@dev", forest, plan="udf",
                        **kw).plan_reuse_hit
    # dead engines unregister themselves (weak hooks): no error on drop
    del engine
    assert store.drop("dense@host") == 0


# ---------------------------------------------------------------------------
# host-tier external ingest (the criteo-scale path)
# ---------------------------------------------------------------------------


def test_libsvm_host_tier_ingest(tmp_path, data_and_forest):
    _, xs, forest = data_and_forest
    y = np.zeros(xs.shape[0], np.float32)
    p = str(tmp_path / "d.svm")
    ld.write_libsvm(p, xs, y)
    pages_h, labels, t_h = ld.load_libsvm_csr_external(
        p, xs.shape[1], page_rows=PAGE, tier="host")
    assert t_h.transfer_s == 0.0, "host-tier ingest must not transfer"
    assert t_h.parse_s > 0 and t_h.total_s > 0
    assert isinstance(pages_h.indptr, np.ndarray)
    assert pages_h.tier == "host"
    # registers with zero device work and streams bit-identically to the
    # device-tier load of the same file
    pages_d, _, t_d = ld.load_libsvm_csr_external(p, xs.shape[1],
                                                  page_rows=PAGE)
    assert t_d.transfer_s > 0.0
    store = TensorBlockStore(default_page_rows=PAGE)
    store.put_sparse("h", pages=pages_h, num_rows=len(labels), tier="host")
    store.put_sparse("d", pages=pages_d, num_rows=len(labels))
    assert store.get("h").tier == "host" and store.get("d").tier == "device"
    engine = _engine(store)
    rh = engine.infer("h", forest, algorithm=SPARSE_ALGO, plan="udf",
                      batch_pages=2)
    rd = engine.infer("d", forest, algorithm=SPARSE_ALGO, plan="udf",
                      batch_pages=2)
    assert rh.tier == "host" and rh.storage_format == "csr"
    assert np.array_equal(np.asarray(rh.predictions),
                          np.asarray(rd.predictions))


# ---------------------------------------------------------------------------
# multi-device half of the parity grid (+ the pinned miscompile)
# ---------------------------------------------------------------------------

NDEV = len(jax.devices())
needs_mesh = pytest.mark.skipif(
    NDEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh(n_data, n_model):
    devs = np.array(jax.devices()[: n_data * n_model])
    from jax.sharding import Mesh
    return Mesh(devs.reshape(n_data, n_model), ("data", "model"))


@needs_mesh
@pytest.mark.parametrize("plan", ["udf", "rel"])
@pytest.mark.parametrize("fmt,algo", [("dense", FUSED),
                                      ("csr", SPARSE_ALGO)])
def test_mesh_host_tier_bitwise_parity(data_and_forest, plan, fmt, algo):
    """Host-tier pages DMA'd under data_sharding through the shard_map
    plans: bit-identical to the device-resident mesh run."""
    x, xs, forest = data_and_forest
    mesh = _mesh(2, 4)
    engine = _engine(_put_tiered(x, xs, mesh=mesh))
    kw = dict(algorithm=algo, plan=plan, batch_pages=4)
    rd = engine.infer(f"{fmt}@dev", forest, **kw)
    rh = engine.infer(f"{fmt}@host", forest, **kw)
    assert rh.tier == "host" and rh.mesh_devices == 8
    assert rh.scan.batches > 1 and rh.scan.max_in_flight == 2
    assert np.array_equal(np.asarray(rh.predictions),
                          np.asarray(rd.predictions)), "f32 bitwise parity"


@needs_mesh
def test_mesh_budget_batch_respects_budget(data_and_forest):
    """Data-axis divisibility must not inflate the budget-derived batch:
    the default is sized in data-axis units rounding DOWN, so the two
    in-flight buffers stay within the budget whenever it has room for
    at least one page per device."""
    x, _, forest = data_and_forest
    mesh = _mesh(2, 4)                           # n_data = 2
    budget = x.nbytes // 2
    store = TensorBlockStore(mesh, default_page_rows=PAGE,
                             device_budget_bytes=budget)
    ds = store.put("d", x)
    assert ds.tier == "host"
    res = _engine(store).infer("d", forest, algorithm=FUSED, plan="udf")
    assert res.scan.batch_pages % 2 == 0         # data-axis divisible
    assert 2 * res.scan.batch_pages * ds.page_nbytes <= budget


@needs_mesh
def test_mesh_multibatch_device_tier_needs_no_workaround(data_and_forest):
    """The retired jax-0.4.37 concatenate workaround's territory: multi-
    batch device-tier output on a (data, model) mesh.  The executor's
    host result buffer (per-shard copy + stitch) assembles it correctly
    without replicating anything first."""
    x, xs, forest = data_and_forest
    engine = _engine(_put_tiered(x, xs, mesh=_mesh(2, 4)))
    whole = engine.infer("dense@dev", forest, algorithm=FUSED, plan="rel")
    multi = engine.infer("dense@dev", forest, algorithm=FUSED, plan="rel",
                         batch_pages=2)
    assert multi.scan.batches > 1
    assert np.array_equal(np.asarray(multi.predictions),
                          np.asarray(whole.predictions))


@needs_mesh
@pytest.mark.skipif(jax.__version__ != "0.4.37",
                    reason="pinned to the jax 0.4.37 miscompile; if this "
                           "SKIPS after a jax bump, rerun it manually — "
                           "if it FAILS there, the bug is fixed: delete "
                           "this test and the executor docstring note")
def test_jax_0437_partial_replication_concat_miscompile_pinned():
    """PINNED reproduction of the XLA:CPU bug the old hot-path workaround
    existed for: eager ``jnp.concatenate`` of PARTIALLY replicated
    operands sums the replicas — a P('data')-sharded [B] on a
    (data, model) mesh comes out n_model times too large.  The streaming
    executor avoids the primitive entirely (host result buffer), so this
    is the only place the bug is still exercised."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = _mesh(4, 2)                        # n_model = 2
    sh = NamedSharding(mesh, P("data"))
    a = jax.device_put(np.arange(8, dtype=np.float32), sh)
    b = jax.device_put(np.arange(8, 16, dtype=np.float32), sh)
    got = np.asarray(jnp.concatenate([a, b]))
    want = np.arange(16, dtype=np.float32)
    assert np.array_equal(got, 2.0 * want), \
        "miscompile no longer reproduces — jax was fixed/bumped: delete " \
        "this test and the retired-workaround notes"
    # ...while the host gather the executor relies on is NOT affected:
    assert np.array_equal(np.asarray(a), want[:8])
