"""Fault tolerance: atomic checkpoints, bit-identical restart, failure
injection + elastic restore, straggler detection."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.dist.sharding import make_plan
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.data import DataConfig, synthetic_batch
from repro.train.fault import FailureInjector, TrainLoop
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.trainer import init_state, make_train_step

KEY = jax.random.PRNGKey(0)
CFG = reduced(get_config("olmo-1b"))


def _setup(tmp_path, **loop_kw):
    opt = make_optimizer(OptimizerConfig(name="adamw", lr=1e-3,
                                         warmup_steps=2))
    splan = make_plan(CFG, None)
    step = jax.jit(make_train_step(CFG, opt, splan))
    state = init_state(CFG, opt, KEY, dtype=jnp.float32)
    dc = DataConfig(seed=5, vocab_size=CFG.vocab_size, batch=4, seq_len=32)
    loop = TrainLoop(step, lambda k: synthetic_batch(dc, k),
                     ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=5,
                     **loop_kw)
    return loop, state


def _max_param_diff(a, b):
    d = jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                           - y.astype(jnp.float32)))), a, b)
    return max(jax.tree_util.tree_leaves(d))


def test_save_restore_roundtrip(tmp_path):
    loop, state = _setup(tmp_path)
    state, _ = loop.run(state, 6)
    restored, step = loop.restore(jax.eval_shape(lambda: state))
    assert step == 6
    assert _max_param_diff(state["params"], restored["params"]) == 0.0


def test_bit_identical_continuation(tmp_path):
    """train 10 straight  ==  train 5, 'crash', restore, train 5."""
    loop, state = _setup(tmp_path)
    full, _ = loop.run(state, 10)

    loop2, state2 = _setup(tmp_path / "b")
    mid, _ = loop2.run(state2, 5)
    restored, step = loop2.restore(jax.eval_shape(lambda: mid))
    assert step == 5
    resumed, _ = loop2.run(restored, 5, start_step=step)
    assert _max_param_diff(full["params"], resumed["params"]) == 0.0
    assert int(full["step"]) == int(resumed["step"]) == 10


def test_failure_injection_and_recovery(tmp_path):
    inj = FailureInjector(fail_at=7)
    loop, state = _setup(tmp_path, injector=inj)
    with pytest.raises(RuntimeError, match="injected node failure"):
        loop.run(state, 20)
    # checkpoint at step 5 survives; restart continues to 10
    assert latest_step(str(tmp_path / "ckpt")) == 5
    restored, step = loop.restore(jax.eval_shape(lambda: state))
    assert step == 5
    state2, report = loop.run(restored, 5, start_step=step)
    assert int(state2["step"]) == 10

    # and matches an uninterrupted run bit-for-bit
    loop3, state3 = _setup(tmp_path / "c")
    straight, _ = loop3.run(state3, 10)
    assert _max_param_diff(straight["params"], state2["params"]) == 0.0


def test_atomic_save_no_tmp_left(tmp_path):
    loop, state = _setup(tmp_path)
    save_checkpoint(str(tmp_path / "ckpt"), state, 3)
    entries = os.listdir(tmp_path / "ckpt")
    assert "step_00000003" in entries
    assert not any(e.endswith(".tmp") for e in entries)


def test_restore_shape_mismatch_raises(tmp_path):
    loop, state = _setup(tmp_path)
    save_checkpoint(str(tmp_path / "ckpt"), {"w": jnp.zeros((3, 3))}, 1)
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(str(tmp_path / "ckpt"),
                           {"w": jnp.zeros((2, 2))})


def test_straggler_detection(tmp_path):
    import time
    flagged = []
    opt = make_optimizer(OptimizerConfig(name="sgd", lr=1e-3))
    splan = make_plan(CFG, None)
    base_step = jax.jit(make_train_step(CFG, opt, splan))

    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 8:
            time.sleep(1.0)            # one slow host
        return base_step(state, batch)

    dc = DataConfig(seed=5, vocab_size=CFG.vocab_size, batch=4, seq_len=32)
    loop = TrainLoop(slow_step, lambda k: synthetic_batch(dc, k),
                     straggler_factor=3.0,
                     on_straggler=lambda s, dt: flagged.append(s))
    state = init_state(CFG, opt, KEY, dtype=jnp.float32)
    _, report = loop.run(state, 10)
    assert 7 in report.stragglers or flagged
