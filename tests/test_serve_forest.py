"""Forest serving plane: coalescer correctness, tenancy, LRU eviction.

The load-bearing claims, each tested against ground truth rather than
the engine's own bookkeeping:

  * coalescing is INVISIBLE to callers — row order preserved across
    arbitrary interleavings, padding rows never leak, predictions match
    the direct ``predict_proba`` kernel;
  * the bucket ladder keeps the steady state on the compiled-plan
    cache — zero plan misses after registration warmup;
  * tenancy is real — per-model plan keys never collide under
    interleaved multi-model traffic, and an LRU-evicted model
    re-registers and re-serves BIT-identically;
  * the deadline ladder fires — a lone interactive request flushes at
    the interactive deadline, batch-tier work waits for a full bucket
    but is bounded by the batch deadline, lapsed admission timeouts
    shed to the batch tier (PR 6 contract).
"""

import time

import numpy as np
import pytest

from repro.core.postprocess import predict_proba
from repro.core.train import TrainConfig, train_forest
from repro.obs import METRICS
from repro.serve.forest import ForestServeEngine
from repro.serve.router import (QUEUE_DEPTH_METRIC, TIER_BATCH,
                                TIER_INTERACTIVE, ForestRouter,
                                live_queue_depth, request_features)

F = 6


def _forest(seed: int, trees: int = 6, depth: int = 3):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(256, F)).astype(np.float32)
    y = (x[:, seed % F] + x[:, (seed + 1) % F] > 0).astype(np.float32)
    return train_forest(x, y, TrainConfig(model_type="randomforest",
                                          num_trees=trees, max_depth=depth,
                                          seed=seed))


def _rows(seed: int, n: int) -> np.ndarray:
    return np.random.default_rng(100 + seed).normal(
        size=(n, F)).astype(np.float32)


@pytest.fixture(scope="module")
def engine():
    eng = ForestServeEngine(buckets=(8,), interactive_deadline_s=0.001,
                            batch_deadline_s=0.02)
    eng.register_model("m0", _forest(0))
    return eng


def _ref(eng, model, x):
    return np.asarray(predict_proba(eng._get(model).forest, x,
                                    algorithm="predicated"))


# ---------------------------------------------------------------------------
# coalescer correctness
# ---------------------------------------------------------------------------

def test_row_order_preserved_across_coalesce(engine):
    """Mixed-size requests coalesced into one padded tick come back in
    request-row order, matching the direct kernel bitwise."""
    x = _rows(0, 7)
    sizes = [1, 3, 1, 2]
    reqs, off = [], 0
    for k in sizes:
        reqs.append(engine.submit("m0", x[off:off + k]))
        off += k
    engine.drain()
    got = np.concatenate([r.wait(5.0) for r in reqs])
    assert np.array_equal(got, _ref(engine, "m0", x))


def test_padding_never_leaks(engine):
    """3 rows into an 8-bucket: exactly 3 predictions, none NaN (the
    engine NaNs padding rows internally — a leak would surface here)."""
    x = _rows(1, 3)
    req = engine.submit("m0", x)
    engine.drain()
    out = req.wait(5.0)
    assert out.shape == (3,)
    assert not np.isnan(out).any()
    assert np.array_equal(out, _ref(engine, "m0", x))
    # and the flush really padded: 8-bucket, 3 live rows
    assert engine.stats("m0")["padding_rows"] >= 5


def test_steady_state_zero_plan_misses(engine):
    """After registration warmup, every tick hits a resident compiled
    plan — the zero-retrace property the bucket ladder buys."""
    st0 = engine.stats("m0")
    misses0 = METRICS.counter("plan.cache_misses").value
    for i in range(6):
        engine.submit("m0", _rows(2 + i, 1 + i % 4))
    engine.drain()
    st1 = engine.stats("m0")
    assert st1["plan_misses"] == st0["plan_misses"]
    assert st1["plan_hits"] > st0["plan_hits"]
    assert METRICS.counter("plan.cache_misses").value == misses0


def test_oversized_request_rejected(engine):
    with pytest.raises(ValueError, match="largest"):
        engine.submit("m0", _rows(9, 16))   # largest bucket is 8
    with pytest.raises(ValueError, match="features"):
        engine.submit("m0", np.zeros((1, F + 2), np.float32))


def test_deadline_flush_fires_on_lone_request(engine):
    """A single interactive request must not wait for a full bucket:
    the ticker flushes it at the interactive deadline."""
    engine.start()
    try:
        req = engine.submit("m0", _rows(10, 1),
                            priority=TIER_INTERACTIVE)
        out = req.wait(5.0)
    finally:
        engine.stop()
    assert out.shape == (1,)
    # flushed by deadline, not by a full bucket
    assert req.finished_at - req.submitted_at < 1.0


def test_batch_tier_waits_for_deadline():
    """TIER_BATCH work waits for a full bucket; the batch deadline
    bounds the wait for a queue that never fills one."""
    eng = ForestServeEngine(buckets=(8,), batch_deadline_s=0.05)
    eng.register_model("m", _forest(3))
    now = time.perf_counter()
    req = eng.submit("m", _rows(11, 2), priority=TIER_BATCH)
    assert eng.tick(now=now) == 0                  # not due yet
    assert not req.done.is_set()
    assert eng.tick(now=now + 0.051) == 2          # batch deadline lapsed
    assert req.done.is_set()
    # a FULL bucket flushes immediately, no deadline needed
    reqs = [eng.submit("m", _rows(12 + i, 2), priority=TIER_BATCH)
            for i in range(4)]
    assert eng.tick(now=time.perf_counter()) == 8
    assert all(r.done.is_set() for r in reqs)


def test_admission_timeout_sheds_to_batch_tier():
    """PR 6 degradation ladder, coalescer edition: an interactive
    request queued past its timeout is demoted to the batch tier
    (flagged + counted) instead of forcing an early flush."""
    eng = ForestServeEngine(buckets=(8,), interactive_deadline_s=0.001,
                            batch_deadline_s=0.05)
    eng.register_model("m", _forest(4))
    req = eng.submit("m", _rows(13, 1), priority=TIER_INTERACTIVE,
                     timeout_s=0.0)
    now = time.perf_counter()
    eng.tick(now=now + 0.002)      # past the interactive deadline ...
    assert not req.done.is_set()   # ... but it was shed first
    assert req.shed and req.priority == TIER_BATCH
    assert eng.stats("m")["shed"] == 1
    eng.tick(now=now + 0.06)       # batch deadline still bounds it
    assert np.array_equal(req.wait(1.0), _ref(eng, "m", req.rows))


def test_queue_depth_counter_roundtrip(engine):
    """The process-global arrival-load gauge: +1 per submit, -1 per
    coalesced admission, back to baseline after a drain."""
    base = METRICS.counter(QUEUE_DEPTH_METRIC).value
    reqs = [engine.submit("m0", _rows(20 + i, 1)) for i in range(5)]
    assert METRICS.counter(QUEUE_DEPTH_METRIC).value == base + 5
    engine.drain()
    for r in reqs:
        r.wait(5.0)
    assert METRICS.counter(QUEUE_DEPTH_METRIC).value == base


def test_predict_blocks_without_ticker(engine):
    x = _rows(30, 2)
    assert np.array_equal(engine.predict("m0", x),
                          _ref(engine, "m0", x))


# ---------------------------------------------------------------------------
# tenancy + LRU eviction
# ---------------------------------------------------------------------------

def test_multi_model_interleaved_traffic_never_collides():
    """Interleaved traffic over 3 tenants: every request's predictions
    match ITS model's direct kernel — a plan-key collision would serve
    one model's executable for another's rows."""
    eng = ForestServeEngine(buckets=(8,))
    for i in range(3):
        eng.register_model(f"t{i}", _forest(10 + i))
    x = _rows(40, 12)
    reqs = [(f"t{i % 3}", eng.submit(f"t{i % 3}", x[i:i + 1]))
            for i in range(12)]
    eng.drain()
    for i, (name, req) in enumerate(reqs):
        assert np.array_equal(req.wait(5.0), _ref(eng, name, x[i:i + 1])), \
            f"request {i} served with the wrong tenant's plan"
    # the tenants are genuinely different models (the check above would
    # pass vacuously otherwise)
    assert not np.array_equal(_ref(eng, "t0", x), _ref(eng, "t1", x))


def test_lru_eviction_and_bit_identical_reserve():
    """More tenants than the plan cache holds: the coldest model's
    executable ages out (a plan MISS on its next request), but the
    model catalog pin keeps it servable — and the recompiled plan
    serves BIT-identical predictions."""
    eng = ForestServeEngine(buckets=(8,), max_plans=3)
    x = _rows(50, 4)
    eng.register_model("a", _forest(20))
    first = eng.predict("a", x)
    a_misses0 = eng.stats("a")["plan_misses"]
    # 3 more tenants x 1 bucket each: "a"'s plan is the LRU victim
    for i in range(3):
        eng.register_model(f"b{i}", _forest(21 + i))
    miss0 = METRICS.counter("plan.cache_misses").value
    again = eng.predict("a", x)
    assert METRICS.counter("plan.cache_misses").value > miss0, \
        "expected an eviction-driven plan miss"
    assert eng.stats("a")["plan_misses"] > a_misses0
    assert np.array_equal(first, again)
    # warm again -> steady state restored (next serve is a hit)
    h0 = eng.stats("a")["plan_hits"]
    assert np.array_equal(eng.predict("a", x), first)
    assert eng.stats("a")["plan_hits"] > h0


def test_unregister_then_reregister_serves_identically():
    eng = ForestServeEngine(buckets=(8,))
    f = _forest(30)
    eng.register_model("m", f)
    x = _rows(60, 3)
    first = eng.predict("m", x)
    assert eng.unregister_model("m") > 0          # plans swept
    with pytest.raises(KeyError):
        eng.submit("m", x)
    with pytest.raises(KeyError):
        eng.store.get_model("m")
    eng.register_model("m", f)
    assert np.array_equal(eng.predict("m", x), first)


def test_store_model_catalog_roundtrip():
    eng = ForestServeEngine(buckets=(8,))
    f = _forest(31)
    eng.register_model("cat", f, warmup=False)
    assert eng.store.get_model("cat") is f
    cat = eng.store.model_catalog()
    assert "cat" in cat and "forest" not in cat["cat"]
    assert cat["cat"]["trees"] == f.num_trees
    assert eng.models()["cat"]["algorithm"] == "predicated"


# ---------------------------------------------------------------------------
# router: live arrival-load feature + named tier defaults (satellites)
# ---------------------------------------------------------------------------

def test_live_queue_depth_reads_metric_and_clamps():
    c = METRICS.counter(QUEUE_DEPTH_METRIC)
    old = c.value
    try:
        c.set(7)
        assert live_queue_depth() == 7.0
        assert request_features(4, 2)[2] == 7.0
        c.set(-3)          # transient mid-reset skew must not go negative
        assert live_queue_depth() == 0.0
    finally:
        c.set(old)


def test_routing_shifts_with_live_load():
    """The regression the live-load feature exists for: the SAME
    request routes interactive when the process is idle and batch when
    the queue metric reports load — without the caller passing depth."""
    router = ForestRouter(seed=0)
    flip = None
    for plen in range(40, 520, 40):
        for mnt in range(10, 260, 25):
            idle = router.route(request_features(plen, mnt, 0.0))
            busy = router.route(request_features(plen, mnt, 60.0))
            if idle == TIER_INTERACTIVE and busy == TIER_BATCH:
                flip = (plen, mnt)
                break
        if flip:
            break
    assert flip is not None, "no load-sensitive request in the grid"
    plen, mnt = flip
    c = METRICS.counter(QUEUE_DEPTH_METRIC)
    old = c.value
    try:
        c.set(0)
        assert router.route(request_features(plen, mnt)) \
            == TIER_INTERACTIVE
        c.set(60)
        assert router.route(request_features(plen, mnt)) == TIER_BATCH
    finally:
        c.set(old)


def test_default_priority_is_named_batch_tier():
    """Satellite: the request default is the named TIER_BATCH constant
    (not a magic int), in both serve engines' request types."""
    from repro.serve.engine import Request
    import dataclasses as dc
    assert Request(uid=1, prompt=np.zeros(1, np.int32)).priority \
        == TIER_BATCH
    from repro.serve.forest import ForestRequest
    f = dc.fields(ForestRequest)
    assert next(fl for fl in f if fl.name == "priority").default \
        == TIER_BATCH
