"""In-database layer: store, plans (udf / rel / rel+reuse), loaders.

The paper's core systems claims, as testable invariants:
  * all three physical plans produce identical predictions;
  * udf compiles to ONE pipeline stage, rel to multiple (Sec. 3.2/3.3);
  * model-reuse skips the partition stage on the second query (netsDB-OPT);
  * external loaders (CSV / LIBSVM / array-rows) round-trip exactly and
    report the split load/convert/transfer timings the benchmarks plot.
"""

import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.postprocess import predict_proba
from repro.core.reuse import ModelReuseCache
from repro.core.train import TrainConfig, train_forest
from repro.db.loader import (load_array_rows_external, load_csv_external,
                             load_libsvm_external, synth_dataset,
                             write_array_rows, write_csv, write_libsvm)
from repro.obs import METRICS
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(300, 8)).astype(np.float32)
    w = rng.normal(size=8).astype(np.float32)
    y = (x @ w > 0).astype(np.float32)
    forest = train_forest(x, y, TrainConfig(model_type="xgboost",
                                            num_trees=12, max_depth=4))
    store = TensorBlockStore(default_page_rows=64)
    store.put("test", x, labels=y)
    return store, forest, x


PLANS = ["udf", "rel", "rel+reuse"]


@pytest.mark.parametrize("plan", PLANS)
@pytest.mark.parametrize("algorithm", ["predicated", "hummingbird",
                                       "quickscorer"])
def test_plans_agree_with_direct(setup, plan, algorithm):
    store, forest, x = setup
    engine = ForestQueryEngine(store,
                               reuse_cache=ModelReuseCache())
    res = engine.infer("test", forest, algorithm=algorithm, plan=plan)
    direct = predict_proba(forest, jnp.asarray(x), algorithm=algorithm)
    np.testing.assert_allclose(np.asarray(res.predictions),
                               np.asarray(direct), rtol=1e-5, atol=1e-6)


def test_stage_counts(setup):
    store, forest, _ = setup
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
    udf = engine.infer("test", forest, plan="udf")
    rel = engine.infer("test", forest, plan="rel")
    assert udf.num_stages == 1
    assert rel.num_stages >= 4      # partition, cross-product, agg, write


def test_model_reuse_skips_partition(setup):
    store, forest, _ = setup
    cache = ModelReuseCache()
    engine = ForestQueryEngine(store, reuse_cache=cache)
    r1 = engine.infer("test", forest, plan="rel+reuse", model_id="m1")
    r2 = engine.infer("test", forest, plan="rel+reuse", model_id="m1")
    assert not r1.reuse_hit and r2.reuse_hit
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    assert r2.partition_s == 0.0
    np.testing.assert_allclose(np.asarray(r1.predictions),
                               np.asarray(r2.predictions))


FUSED = ["predicated_pallas_fused", "hummingbird_pallas_fused",
         "quickscorer_pallas_fused"]


@pytest.mark.parametrize("plan", PLANS)
@pytest.mark.parametrize("algorithm", FUSED)
def test_fused_plans_agree_with_direct(setup, plan, algorithm):
    """Fused in-kernel aggregation backends through every physical plan."""
    store, forest, x = setup
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                               plan_cache=ModelReuseCache())
    res = engine.infer("test", forest, algorithm=algorithm, plan=plan)
    base = algorithm.replace("_pallas_fused", "")
    direct = predict_proba(forest, jnp.asarray(x), algorithm=base)
    np.testing.assert_allclose(np.asarray(res.predictions),
                               np.asarray(direct), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("plan", ["udf", "rel+reuse"])
def test_compiled_plan_cache_no_retrace(setup, plan):
    """Second identical query: reuse_hit, zero partition time, and ZERO
    re-traces of any stage function (the compile counter must not move)."""
    store, forest, x = setup
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                               plan_cache=ModelReuseCache())
    kw = dict(algorithm="hummingbird_pallas_fused", plan=plan,
              model_id="plan-cache-m1")
    r1 = engine.infer("test", forest, **kw)
    assert not r1.plan_reuse_hit
    traces_after_first = METRICS.counter("plan.traces").value
    assert traces_after_first > 0

    r2 = engine.infer("test", forest, **kw)
    assert r2.reuse_hit and r2.plan_reuse_hit
    assert r2.partition_s == 0.0
    assert METRICS.counter("plan.traces").value == traces_after_first, \
        "stage re-traced"
    np.testing.assert_allclose(np.asarray(r1.predictions),
                               np.asarray(r2.predictions))


def test_plan_cache_distinguishes_batch_shape(setup):
    """A different page batching is a different executable: no false hit."""
    store, forest, _ = setup
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                               plan_cache=ModelReuseCache())
    r1 = engine.infer("test", forest, plan="udf", model_id="m-bs")
    r2 = engine.infer("test", forest, plan="udf", model_id="m-bs",
                      batch_pages=2)
    assert not r2.plan_reuse_hit
    np.testing.assert_allclose(np.asarray(r1.predictions),
                               np.asarray(r2.predictions), rtol=1e-6)


def test_plan_cache_not_stale_after_model_eviction(setup):
    """If the model cache evicts and rebuilds a materialization, the plan
    cache must MISS (its stages close over the old mat) — partition cost
    is honestly reported and no stale executable is served."""
    store, forest, x = setup
    rng = np.random.default_rng(3)
    y2 = (x @ rng.normal(size=x.shape[1]).astype(np.float32) > 0)
    forest2 = train_forest(x, y2.astype(np.float32),
                           TrainConfig(model_type="xgboost", num_trees=8,
                                       max_depth=3))
    engine = ForestQueryEngine(store,
                               reuse_cache=ModelReuseCache(max_entries=1),
                               plan_cache=ModelReuseCache())
    kw = dict(algorithm="predicated", plan="rel+reuse")
    r1 = engine.infer("test", forest, model_id="mA", **kw)
    engine.infer("test", forest2, model_id="mB", **kw)   # evicts mA's mat
    r3 = engine.infer("test", forest, model_id="mA", **kw)
    assert not r3.reuse_hit and not r3.plan_reuse_hit
    assert r3.partition_s > 0.0
    np.testing.assert_allclose(np.asarray(r3.predictions),
                               np.asarray(r1.predictions))


def test_engine_invalidate_sweeps_both_caches(setup):
    """Regression: plan keys lead with a kind tag ('udf-plan'/'rel-plan'),
    so ModelReuseCache.invalidate's key[0] == model_id match silently
    misses every compiled plan.  The engine-level invalidate must sweep
    BOTH the partition cache and the plan cache."""
    store, forest, _ = setup
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                               plan_cache=ModelReuseCache())
    engine.infer("test", forest, plan="udf", model_id="mX")
    engine.infer("test", forest, plan="rel+reuse", model_id="mX")
    assert len(engine.cache) == 1 and len(engine.plan_cache) == 2
    # the raw cache-level sweep is exactly the silent miss being fixed
    assert engine.plan_cache.invalidate("mX") == 0
    n = engine.invalidate("mX")
    assert n == 3
    assert len(engine.cache) == 0 and len(engine.plan_cache) == 0
    # next queries rebuild from scratch: no stale hit either way
    r = engine.infer("test", forest, plan="rel+reuse", model_id="mX")
    assert not r.reuse_hit and not r.plan_reuse_hit
    # other models' entries survive a targeted sweep
    engine.infer("test", forest, plan="udf", model_id="mY")
    engine.invalidate("mX")
    assert len(engine.plan_cache) == 1


def test_reuse_cache_is_lru_not_fifo():
    """A hit must refresh recency: with capacity 2, touching A before
    inserting C must evict B (FIFO would evict the hot A)."""
    import dataclasses as _dc

    @_dc.dataclass
    class E:
        v: int
        build_time_s: float = 0.0

    cache = ModelReuseCache(max_entries=2)
    cache.get_or_build(("A",), lambda: E(1))
    cache.get_or_build(("B",), lambda: E(2))
    cache.get_or_build(("A",), lambda: E(-1))      # hit: refresh A
    cache.get_or_build(("C",), lambda: E(3))       # evicts B, not A
    assert cache.get_or_build(("A",), lambda: E(-2)).v == 1, "hot A evicted"
    assert cache.get_or_build(("B",), lambda: E(4)).v == 4, "B survived"


def test_rel_n_parts_default_derived_from_tree_block(setup):
    """Mesh-less rel partitioning of kernel-backed algorithms derives
    from the kernel tree-block heuristic (ceil(T / tree_block)), not the
    old magic 4; jnp backends (no tree blocks) keep the small default."""
    store, forest, x = setup
    from repro.core.forest import make_forest
    from repro.kernels.ops import default_tree_block
    from conftest import random_forest_arrays
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
    # jnp backend: thread-count-like default, clamped to the tree count
    r = engine.infer("test", forest, plan="rel", algorithm="predicated")
    assert r.n_parts == 4
    # fused kernel backend, 12 trees <= one 32-tree block -> 1 partition
    rf = engine.infer("test", forest, plan="rel",
                      algorithm="predicated_pallas_fused")
    assert rf.n_parts == 1
    # a forest wider than one tree block really splits: 100 trees / 32
    fe, th, dl, lv = random_forest_arrays(np.random.default_rng(5),
                                          T=100, depth=3, F=8, seed=5)
    wide = make_forest(fe, th, lv, default_left=dl, n_features=8)
    bt = default_tree_block(wide, fused=True)
    assert engine._resolve_n_parts(wide, "predicated_pallas_fused", None) \
        == -(-100 // bt) == 4
    direct = predict_proba(forest, jnp.asarray(x), algorithm="predicated")
    np.testing.assert_allclose(np.asarray(r.predictions),
                               np.asarray(direct), rtol=1e-5, atol=1e-6)


def test_rel_n_parts_override(setup):
    """infer(n_parts=...) overrides the mesh-less partition count; the
    partition count is part of both rel cache keys (no false sharing)."""
    store, forest, x = setup
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                               plan_cache=ModelReuseCache())
    kw = dict(algorithm="predicated_pallas_fused", plan="rel+reuse",
              model_id="np-m1")
    r3 = engine.infer("test", forest, n_parts=3, **kw)
    assert r3.n_parts == 3
    r4 = engine.infer("test", forest, n_parts=4, **kw)
    assert r4.n_parts == 4 and not r4.reuse_hit, \
        "different n_parts must be a different materialization + plan"
    again = engine.infer("test", forest, n_parts=3, **kw)
    assert again.reuse_hit and again.n_parts == 3
    direct = predict_proba(forest, jnp.asarray(x), algorithm="predicated")
    for r in (r3, r4):
        np.testing.assert_allclose(np.asarray(r.predictions),
                                   np.asarray(direct), rtol=1e-5, atol=1e-6)


def test_batching_equivalence(setup):
    """F3: page-batched execution must equal single-batch execution."""
    store, forest, x = setup
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
    whole = engine.infer("test", forest, plan="udf")
    batched = engine.infer("test", forest, plan="udf", batch_pages=2)
    np.testing.assert_allclose(np.asarray(batched.predictions),
                               np.asarray(whole.predictions), rtol=1e-6)


def test_write_operator(setup):
    store, forest, _ = setup
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
    res = engine.infer("test", forest, plan="udf", write_as="preds_out")
    assert "preds_out" in store
    out = store.get("preds_out")
    assert out.num_rows == 300
    assert res.write_s >= 0.0


def test_store_page_padding():
    store = TensorBlockStore(default_page_rows=64)
    ds = store.put("odd", np.ones((100, 4), np.float32))
    assert ds.num_rows == 100
    assert ds.data.shape[0] % 64 == 0
    # padded rows are NaN (never counted in results)
    tail = np.asarray(ds.data)[100:]
    assert np.isnan(tail).all()


# ---------------------------------------------------------------------------
# external loaders (the data-loading cost the paper measures)
# ---------------------------------------------------------------------------


def test_csv_roundtrip(tmp_path):
    x, _ = synth_dataset("fraud", max_rows=50)
    p = str(tmp_path / "d.csv")
    write_csv(p, x)
    dev, timing = load_csv_external(p)
    np.testing.assert_allclose(np.asarray(dev), x, rtol=1e-4, atol=1e-5)
    assert timing.total_s > 0 and timing.parse_s > 0


def test_libsvm_roundtrip(tmp_path):
    x, y = synth_dataset("bosch", max_rows=40)
    p = str(tmp_path / "d.svm")
    write_libsvm(p, x, y)
    dev, labels, timing = load_libsvm_external(p, x.shape[1])
    got = np.asarray(dev)
    mask = ~np.isnan(x) & (x != 0.0)
    np.testing.assert_allclose(got[mask], x[mask], rtol=1e-4, atol=1e-5)
    assert np.isnan(got[~mask]).all()
    np.testing.assert_allclose(labels, y)


def test_array_rows_roundtrip(tmp_path):
    x, _ = synth_dataset("epsilon", max_rows=10)
    p = str(tmp_path / "d.arr")
    write_array_rows(p, x)
    dev, timing = load_array_rows_external(p)
    np.testing.assert_allclose(np.asarray(dev), x, rtol=1e-4, atol=1e-5)
    assert timing.convert_s >= 0
