"""Property-based tests (hypothesis) on the system's invariants."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.algorithms import naive_predict, predict_raw
from repro.core.forest import make_forest, pad_trees
from repro.core.postprocess import postprocess

SETTINGS = dict(max_examples=20, deadline=None)


@st.composite
def forests(draw):
    T = draw(st.integers(1, 6))
    depth = draw(st.integers(1, 5))
    F = draw(st.integers(1, 8))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    I, L = (1 << depth) - 1, 1 << depth
    return make_forest(
        rng.integers(0, F, (T, I)).astype(np.int32),
        rng.normal(size=(T, I)).astype(np.float32),
        rng.normal(size=(T, L)).astype(np.float32),
        default_left=rng.random((T, I)) < 0.5,
        n_features=F), seed


@given(forests(), st.sampled_from(["predicated", "hummingbird",
                                   "quickscorer"]))
@settings(**SETTINGS)
def test_backends_equal_naive(fs, backend):
    forest, seed = fs
    rng = np.random.default_rng(seed + 1)
    x = rng.normal(size=(5, forest.n_features)).astype(np.float32)
    want = np.asarray(naive_predict(forest, jnp.asarray(x)))
    got = np.asarray(predict_raw(forest, jnp.asarray(x), backend))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@given(forests())
@settings(**SETTINGS)
def test_prediction_in_leaf_range(fs):
    """Every per-tree raw score must be one of that tree's leaf values."""
    forest, seed = fs
    rng = np.random.default_rng(seed + 2)
    x = rng.normal(size=(4, forest.n_features)).astype(np.float32)
    raw = np.asarray(predict_raw(forest, jnp.asarray(x), "predicated"))
    leaves = np.asarray(forest.leaf_value)
    for t in range(forest.num_trees):
        for b in range(x.shape[0]):
            assert np.any(np.isclose(raw[b, t], leaves[t])), (b, t)


@given(forests(), st.integers(1, 7))
@settings(**SETTINGS)
def test_padding_never_changes_sum(fs, multiple):
    forest, seed = fs
    rng = np.random.default_rng(seed + 3)
    x = rng.normal(size=(3, forest.n_features)).astype(np.float32)
    base = np.asarray(predict_raw(forest, jnp.asarray(x),
                                  "predicated")).sum(-1)
    padded, _ = pad_trees(forest, multiple)
    got = np.asarray(predict_raw(padded, jnp.asarray(x),
                                 "predicated")).sum(-1)
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-6)


@given(forests())
@settings(**SETTINGS)
def test_tree_permutation_invariance(fs):
    """Forest prediction is a sum over trees — permutation-invariant."""
    forest, seed = fs
    rng = np.random.default_rng(seed + 4)
    x = rng.normal(size=(3, forest.n_features)).astype(np.float32)
    perm = rng.permutation(forest.num_trees)
    shuffled = dataclasses.replace(
        forest, **{k: v[perm] for k, v in forest.arrays().items()})
    a = np.asarray(predict_raw(forest, jnp.asarray(x), "predicated")).sum(-1)
    b = np.asarray(predict_raw(shuffled, jnp.asarray(x),
                               "predicated")).sum(-1)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@given(st.integers(1, 200), st.floats(-5, 5),
       st.sampled_from(["randomforest", "xgboost"]))
@settings(**SETTINGS)
def test_postprocess_probability_bounds(n_trees, summed, model_type):
    p = postprocess(jnp.asarray([summed * n_trees], jnp.float32),
                    model_type=model_type, task="classification",
                    num_trees=n_trees)
    val = float(p[0])
    assert 0.0 <= val <= 1.0


@given(st.integers(0, 2**16), st.integers(1, 4), st.integers(2, 48))
@settings(**SETTINGS)
def test_chunked_attention_matches_dense(seed, b, s):
    """The flash-style blockwise attention == plain softmax attention."""
    from repro.models.layers import _chunked_sdpa, _sdpa, AttnSpec
    rng = np.random.default_rng(seed)
    H = KV = 2
    dh = 4
    q = jnp.asarray(rng.normal(size=(b, s, H, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, KV, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, KV, dh)).astype(np.float32))
    pos = jnp.arange(s)
    mask = pos[:, None] >= pos[None, :]
    want = _sdpa(q, k, v, mask, kv_groups=1)
    got = _chunked_sdpa(q, k, v, kv_groups=1, q_positions=pos,
                        kv_positions=pos,
                        spec=AttnSpec(causal=True), chunk=7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@given(st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_ssd_chunked_matches_recurrence(seed):
    """SSD chunked scan == the literal per-token recurrence."""
    from repro.configs import get_config, reduced
    from repro.models.ssd import init_ssd, ssd_forward, ssd_decode, \
        init_ssd_cache
    cfg = reduced(get_config("mamba2-2.7b"))
    key = jax.random.PRNGKey(seed)
    p = init_ssd(cfg, key, jnp.float32)
    rng = np.random.default_rng(seed)
    B, S = 1, 24
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)
                    * 0.3)
    full = ssd_forward(cfg, p, x, chunk=8)
    cache = init_ssd_cache(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        y, cache = ssd_decode(cfg, p, x[:, t:t + 1], cache)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


# ---------------------------------------------------------------------------
# In-database training invariants (core/train.py + db/train.py)
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**16), st.integers(1, 8), st.integers(20, 200),
       st.integers(4, 32), st.floats(0.0, 0.6))
@settings(**SETTINGS)
def test_quantile_edges_monotone_and_missing_slot(seed, F, N, num_bins,
                                                  nan_frac):
    """Edges are per-column non-decreasing and NaN always lands in the
    dedicated MISSING slot, never in a value bin — under arbitrary
    random NaN patterns (including all-NaN and constant columns)."""
    from repro.core.train import quantile_bin_edges, bin_features
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(N, F)).astype(np.float32)
    x[rng.random((N, F)) < nan_frac] = np.nan
    if F >= 2:
        x[:, 1] = 7.0          # constant column -> +inf edges, still valid
    edges = quantile_bin_edges(x, num_bins)
    assert edges.shape == (F, num_bins - 1)
    # inf <= inf is True, so this also covers the dedup/constant columns.
    assert np.all(edges[:, :-1] <= edges[:, 1:])
    bins = np.asarray(bin_features(x, edges))
    nan_mask = np.isnan(x)
    assert np.all(bins[nan_mask] == num_bins)
    assert np.all(bins[~nan_mask] >= 0)
    assert np.all(bins[~nan_mask] < num_bins)


@given(st.integers(0, 2**16),
       st.sampled_from(["xgboost", "lightgbm", "randomforest"]))
@settings(max_examples=8, deadline=None)
def test_trained_forest_compact_invariant(seed, model_type):
    """compact_forest on a trained forest never changes predictions:
    scoring x[:, gather_idx] with the compact forest is bit-identical
    to scoring x with the original."""
    from repro.core.train import TrainConfig, train_forest
    from repro.core.forest import compact_forest
    rng = np.random.default_rng(seed)
    N, F = 160, 7
    x = rng.normal(size=(N, F)).astype(np.float32)
    y = (x[:, 0] + 0.5 * x[:, 2] > 0).astype(np.float32)
    cfg = TrainConfig(model_type=model_type, num_trees=3, max_depth=3,
                      num_bins=16, colsample=0.7, seed=seed)
    forest = train_forest(x, y, cfg)
    cf, gather_idx = compact_forest(forest)
    want = predict_raw(forest, jnp.asarray(x))
    got = predict_raw(cf, jnp.asarray(x[:, gather_idx]))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(0, 2**16))
@settings(max_examples=5, deadline=None)
def test_trained_forest_same_across_plans(seed):
    """A trained forest scores identically under plan=udf and plan=rel."""
    from repro.core.train import TrainConfig, train_forest
    from repro.core.reuse import ModelReuseCache
    from repro.db.store import TensorBlockStore
    from repro.db.query import ForestQueryEngine
    rng = np.random.default_rng(seed)
    N, F = 192, 6
    x = rng.normal(size=(N, F)).astype(np.float32)
    y = (x[:, 1] - x[:, 3] > 0).astype(np.float32)
    forest = train_forest(x, y, TrainConfig(num_trees=3, max_depth=3,
                                            num_bins=16, seed=seed))
    store = TensorBlockStore(default_page_rows=64)
    store.put("prop-train", x)
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
    udf = engine.infer("prop-train", forest, plan="udf")
    rel = engine.infer("prop-train", forest, plan="rel")
    np.testing.assert_allclose(np.asarray(udf.predictions),
                               np.asarray(rel.predictions),
                               rtol=1e-6, atol=1e-6)
