"""Cost-based optimizer: decisions, persistence, invalidation, routing.

The optimizer's contract, as testable invariants:
  * ``infer(plan="auto", algorithm="auto")`` resolves to a concrete
    feasible cell and its predictions are BIT-identical to running that
    cell statically;
  * the first decision per (model, dataset signature, mesh) pays one
    bounded autotune pass; every repeat is a persisted-decision lookup
    (``optimizer.decision_cache_hits``; ZERO ``autotune_runs`` deltas);
  * decisions are swept exactly like compiled plans —
    ``engine.invalidate(model_id)``, ``store.drop``,
    ``invalidate_dataset``, and a re-``put`` of the dataset
    (stale-decision regression) all remove them;
  * the analytic cost model ranks the paper's asymptotics correctly
    (hummingbird's GEMM grows with 2^{2·depth}; everything grows with
    rows × trees) and the calibrated-peaks table is measured once;
  * the serving plane resolves ``algorithm="auto"`` once at
    registration;
  * importing ``launch.hillclimb`` is side-effect-free (regression for
    the XLA_FLAGS-above-docstring bug).
"""

import os

import numpy as np
import pytest

from repro.core.reuse import ModelReuseCache
from repro.core.train import TrainConfig, train_forest
from repro.db.optimizer import (DEFAULT_ALGORITHMS, CostBasedOptimizer,
                                Decision, _forest_flop_bytes,
                                dataset_signature)
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore
from repro.obs import METRICS


def _counter(name: str) -> int:
    return METRICS.counter_values().get(name, 0)


def _tight(engine) -> CostBasedOptimizer:
    """Test-sized budgets: tiny probes, no minutes-long autotunes."""
    opt = CostBasedOptimizer(engine, measure_budget_s=2.0,
                             max_measurements=6, probe_iters=1)
    engine.optimizer = opt
    return opt


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(300, 8)).astype(np.float32)
    w = rng.normal(size=8).astype(np.float32)
    y = (x @ w > 0).astype(np.float32)
    forest = train_forest(x, y, TrainConfig(model_type="xgboost",
                                            num_trees=12, max_depth=4))
    return forest, x


def _store(x) -> TensorBlockStore:
    store = TensorBlockStore(default_page_rows=64)
    store.put("ds", x)
    return store


# ---------------------------------------------------------------------------
# auto routing + decision persistence
# ---------------------------------------------------------------------------

def test_auto_matches_static_bit_identically(setup):
    forest, x = setup
    engine = ForestQueryEngine(_store(x), reuse_cache=ModelReuseCache())
    _tight(engine)
    res = engine.infer("ds", forest, plan="auto", algorithm="auto")
    assert res.decision is not None
    assert res.algorithm in DEFAULT_ALGORITHMS
    assert res.plan in ("udf", "rel+reuse")
    static = engine.infer("ds", forest, plan=res.plan,
                          algorithm=res.algorithm, n_parts=res.n_parts)
    assert np.array_equal(np.asarray(res.predictions),
                          np.asarray(static.predictions), equal_nan=True)


def test_repeat_auto_is_a_lookup_not_an_autotune(setup):
    forest, x = setup
    engine = ForestQueryEngine(_store(x), reuse_cache=ModelReuseCache())
    _tight(engine)
    first = engine.infer("ds", forest, plan="auto", algorithm="auto")
    runs0, hits0 = _counter("optimizer.autotune_runs"), \
        _counter("optimizer.decision_cache_hits")
    again = engine.infer("ds", forest, plan="auto", algorithm="auto")
    assert _counter("optimizer.autotune_runs") == runs0     # ZERO re-runs
    assert _counter("optimizer.decision_cache_hits") == hits0 + 1
    assert again.decision == first.decision
    assert (again.algorithm, again.plan) == (first.algorithm, first.plan)


def test_pinned_axis_constrains_the_decision(setup):
    forest, x = setup
    engine = ForestQueryEngine(_store(x), reuse_cache=ModelReuseCache())
    _tight(engine)
    res = engine.infer("ds", forest, plan="rel+reuse", algorithm="auto")
    assert res.plan == "rel+reuse"
    assert res.algorithm in DEFAULT_ALGORITHMS
    res2 = engine.infer("ds", forest, plan="auto",
                        algorithm="hummingbird")
    assert res2.algorithm == "hummingbird"
    assert res2.plan in ("udf", "rel+reuse")


def test_decision_persists_in_store_catalog(setup):
    forest, x = setup
    store = _store(x)
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
    _tight(engine)
    engine.infer("ds", forest, plan="auto", algorithm="auto")
    cat = store.decision_catalog()
    assert len(cat) == 1
    (key, entry), = cat.items()
    assert key[0] == engine._model_key(forest, None)     # fingerprint
    assert key[1] == "ds"                                # dataset name
    assert key[2] == dataset_signature(store.get("ds"))
    assert entry["source"] in ("measured", "model")
    assert entry["plan"] in ("udf", "rel+reuse")


# ---------------------------------------------------------------------------
# invalidation: decisions are swept exactly like compiled plans
# ---------------------------------------------------------------------------

def test_invalidate_model_sweeps_decisions(setup):
    forest, x = setup
    store = _store(x)
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
    _tight(engine)
    engine.infer("ds", forest, plan="auto", algorithm="auto")
    assert len(store.decision_catalog()) == 1
    mid = engine._model_key(forest, None)
    swept = engine.invalidate(mid)
    assert swept >= 1                       # plans + the decision
    assert store.decision_catalog() == {}
    # next auto query re-decides (miss), not a stale hit
    misses0 = _counter("optimizer.decision_cache_misses")
    engine.infer("ds", forest, plan="auto", algorithm="auto")
    assert _counter("optimizer.decision_cache_misses") == misses0 + 1


def test_store_drop_sweeps_decisions(setup):
    forest, x = setup
    store = _store(x)
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
    _tight(engine)
    engine.infer("ds", forest, plan="auto", algorithm="auto")
    assert len(store.decision_catalog()) == 1
    swept = store.drop("ds")
    assert swept >= 1
    assert store.decision_catalog() == {}


def test_invalidate_dataset_sweeps_decisions(setup):
    forest, x = setup
    store = _store(x)
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
    _tight(engine)
    engine.infer("ds", forest, plan="auto", algorithm="auto")
    assert engine.invalidate_dataset("ds") >= 1
    assert store.decision_catalog() == {}


def test_stale_decision_swept_after_re_put(setup):
    """Regression: re-putting a dataset must not leave the old decision
    resident — even though the new SIGNATURE would miss anyway, a stale
    entry would resurface if the old shape ever came back."""
    forest, x = setup
    store = _store(x)
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
    _tight(engine)
    engine.infer("ds", forest, plan="auto", algorithm="auto")
    assert len(store.decision_catalog()) == 1
    store.put("ds", x[:128])                 # reshaped re-put
    assert store.decision_catalog() == {}
    misses0 = _counter("optimizer.decision_cache_misses")
    engine.infer("ds", forest, plan="auto", algorithm="auto")
    assert _counter("optimizer.decision_cache_misses") == misses0 + 1


def test_signature_conditions_on_tier_and_shape(setup):
    forest, x = setup
    store = _store(x)
    ds = store.get("ds")
    sig = dataset_signature(ds)
    assert sig[3] == "device"
    store.move("ds", "host")
    assert dataset_signature(store.get("ds"))[3] == "host"
    assert dataset_signature(store.get("ds")) != sig


# ---------------------------------------------------------------------------
# analytic cost model + calibrated peaks
# ---------------------------------------------------------------------------

def test_cost_model_ranks_the_paper_asymptotics():
    kw = dict(rows=4096, trees=100, depth=8, f_used=32)
    hb_flops = _forest_flop_bytes("hummingbird", **kw)[0]
    qs_flops = _forest_flop_bytes("quickscorer", **kw)[0]
    pr_flops = _forest_flop_bytes("predicated", **kw)[0]
    # hummingbird's GEMM term is 2·B·T·L·I ≫ quickscorer's bit ops ≫
    # predicated's per-level selects (the flip the optimizer exploits)
    assert hb_flops > qs_flops > pr_flops
    # everything scales ~linearly in rows and trees
    f2 = _forest_flop_bytes("predicated", rows=8192, trees=100, depth=8,
                            f_used=32)[0]
    assert f2 == pytest.approx(2 * pr_flops, rel=0.01)


def test_score_cell_orders_by_work(setup):
    forest, x = setup
    engine = ForestQueryEngine(_store(x), reuse_cache=ModelReuseCache())
    opt = _tight(engine)
    from repro.db.optimizer import _Cell
    from repro.launch.roofline import resolve_peaks
    peaks = resolve_peaks()
    kw = dict(trees=100, depth=8, f_used=32, data_nbytes=1 << 20,
              num_pages=16, page_rows=256, peaks=peaks)
    small = opt.score_cell(_Cell("predicated", "udf", "device"),
                           rows=1024, **kw)
    big = opt.score_cell(_Cell("predicated", "udf", "device"),
                         rows=65536, **kw)
    assert 0 < small < big
    # off-device tiers pay the transfer term
    host = opt.score_cell(_Cell("predicated", "udf", "host"),
                          rows=1024, **kw)
    assert host > small


def test_calibrated_peaks_measured_once_and_positive():
    from repro.launch import roofline
    p1 = roofline.calibrate_peaks()
    assert p1["measured"] is True
    for k in ("peak_flops_bf16", "hbm_bandwidth", "ici_bandwidth",
              "gather_bandwidth", "h2d_bandwidth", "dispatch_s"):
        assert p1[k] > 0
    assert roofline.calibrate_peaks() is p1          # cached
    assert roofline.resolve_peaks() is p1            # non-TPU backend
    # the production-mesh dryrun keeps modeling v5e explicitly
    from repro.launch.mesh import V5E
    assert roofline.roofline_terms(
        flops_per_chip=V5E["peak_flops_bf16"], bytes_per_chip=1.0,
        coll_bytes_per_chip=0.0)["compute_s"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# serving plane
# ---------------------------------------------------------------------------

def test_serve_register_model_resolves_auto(setup):
    from repro.serve.forest import ForestServeEngine
    forest, x = setup
    se = ForestServeEngine(buckets=(8, 32))
    _tight(se.qe)
    m = se.register_model("m", forest, algorithm="auto", plan="auto")
    assert m.algorithm in DEFAULT_ALGORITHMS
    assert m.plan in ("udf", "rel+reuse")
    # the row decision persisted under the #rows sentinel: dataset
    # sweeps never touch it, model invalidation does
    cat = se.store.decision_catalog()
    assert len(cat) == 1
    (key, _), = cat.items()
    assert key[1] == "#rows"
    assert se.store.drop_decisions(dataset="ds") == 0
    assert se.qe.invalidate(m.model_id) >= 1
    assert se.store.decision_catalog() == {}


def test_infer_rows_auto_routes_through_row_decision(setup):
    forest, x = setup
    engine = ForestQueryEngine(_store(x), reuse_cache=ModelReuseCache())
    _tight(engine)
    batch = np.zeros((16, forest.n_features), np.float32)
    res = engine.infer_rows(forest, batch, algorithm="auto", plan="auto")
    assert res.algorithm in DEFAULT_ALGORITHMS
    assert res.plan in ("udf", "rel+reuse")
    runs0 = _counter("optimizer.autotune_runs")
    engine.infer_rows(forest, batch, algorithm="auto", plan="auto")
    assert _counter("optimizer.autotune_runs") == runs0


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------

def test_hillclimb_import_is_side_effect_free():
    import repro.launch.hillclimb as hc
    assert hc.__doc__ and "hillclimb" in hc.__doc__
    assert "--xla_force_host_platform_device_count=512" not in \
        os.environ.get("XLA_FLAGS", "")


def test_decision_overrides_round_trip():
    d = Decision(algorithm="quickscorer", plan="rel+reuse", tier="device",
                 n_parts=3, batch_pages=None, predicted_s=1e-3,
                 measured_s=None, source="model")
    assert d.overrides() == dict(algorithm="quickscorer",
                                 plan="rel+reuse", n_parts=3,
                                 batch_pages=None)
