"""Prefill/decode consistency: step-by-step decode must reproduce the
full-sequence forward — the KV-cache/SSD-state correctness proof."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.dist.sharding import make_plan
from repro.models import get_bundle

KEY = jax.random.PRNGKey(0)


def _pad_kv(caches, extra=1):
    def one(path, x):
        name = str(getattr(path[-1], "key", ""))
        if name in ("k", "v"):
            pads = [(0, 0)] * x.ndim
            pads[-3] = (0, extra)
            return jnp.pad(x, pads)
        return x
    return jax.tree_util.tree_map_with_path(one, caches)


ARCHS = ["olmo-1b", "qwen2-7b", "mamba2-2.7b", "llama4-scout-17b-a16e",
         "zamba2-2.7b", "chameleon-34b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = reduced(get_config(arch))
    if cfg.num_experts:  # disable MoE capacity drops for exactness
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    bundle = get_bundle(cfg)
    params = bundle.init(cfg, KEY, dtype=jnp.float32)
    splan = make_plan(cfg, None)
    B, S = 2, 64
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = bundle.prefill(cfg, params, {"tokens": tokens}, splan)
    _, caches = bundle.prefill(cfg, params, {"tokens": tokens[:, :S - 1]},
                               splan)
    step, _ = bundle.decode(cfg, params, _pad_kv(caches),
                            tokens[:, S - 1:S], splan)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_multi_step_decode_matches_teacher_forcing():
    """Three decode steps == teacher-forced prefill at each prefix."""
    cfg = reduced(get_config("olmo-1b"))
    bundle = get_bundle(cfg)
    params = bundle.init(cfg, KEY, dtype=jnp.float32)
    splan = make_plan(cfg, None)
    B, S, EXTRA = 2, 16, 3
    tokens = jax.random.randint(KEY, (B, S + EXTRA), 0, cfg.vocab_size)
    _, caches = bundle.prefill(cfg, params, {"tokens": tokens[:, :S]},
                               splan)
    caches = _pad_kv(caches, EXTRA)
    for i in range(EXTRA):
        want, _ = bundle.prefill(cfg, params,
                                 {"tokens": tokens[:, :S + i + 1]}, splan)
        got, caches = bundle.decode(cfg, params, caches,
                                    tokens[:, S + i:S + i + 1], splan)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4, err_msg=f"step {i}")


def test_windowed_decode_masks_out_of_chunk():
    """iRoPE chunked-local layers must not attend across window blocks."""
    cfg = dataclasses.replace(reduced(get_config("llama4-scout-17b-a16e")),
                              attn_window=16, capacity_factor=8.0)
    bundle = get_bundle(cfg)
    params = bundle.init(cfg, KEY, dtype=jnp.float32)
    splan = make_plan(cfg, None)
    B, S = 1, 48  # 3 window blocks
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _ = bundle.prefill(cfg, params, {"tokens": tokens}, splan)
    _, caches = bundle.prefill(cfg, params, {"tokens": tokens[:, :S - 1]},
                               splan)
    step, _ = bundle.decode(cfg, params, _pad_kv(caches),
                            tokens[:, S - 1:S], splan)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-4, atol=1e-4)
