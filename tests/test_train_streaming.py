"""In-database streamed training == resident training, bit for bit.

The contract under test (``db/train.py``, ``docs/training.md``): given
identical bin edges, ``ForestQueryEngine.train`` — which streams every
pass (sketch, bin ingest, per-level histogram scans) through the tiered
store and ``StreamingScanExecutor`` — produces a forest BIT-identical to
the resident ``core.train.train_forest``, across {host, disk} tier x
{dense, CSR} format x {mesh, mesh-less} x all three model families.
Plus: the scans obey the executor's telemetry contract (<= 2 live device
page buffers, real streaming), the trained model lands in the store's
model catalog / serving plane, and re-training sweeps the compiled-plan
cache AND the optimizer decision catalog (the stale-decision-after-
retrain regression).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.train import TrainConfig, quantile_bin_edges, train_forest
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore

NDEV = len(jax.devices())
PAGE = 64
N, F = 700, 9


def _data(seed=0, nan_frac=0.1):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(N, F)).astype(np.float32)
    w = rng.normal(size=F).astype(np.float32)
    y = (np.nan_to_num(x) @ w > 0).astype(np.float32)
    if nan_frac:
        x[rng.random(x.shape) < nan_frac] = np.nan
    return x, y


def _store(tier, *, mesh=None, fmt="dense", data=None):
    """A store whose budgets force ``tier`` for the test dataset."""
    x, y = data if data is not None else _data()
    kw = dict(default_page_rows=PAGE, device_budget_bytes=16 << 10)
    if tier == "disk":
        kw["host_budget_bytes"] = 8 << 10
    store = TensorBlockStore(mesh, **kw)
    if fmt == "csr":
        store.put_sparse("d", x, labels=y, tier="auto")
    else:
        store.put("d", x, labels=y, tier="auto")
    assert store.get("d").tier == tier
    return store, x, y


def assert_forests_identical(a, b, msg=""):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for i, (u, v) in enumerate(zip(la, lb)):
        ru, rv = np.asarray(u), np.asarray(v)
        assert ru.dtype == rv.dtype, (msg, i, ru.dtype, rv.dtype)
        np.testing.assert_array_equal(ru, rv, err_msg=f"{msg} leaf {i}")


# ---------------------------------------------------------------------------
# the bit-identity matrix: tier x format x model family (mesh-less)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model_type", ["randomforest", "xgboost",
                                        "lightgbm"])
@pytest.mark.parametrize("tier", ["host", "disk"])
def test_streamed_matches_resident_dense(tier, model_type):
    store, x, y = _store(tier)
    cfg = TrainConfig(model_type=model_type, num_trees=4, max_depth=3,
                      num_bins=16, colsample=0.6, seed=3)
    edges = quantile_bin_edges(x, cfg.num_bins)
    ref = train_forest(x, y, cfg, edges=edges)
    res = ForestQueryEngine(store).train("d", cfg, edges=edges)
    assert_forests_identical(ref, res.forest, f"{tier}/{model_type}")
    assert res.tier == tier and res.storage_format == "dense"
    assert res.materialized_full_x is False


@pytest.mark.parametrize("model_type", ["randomforest", "xgboost",
                                        "lightgbm"])
@pytest.mark.parametrize("tier", ["host", "disk"])
def test_streamed_matches_resident_csr(tier, model_type):
    """CSR pages densify per batch with NaN fill, so the parity target is
    resident training on the SAME matrix (missing = NaN = MISSING bin)."""
    store, x, y = _store(tier, fmt="csr")
    cfg = TrainConfig(model_type=model_type, num_trees=3, max_depth=3,
                      num_bins=16, seed=5)
    edges = quantile_bin_edges(x, cfg.num_bins)
    ref = train_forest(x, y, cfg, edges=edges)
    res = ForestQueryEngine(store).train("d", cfg, edges=edges)
    assert_forests_identical(ref, res.forest, f"csr/{tier}/{model_type}")
    assert res.storage_format == "csr"


def test_batch_geometry_never_changes_the_forest():
    """Any batch size / prefetch depth — same bits (the np.add.at
    canonical-accumulation argument in core/train's module doc)."""
    store, x, y = _store("host")
    cfg = TrainConfig(num_trees=3, max_depth=3, num_bins=16)
    edges = quantile_bin_edges(x, cfg.num_bins)
    eng = ForestQueryEngine(store)
    base = eng.train("d", cfg, edges=edges, batch_pages=1).forest
    for bp, depth in ((2, 2), (3, 1), (7, 2)):
        got = eng.train("d", cfg, edges=edges, batch_pages=bp,
                        prefetch_depth=depth).forest
        assert_forests_identical(base, got, f"batch_pages={bp}")


# ---------------------------------------------------------------------------
# mesh x mesh-less (runs under the CI multi-device topology)
# ---------------------------------------------------------------------------


@pytest.mark.skipif(
    NDEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
def test_streamed_mesh_matches_meshless():
    x, y = _data(seed=7)
    cfg = TrainConfig(num_trees=4, max_depth=3, num_bins=16)
    edges = quantile_bin_edges(x, cfg.num_bins)
    ref = train_forest(x, y, cfg, edges=edges)
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    store, _, _ = _store("host", mesh=mesh, data=(x, y))
    eng = ForestQueryEngine(store)
    res = eng.train("d", cfg, edges=edges)
    assert_forests_identical(ref, res.forest, "mesh")
    # tree blocks land sharded over the model axis (ForestShardingPlan)
    sh = res.forest.threshold.sharding
    assert getattr(sh, "spec", None) is not None
    assert tuple(sh.spec) == ("model",)


# ---------------------------------------------------------------------------
# ScanStats: training scans stream under the same telemetry contract
# ---------------------------------------------------------------------------


def test_training_scan_stats():
    store, x, y = _store("disk")
    cfg = TrainConfig(num_trees=2, max_depth=3, num_bins=16)
    # explicit batch_pages: the uint8 bins relation is 4x smaller than
    # the f32 source, so the budget-driven auto size would (correctly)
    # scan it in one batch at this toy scale — force real streaming
    res = ForestQueryEngine(store).train("d", cfg, sketch_rows=128,
                                         batch_pages=4)
    # sketch + bin ingest + num_trees * (max_depth + 1) level scans
    assert res.num_scans == 2 + cfg.num_trees * (cfg.max_depth + 1)
    assert len(res.scan_stats) == res.num_scans
    src_nbytes = store.get("d").nbytes
    for st in res.scan_stats:
        assert st.batches > 1, "training scan did not stream"
        assert st.max_in_flight <= 2, "page-buffer bound violated"
        assert st.bytes_streamed > 0
        # no batch ever approached a whole-matrix transfer
        assert st.bytes_streamed / st.batches < src_nbytes
    assert res.scan_stats[0].tier == "disk"       # sketch reads the source
    assert res.scan_stats[-1].tier == "disk"      # bins inherit the tier
    assert 0 < res.sketch_rows_used <= 128


def test_bins_relation_registered_in_store():
    store, x, y = _store("host")
    cfg = TrainConfig(num_trees=2, max_depth=2, num_bins=16)
    edges = quantile_bin_edges(x, cfg.num_bins)
    res = ForestQueryEngine(store).train("d", cfg, edges=edges)
    assert res.bins_dataset == "d::bins"
    bd = store.get("d::bins")
    assert bd.data.dtype == np.uint8
    assert bd.tier == "host" and bd.page_rows == PAGE
    assert bd.num_rows == N
    host = np.asarray(bd.data)
    # real rows carry valid bins; page-padding tail is the MISSING slot
    assert host[:N].max() <= cfg.num_bins
    if host.shape[0] > N:
        assert (host[N:] == cfg.num_bins).all()


def test_num_bins_must_fit_uint8():
    store, x, y = _store("host")
    with pytest.raises(ValueError, match="uint8"):
        ForestQueryEngine(store).train(
            "d", TrainConfig(num_bins=256, num_trees=1))


def test_unlabeled_dataset_refused():
    store = TensorBlockStore(default_page_rows=PAGE)
    store.put("u", np.zeros((8, 2), np.float32))
    with pytest.raises(ValueError, match="labels"):
        ForestQueryEngine(store).train("u", TrainConfig(num_trees=1))


# ---------------------------------------------------------------------------
# lifecycle: catalog landing, serving plane, observability
# ---------------------------------------------------------------------------


def test_trained_model_lands_in_catalog_and_serves():
    store, x, y = _store("host")
    cfg = TrainConfig(num_trees=3, max_depth=3, num_bins=16)
    eng = ForestQueryEngine(store)
    res = eng.train("d", cfg)
    assert store.get_model("d:model") is res.forest
    meta = store.model_catalog()["d:model"]
    assert meta["fingerprint"] == res.fingerprint
    assert meta["trained_on"] == "d" and meta["streamed"] is True
    # the catalog model runs through the normal inference plans
    q = eng.infer("d", store.get_model("d:model"), plan="udf",
                  model_id=res.fingerprint)
    assert np.isfinite(np.asarray(q.predictions)).all()
    # ... and through the serving plane, straight from the catalog
    from repro.serve.forest import ForestServeEngine
    serve = ForestServeEngine(store, query_engine=eng)
    m = serve.register_from_catalog("d:model", warmup=False)
    out = serve.predict("d:model", x[:8])
    assert out.shape == (8,) and np.isfinite(out).all()
    assert m.model_id == res.fingerprint


def test_train_metrics_and_spans():
    from repro.obs import METRICS, TRACER
    store, x, y = _store("host")
    cfg = TrainConfig(num_trees=2, max_depth=2, num_bins=16)
    runs0 = METRICS.counter("train.runs").value
    trees0 = METRICS.counter("train.trees_grown").value
    scans0 = METRICS.counter("train.level_scans").value
    TRACER.enable()
    try:
        mark = TRACER.mark()
        res = ForestQueryEngine(store).train("d", cfg, sketch_rows=128)
        names = {s.name for s in TRACER.finished(mark)}
    finally:
        TRACER.disable()
    assert {"train.forest", "train.sketch", "train.bin_ingest",
            "train.level"} <= names
    assert METRICS.counter("train.runs").value == runs0 + 1
    assert METRICS.counter("train.trees_grown").value \
        == trees0 + cfg.num_trees
    assert METRICS.counter("train.level_scans").value \
        == scans0 + cfg.num_trees * (cfg.max_depth + 1)
    assert res.wall_s > 0


# ---------------------------------------------------------------------------
# the stale-decision-after-retrain regression
# ---------------------------------------------------------------------------


def test_retrain_sweeps_plans_and_decisions():
    """Re-training under the same model name must sweep BOTH the compiled-
    plan cache and the optimizer decision catalog for the replaced
    fingerprint — a retrained model must never serve the old verdict."""
    from repro.core.reuse import ModelReuseCache
    store, x, y = _store("host")
    eng = ForestQueryEngine(store, reuse_cache=ModelReuseCache(8),
                            plan_cache=ModelReuseCache(8))
    cfg = TrainConfig(num_trees=2, max_depth=2, num_bins=16, seed=1)
    r1 = eng.train("d", cfg)
    fp1 = r1.fingerprint
    m1 = store.get_model("d:model")
    # compile a plan and persist an optimizer decision for fp1
    eng.infer("d", m1, plan="udf", model_id=fp1)
    eng.infer("d", m1, plan="auto", algorithm="predicated", model_id=fp1)
    assert any(k[1] == fp1 for k in eng.plan_cache._entries)
    assert any(k[0] == fp1 for k in store.decision_catalog())
    # retrain (different config -> different forest) under the same name
    r2 = eng.train("d", TrainConfig(num_trees=3, max_depth=2,
                                    num_bins=16, seed=2))
    assert r2.fingerprint != fp1
    assert store.get_model("d:model") is r2.forest
    assert not any(k[1] == fp1 for k in eng.plan_cache._entries), \
        "stale compiled plan survived the retrain"
    assert not any(k[0] == fp1 for k in store.decision_catalog()), \
        "stale optimizer decision survived the retrain"
    # a fresh auto query decides (and serves) the NEW model
    q = eng.infer("d", r2.forest, plan="auto", algorithm="predicated",
                  model_id=r2.fingerprint)
    assert any(k[0] == r2.fingerprint for k in store.decision_catalog())
    assert np.isfinite(np.asarray(q.predictions)).all()


def test_put_model_same_forest_does_not_sweep():
    """Re-pinning the SAME forest object (serve re-registration) must not
    invalidate its own plans/decisions."""
    store, x, y = _store("host")
    eng = ForestQueryEngine(store)
    r = eng.train("d", TrainConfig(num_trees=2, max_depth=2, num_bins=16))
    eng.infer("d", r.forest, plan="auto", algorithm="predicated",
              model_id=r.fingerprint)
    n_before = len(store.decision_catalog())
    store.put_model("d:model", r.forest, fingerprint=r.fingerprint)
    assert len(store.decision_catalog()) == n_before
