"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; only launch/dryrun.py forces 512 placeholder devices."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_forest_arrays(rng, *, T=7, depth=4, F=11, seed=None):
    """Random dense complete forest arrays (valid for every backend)."""
    r = np.random.default_rng(seed) if seed is not None else rng
    I, L = (1 << depth) - 1, 1 << depth
    feature = r.integers(0, F, (T, I)).astype(np.int32)
    threshold = r.normal(size=(T, I)).astype(np.float32)
    default_left = r.random((T, I)) < 0.5
    leaf_value = r.normal(size=(T, L)).astype(np.float32)
    return feature, threshold, default_left, leaf_value


@pytest.fixture
def random_forest(rng):
    from repro.core.forest import make_forest

    feature, threshold, default_left, leaf_value = \
        random_forest_arrays(rng, seed=42)
    return make_forest(feature, threshold, leaf_value,
                       default_left=default_left, n_features=11,
                       model_type="xgboost")
