"""Fused (in-kernel aggregation) Pallas backends vs the unfused reference.

The contract: ``*_pallas_fused(forest, x)`` == ``aggregate_raw(
predict_raw_pallas(forest, x))`` for every algorithm, with tree/sample
padding never perturbing SUM or MEAN, and with NO [B, T] score matrix in
the traced program (checked on the jaxpr, not narrated).
"""

import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.forest import make_forest, pad_trees
from repro.core.postprocess import aggregate_raw, postprocess, predict_proba
from repro.kernels.ops import (FUSED_KERNEL_ALGORITHMS, KERNEL_ALGORITHMS,
                               predict_sum_pallas)

from conftest import random_forest_arrays

BASES = ("predicated", "hummingbird", "quickscorer")

SHAPE_GRID = [
    # (B, T, depth, F, block_b, block_t)
    (8, 4, 3, 8, 8, 4),
    (16, 5, 4, 11, 8, 2),        # tree padding (5 -> 6)
    (7, 3, 2, 5, 4, 2),          # padding on both axes
    (24, 10, 8, 30, 8, 2),       # paper's depth-8 regime
    (9, 13, 5, 7, 8, 8),         # B and T both non-multiples
]


def _forest_and_x(rng, B, T, depth, F, seed, *, nan_frac=0.0,
                  integer_leaves=False):
    fe, th, dl, lv = random_forest_arrays(rng, T=T, depth=depth, F=F,
                                          seed=seed)
    if integer_leaves:
        r = np.random.default_rng(seed)
        lv = r.integers(-8, 9, lv.shape).astype(np.float32)
    forest = make_forest(fe, th, lv, default_left=dl, n_features=F)
    r = np.random.default_rng(seed + 1)
    x = r.normal(size=(B, F)).astype(np.float32)
    if nan_frac:
        x[r.random(x.shape) < nan_frac] = np.nan
    return forest, jnp.asarray(x)


@pytest.mark.parametrize("base", BASES)
@pytest.mark.parametrize("shape", SHAPE_GRID,
                         ids=[f"B{b}T{t}d{d}F{f}" for b, t, d, f, _, _
                              in SHAPE_GRID])
def test_fused_matches_unfused(rng, base, shape):
    B, T, depth, F, bb, bt = shape
    # crc32, not hash(): str hashing is PYTHONHASHSEED-randomized, and a
    # per-process seed would make any tolerance-marginal failure
    # unreproducible
    forest, x = _forest_and_x(rng, B, T, depth, F,
                              seed=zlib.crc32(f"{base}{shape}".encode())
                              % 9973)
    want = aggregate_raw(KERNEL_ALGORITHMS[base + "_pallas"](
        forest, x, block_b=bb, block_t=bt, interpret=True))
    got = FUSED_KERNEL_ALGORITHMS[base + "_pallas_fused"](
        forest, x, block_b=bb, block_t=bt, interpret=True)
    assert got.shape == (B,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("base", BASES)
def test_fused_bit_identical_on_exact_sums(rng, base):
    """Small-integer leaf values make every partial sum exact in f32, so
    the fused accumulation order must reproduce the unfused reduction
    BIT-identically (padding trees included: 5 trees -> block_t 4)."""
    forest, x = _forest_and_x(rng, 16, 5, 4, 9, seed=123,
                              integer_leaves=True)
    want = aggregate_raw(KERNEL_ALGORITHMS[base + "_pallas"](
        forest, x, block_b=8, block_t=4, interpret=True))
    got = FUSED_KERNEL_ALGORITHMS[base + "_pallas_fused"](
        forest, x, block_b=8, block_t=4, interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(want)), (
        np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("base", BASES)
def test_fused_nan_features(rng, base):
    forest, x = _forest_and_x(rng, 12, 4, 4, 9, seed=31, nan_frac=0.25)
    want = aggregate_raw(KERNEL_ALGORITHMS[base + "_pallas"](
        forest, x, block_b=4, block_t=2, interpret=True))
    got = FUSED_KERNEL_ALGORITHMS[base + "_pallas_fused"](
        forest, x, block_b=4, block_t=2, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("base", BASES)
def test_fused_tree_padding_preserves_mean(rng, base):
    """MEAN semantics: padding 5 trees to a block multiple must not change
    the randomforest mean (zero-leaf pads + division by the TRUE count)."""
    fe, th, dl, lv = random_forest_arrays(rng, T=5, depth=3, F=7, seed=77)
    lv = np.abs(lv) / (np.abs(lv).max() + 1.0)   # valid probabilities
    forest = make_forest(fe, th, lv, default_left=dl, n_features=7,
                         model_type="randomforest")
    r = np.random.default_rng(7)
    x = jnp.asarray(r.normal(size=(6, 7)).astype(np.float32))
    summed = FUSED_KERNEL_ALGORITHMS[base + "_pallas_fused"](
        forest, x, block_b=8, block_t=4, interpret=True)
    got = postprocess(summed, model_type="randomforest",
                      task="classification", num_trees=5)
    want = predict_proba(forest, x, algorithm="predicated")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_fused_trace_has_no_bt_matrix(rng):
    """The fused program must not contain ANY [B_padded, T_padded]
    intermediate, while the unfused one does — asserted on the jaxpr."""
    B, T, bb, bt = 16, 8, 8, 4
    forest, x = _forest_and_x(rng, B, T, 4, 9, seed=5)
    Bp, Tp = B, T                      # already block multiples

    def shapes(fn):
        jaxpr = jax.make_jaxpr(fn)(x)
        out = set()
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                out.add(tuple(getattr(v.aval, "shape", ())))
        return out

    unfused = shapes(lambda xx: KERNEL_ALGORITHMS["hummingbird_pallas"](
        forest, xx, block_b=bb, block_t=bt, interpret=True))
    fused = shapes(lambda xx: FUSED_KERNEL_ALGORITHMS[
        "hummingbird_pallas_fused"](forest, xx, block_b=bb, block_t=bt,
                                    interpret=True))
    assert (Bp, Tp) in unfused          # sanity: the reference materializes
    assert (Bp, Tp) not in fused
    assert (Bp, 1) in fused


def test_predict_sum_pallas_dispatch(rng):
    forest, x = _forest_and_x(rng, 8, 4, 3, 6, seed=11)
    got = predict_sum_pallas(forest, x, "quickscorer_pallas_fused",
                             block_b=8, block_t=4, interpret=True)
    want = aggregate_raw(KERNEL_ALGORITHMS["quickscorer_pallas"](
        forest, x, block_b=8, block_t=4, interpret=True))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    with pytest.raises(ValueError):
        predict_sum_pallas(forest, x, "nope")


@pytest.mark.parametrize("base", BASES)
def test_fused_default_blocks_and_padding(rng, base):
    """No explicit blocks: the heuristics pick them, padding both axes."""
    forest, x = _forest_and_x(rng, 11, 6, 4, 13, seed=900)
    want = aggregate_raw(KERNEL_ALGORITHMS[base + "_pallas"](
        forest, x, interpret=True))
    got = FUSED_KERNEL_ALGORITHMS[base + "_pallas_fused"](
        forest, x, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
