"""Sparse data plane: CSR page store, gather prepass, compaction, plans.

The subsystem's contract, as testable invariants:
  * CSR pages round-trip losslessly (NaN = missing, explicit zeros kept)
    and keep the dense store's page<->batch determinism;
  * used-feature compaction preserves predictions exactly
    (predict(forest, x) == predict(compact, x[:, gather_idx]));
  * the LIBSVM->CSR loader reports the split parse/convert/transfer
    timings without ever densifying;
  * CSR and dense plans agree on predictions for every physical plan,
    and their compiled-plan cache entries never collide;
  * at criteo-scale F the sparse path's traced program contains NO
    intermediate with a full-F trailing axis — the [BT, I, F] one-hot
    is gone, not just modeled away (checked on the jaxpr, recursively
    through the pallas kernel's sub-jaxpr).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.forest import (compact_forest, make_forest,
                               used_feature_counts)
from repro.core.postprocess import predict_proba
from repro.core.reuse import ModelReuseCache
from repro.core.train import TrainConfig, train_forest
from repro.db.loader import (load_libsvm_csr_external, synth_dataset,
                             write_libsvm)
from repro.db.query import ForestQueryEngine
from repro.db.sparse import csr_pages_from_dense, densify_csr
from repro.db.store import TensorBlockStore
from repro.kernels.gather import (csr_block_to_dense, gather_columns,
                                  gather_inverse_map)
from repro.kernels.ops import FUSED_KERNEL_ALGORITHMS, KERNEL_ALGORITHMS

from conftest import random_forest_arrays


def _nan_heavy(n=300, F=24, nan_frac=0.7, seed=0):
    """Bosch-like block: wide-ish, mostly missing, some exact zeros."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, F)).astype(np.float32)
    x[rng.random((n, F)) < 0.05] = 0.0          # explicit zeros are data
    x[rng.random((n, F)) < nan_frac] = np.nan
    return x


@pytest.fixture(scope="module")
def sparse_setup():
    x = _nan_heavy()
    rng = np.random.default_rng(1)
    y = (np.nan_to_num(x) @ rng.normal(size=x.shape[1]).astype(np.float32)
         > 0).astype(np.float32)
    forest = train_forest(np.nan_to_num(x), y,
                          TrainConfig(model_type="xgboost", num_trees=10,
                                      max_depth=4))
    store = TensorBlockStore(default_page_rows=64)
    store.put("d", x)
    store.put_sparse("s", x)
    return store, forest, x


# ---------------------------------------------------------------------------
# storage: CSR pages
# ---------------------------------------------------------------------------


def test_csr_pages_roundtrip(sparse_setup):
    store, _, x = sparse_setup
    ds = store.get("s")
    assert ds.storage_format == "csr"
    dense = densify_csr(np.asarray(ds.pages.indptr),
                        np.asarray(ds.pages.indices),
                        np.asarray(ds.pages.values), x.shape[1])
    got = dense[: x.shape[0]]
    # missing stays missing, present values (zeros included) exact
    assert np.array_equal(np.isnan(got), np.isnan(x))
    m = ~np.isnan(x)
    np.testing.assert_array_equal(got[m], x[m])
    # padding rows are fully missing (the dense plane's NaN rows)
    assert np.isnan(dense[x.shape[0]:]).all()


def test_csr_page_batch_determinism(sparse_setup):
    store, _, _ = sparse_setup
    ds = store.get("s")
    blocks = list(ds.batches(2))
    assert len(blocks) == -(-ds.num_pages // 2)
    # every block has the SAME array shapes (one jit signature per batching)
    shapes = {(b.indptr.shape, b.indices.shape, b.values.shape)
              for _, b in blocks[:-1]}
    assert len(shapes) == 1
    # batch k always covers the same pages: re-iteration is bit-identical
    again = list(ds.batches(2))
    for (_, a), (_, b) in zip(blocks, again):
        np.testing.assert_array_equal(np.asarray(a.indices),
                                      np.asarray(b.indices))


def test_catalog_tags_format(sparse_setup):
    store, _, _ = sparse_setup
    cat = store.catalog()
    assert cat["d"]["format"] == "dense"
    assert cat["s"]["format"] == "csr"
    assert cat["s"]["nnz"] > 0
    # CSR pages genuinely compress the 70%-missing block
    assert cat["s"]["bytes"] < cat["d"]["bytes"]


# ---------------------------------------------------------------------------
# model half: used-feature compaction
# ---------------------------------------------------------------------------


def test_compact_forest_invariants(rng):
    F = 10_000
    fe, th, dl, lv = random_forest_arrays(rng, T=6, depth=4, F=F, seed=7)
    forest = make_forest(fe, th, lv, default_left=dl, n_features=F)
    counts = used_feature_counts(forest)
    assert (counts <= forest.num_internal).all()
    compact, gidx = compact_forest(forest)
    f_used = np.unique(gidx).size
    # sorted, duplicate-free over the real slots; padding repeats gidx[0]
    real = gidx[:f_used]
    assert np.array_equal(real, np.unique(real))
    assert (gidx[f_used:] == gidx[0]).all()
    assert compact.n_features == gidx.size
    assert compact.n_features % 8 == 0
    # every remapped split points inside the compact space
    assert int(np.asarray(compact.feature).max()) < compact.n_features


def test_compact_forest_prediction_parity(rng):
    F = 2_000
    fe, th, dl, lv = random_forest_arrays(rng, T=5, depth=4, F=F, seed=11)
    forest = make_forest(fe, th, lv, default_left=dl, n_features=F)
    compact, gidx = compact_forest(forest)
    r = np.random.default_rng(2)
    x = r.normal(size=(32, F)).astype(np.float32)
    x[r.random(x.shape) < 0.5] = np.nan
    for algo in ("predicated", "hummingbird", "quickscorer"):
        want = predict_proba(forest, jnp.asarray(x), algorithm=algo)
        got = predict_proba(compact, gather_columns(jnp.asarray(x), gidx),
                            algorithm=algo)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# loader: LIBSVM -> CSR, no densify
# ---------------------------------------------------------------------------


def test_libsvm_csr_loader(tmp_path):
    x, y = synth_dataset("bosch", max_rows=40)
    p = str(tmp_path / "d.svm")
    write_libsvm(p, x, y)
    pages, labels, timing = load_libsvm_csr_external(p, x.shape[1],
                                                     page_rows=16)
    # the full LoadTiming breakdown is populated (same contract as every
    # other external loader)
    assert timing.parse_s > 0 and timing.convert_s > 0
    assert timing.transfer_s > 0
    assert timing.total_s >= (timing.parse_s + timing.convert_s
                              + timing.transfer_s) * 0.99
    np.testing.assert_allclose(labels, y)
    dense = densify_csr(np.asarray(pages.indptr), np.asarray(pages.indices),
                        np.asarray(pages.values), x.shape[1])[:40]
    mask = ~np.isnan(x) & (x != 0.0)      # libsvm files drop zeros
    np.testing.assert_allclose(dense[mask], x[mask], rtol=1e-4, atol=1e-5)
    assert np.isnan(dense[~mask]).all()


def test_libsvm_csr_loader_feeds_store(tmp_path):
    x, y = synth_dataset("bosch", max_rows=30)
    p = str(tmp_path / "d.svm")
    write_libsvm(p, x, y)
    pages, labels, _ = load_libsvm_csr_external(p, x.shape[1], page_rows=16)
    store = TensorBlockStore(default_page_rows=16)
    ds = store.put_sparse("ext", pages=pages, num_rows=len(labels),
                          labels=labels)
    assert ds.storage_format == "csr" and ds.num_rows == 30


# ---------------------------------------------------------------------------
# query plans: CSR <-> dense parity, cache key separation
# ---------------------------------------------------------------------------


PLANS = ("udf", "rel", "rel+reuse")


@pytest.mark.parametrize("plan", PLANS)
def test_csr_dense_plan_parity(sparse_setup, plan):
    """Same model, same rows: the CSR plane (compaction + gather prepass)
    must reproduce the dense plane bit-for-allclose on NaN-heavy data."""
    store, forest, _ = sparse_setup
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                               plan_cache=ModelReuseCache())
    algo = "hummingbird_pallas_fused"
    rd = engine.infer("d", forest, algorithm=algo, plan=plan)
    rs = engine.infer("s", forest, algorithm=algo, plan=plan)
    assert rd.storage_format == "dense" and rs.storage_format == "csr"
    assert rd.num_stages == rs.num_stages
    np.testing.assert_allclose(np.asarray(rs.predictions),
                               np.asarray(rd.predictions),
                               rtol=1e-5, atol=1e-6)


def test_plan_cache_separates_formats(sparse_setup):
    """Dense and CSR plans over the SAME model are different executables:
    neither may serve the other's cache entry."""
    store, forest, _ = sparse_setup
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                               plan_cache=ModelReuseCache())
    kw = dict(algorithm="predicated", plan="udf", model_id="fmt-sep")
    r_d1 = engine.infer("d", forest, **kw)
    r_s1 = engine.infer("s", forest, **kw)
    assert not r_d1.plan_reuse_hit and not r_s1.plan_reuse_hit
    # steady state: each format hits its OWN entry
    r_d2 = engine.infer("d", forest, **kw)
    r_s2 = engine.infer("s", forest, **kw)
    assert r_d2.plan_reuse_hit and r_s2.plan_reuse_hit
    assert len(engine.plan_cache) == 2


def test_rel_reuse_model_cache_separates_formats(sparse_setup):
    """The partition-model cache keys on format too: the CSR plane's
    materialization is the COMPACTED forest, not the full-F one."""
    store, forest, _ = sparse_setup
    cache = ModelReuseCache()
    engine = ForestQueryEngine(store, reuse_cache=cache,
                               plan_cache=ModelReuseCache())
    kw = dict(algorithm="predicated", plan="rel+reuse", model_id="m-fmt")
    engine.infer("d", forest, **kw)
    r2 = engine.infer("s", forest, **kw)
    assert not r2.reuse_hit          # csr materialization is distinct
    assert cache.stats.misses == 2
    r3 = engine.infer("s", forest, **kw)
    assert r3.reuse_hit


# ---------------------------------------------------------------------------
# the acceptance check: no [BT, I, F] one-hot at criteo-scale F
# ---------------------------------------------------------------------------


def _all_shapes(jaxpr):
    """Every intermediate's shape, recursing through sub-jaxprs (the
    pallas kernel body lives in the call's params)."""
    out = set()
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            out.add(tuple(getattr(v.aval, "shape", ())))
        for p in eqn.params.values():
            inner = getattr(p, "jaxpr", p)
            if hasattr(inner, "eqns"):
                out |= _all_shapes(inner)
    return out


def test_sparse_path_has_no_full_f_onehot(rng):
    """F=10k: the dense kernel path materializes a [BT, I, F] predicate
    one-hot in-kernel; the sparse path (gather prepass + compact forest)
    must contain NO >=2-D intermediate with a full-F trailing axis
    anywhere in its traced program."""
    F, B, page_rows = 10_000, 16, 8
    fe, th, dl, lv = random_forest_arrays(rng, T=4, depth=3, F=F, seed=13)
    forest = make_forest(fe, th, lv, default_left=dl, n_features=F)
    compact, gidx = compact_forest(forest)
    inv = jnp.asarray(gather_inverse_map(gidx, F))
    f_used = int(gidx.size)
    kfn = FUSED_KERNEL_ALGORITHMS["predicated_pallas_fused"]

    x = _nan_heavy(B, F, nan_frac=0.9, seed=3)
    pages = csr_pages_from_dense(x, page_rows=page_rows)

    def sparse_path(pg):
        xc = csr_block_to_dense(pg, inv, f_used)
        return kfn(compact, xc, block_b=8, block_t=4, interpret=True)

    def dense_path(xx):
        return kfn(forest, xx, block_b=8, block_t=4, interpret=True)

    sparse_shapes = _all_shapes(jax.make_jaxpr(sparse_path)(pages).jaxpr)
    dense_shapes = _all_shapes(
        jax.make_jaxpr(dense_path)(jnp.asarray(x)).jaxpr)

    wide = [s for s in sparse_shapes if len(s) >= 2 and s[-1] == F]
    assert not wide, f"full-F intermediates on the sparse path: {wide}"
    # sanity: the dense path DOES build the [BT, I, F] one-hot
    assert any(len(s) == 3 and s[-1] == F for s in dense_shapes)
    # and the compact one-hot is the expected [BT, I, F_used]
    assert any(len(s) == 3 and s[-1] == f_used for s in sparse_shapes)

    # parity on the same rows, so the jaxpr claim is about a CORRECT path
    want = np.asarray(dense_path(jnp.asarray(x)))
    got = np.asarray(sparse_path(pages))[:B]
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_criteo_scale_end_to_end(rng):
    """The acceptance run: F=10k, used features per tree <= 64, end to
    end through the CSR store + gather prepass, with CSR/dense parity."""
    F = 10_000
    fe, th, dl, lv = random_forest_arrays(rng, T=8, depth=6, F=F, seed=17)
    forest = make_forest(fe, th, lv, default_left=dl, n_features=F)
    assert used_feature_counts(forest).max() <= 64
    x = _nan_heavy(96, F, nan_frac=0.96, seed=5)      # criteo density
    store = TensorBlockStore(default_page_rows=32)
    store.put("wide-d", x)
    store.put_sparse("wide-s", x)
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                               plan_cache=ModelReuseCache())
    algo = "predicated_pallas_fused"
    rd = engine.infer("wide-d", forest, algorithm=algo, plan="udf")
    rs = engine.infer("wide-s", forest, algorithm=algo, plan="udf")
    assert rs.storage_format == "csr"
    np.testing.assert_allclose(np.asarray(rs.predictions),
                               np.asarray(rd.predictions),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# bf16 tree tiles (satellite: kernel-side acc_dtype plumb)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("base", ["predicated", "hummingbird",
                                  "quickscorer"])
def test_fused_bf16_tree_tiles(rng, base):
    """bf16-staged thresholds/leaves with f32 accumulation must match the
    unfused reference over a bf16-quantized forest, and keep f32 output."""
    fe, th, dl, lv = random_forest_arrays(rng, T=5, depth=4, F=11, seed=21)
    forest = make_forest(fe, th, lv, default_left=dl, n_features=11)
    x = jnp.asarray(np.random.default_rng(4).normal(
        size=(16, 11)).astype(np.float32))
    got = FUSED_KERNEL_ALGORITHMS[base + "_pallas_fused"](
        forest, x, block_b=8, block_t=2, interpret=True,
        tree_dtype=jnp.bfloat16)
    assert got.dtype == jnp.float32
    qf = forest.astype(jnp.bfloat16).astype(jnp.float32)
    want = np.sum(np.asarray(KERNEL_ALGORITHMS[base + "_pallas"](
        qf, x, block_b=8, block_t=2, interpret=True)), axis=1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
