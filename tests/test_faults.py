"""Fault matrix for the reliability layer (``db/faults.py``).

The acceptance contract under test: every injected fault site x plan
(udf/rel) x storage format (dense/CSR) x tier (device/host/disk) —
mesh-less here, on a (data x model) mesh in the guarded section — either

  * RECOVERS with BIT-IDENTICAL predictions (transient faults inside the
    retry budget, and every degradation ladder: mid-scan sync-drain
    fallback, halved-batch resubmission, disk-read re-enqueue), with the
    recovery visible in ``ScanStats`` (``retries`` / ``faults_injected``
    / ``degraded_to_sync`` / ``batch_resubmits``), or
  * raises a STRUCTURED ``ScanFault`` (site, attempts, rows completed,
    cause) when the ladder is exhausted, or
  * returns a PARTIAL ``QueryResult`` whose ``degraded`` report is exact
    (``deadline_s``: scored rows bit-match the reference, missing rows
    are NaN, the row mask says which is which) —

never a silent wrong answer, never a hang.  ``store.move``'s rollback
(no orphaned spill files, no corrupted per-tier accounting) and the
injector/retry primitives themselves are covered at the bottom.
See ``docs/reliability.md``.
"""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.reuse import ModelReuseCache
from repro.core.train import TrainConfig, train_forest
from repro.db import store as store_mod
from repro.db.executor import StreamingScanExecutor
from repro.db.faults import (FAULT_SITES, Deadline, DeadlineExceeded,
                             FaultInjector, InjectedFault, RetryPolicy,
                             ScanFault)
from repro.db.operators import Operator, split_into_stages
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore

N, F, T, PAGE = 384, 16, 24, 32
FUSED = "predicated_pallas_fused"
SPARSE_ALGO = "hummingbird_pallas_fused"
TIERS = ("device", "host", "disk")

#: retry semantics identical to the default, backoff sleeps zeroed so the
#: exhaustion tests (3 attempts x every batch) stay fast
FAST = RetryPolicy(backoff_base_s=0.0, max_backoff_s=0.0)


@pytest.fixture(scope="module")
def env():
    """Shared store (every format x tier), engine, and a lazy reference
    cache — fault runs must bit-match the clean run of the same query."""
    rng = np.random.default_rng(11)
    x = rng.normal(size=(N, F)).astype(np.float32)
    w = rng.normal(size=F).astype(np.float32)
    y = (x @ w > 0).astype(np.float32)
    forest = train_forest(x, y, TrainConfig(model_type="xgboost",
                                            num_trees=T, max_depth=4))
    xs = x.copy()
    xs[rng.random(x.shape) < 0.7] = np.nan
    store = TensorBlockStore(default_page_rows=PAGE)
    for tier in TIERS:
        store.put(f"dense@{tier}", x, tier=tier)
        store.put_sparse(f"csr@{tier}", xs, tier=tier)
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                               plan_cache=ModelReuseCache())
    refs: dict = {}

    def ref(name: str, plan: str, algo: str) -> np.ndarray:
        key = (name, plan, algo)
        if key not in refs:
            refs[key] = np.asarray(engine.infer(
                name, forest, algorithm=algo, plan=plan,
                batch_pages=2).predictions)
        return refs[key]

    return engine, forest, ref, (x, xs)


def _sum_stages():
    """Trivial jit-less plan for executor-level tests (sum over F)."""

    def udf(state):
        state = dict(state)
        state["pred"] = jnp.sum(state["x"], axis=1)
        return state

    return split_into_stages(
        [Operator("udf", udf), Operator("write", lambda s: s, breaker=True)],
        jit=False)


# ---------------------------------------------------------------------------
# the fault matrix: transient faults recover bit-identically everywhere
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt,algo", [("dense", FUSED),
                                      ("csr", SPARSE_ALGO)])
@pytest.mark.parametrize("plan", ["udf", "rel"])
@pytest.mark.parametrize("site", FAULT_SITES)
def test_transient_fault_recovers_bit_identical(env, site, plan, fmt, algo):
    """One transient fault (2nd call at the site) per scan: the retry
    policy (or, for worker death, the sync-drain fallback) must recover
    with predictions bit-identical to the clean run, and the ScanStats
    fault fields must account for exactly what happened."""
    engine, forest, ref, _ = env
    for tier in TIERS:
        if site == "disk_page_read" and tier != "disk":
            continue                  # the site only exists on disk scans
        inj = FaultInjector().inject(site, fail_at=2)
        res = engine.infer(f"{fmt}@{tier}", forest, algorithm=algo,
                           plan=plan, batch_pages=2, injector=inj,
                           retry_policy=FAST)
        sc = res.scan
        assert sc.faults_injected == 1, (site, tier)
        if site == "drain_worker":
            # thread death is not retried — it degrades to the sync path
            assert sc.degraded_to_sync and sc.retries == 0, (site, tier)
        else:
            assert sc.retries == 1, (site, tier)
            assert not sc.degraded_to_sync, (site, tier)
        assert sc.batch_resubmits == 0, (site, tier)
        assert not sc.deadline_hit and res.degraded is None
        assert np.array_equal(np.asarray(res.predictions),
                              ref(f"{fmt}@{tier}", plan, algo)), (site, tier)


def test_armed_but_silent_injector_changes_nothing(env):
    """An armed injector whose site never fires (fail_at past the scan's
    call count) must leave predictions AND fault accounting untouched —
    the instrumented zero-fault path is the measured hot path."""
    engine, forest, ref, _ = env
    inj = FaultInjector().inject("kernel_launch", fail_at=10_000)
    res = engine.infer("dense@host", forest, algorithm=FUSED, plan="udf",
                       batch_pages=2, injector=inj, retry_policy=FAST)
    sc = res.scan
    assert sc.faults_injected == 0 and sc.retries == 0
    assert not sc.degraded_to_sync and sc.batch_resubmits == 0
    assert np.array_equal(np.asarray(res.predictions),
                          ref("dense@host", "udf", FUSED))
    assert inj.calls["kernel_launch"] == sc.batches


# ---------------------------------------------------------------------------
# degradation ladders past the retry budget
# ---------------------------------------------------------------------------


def test_dma_halving_ladder_recovers(env):
    """Device-transfer faults that exhaust the retries resubmit the batch
    at HALVED batch_pages (the OOM answer): the split batches land at the
    same deterministic slots, so the result stays bit-identical."""
    engine, forest, ref, _ = env
    inj = FaultInjector().inject("page_dma_in", fail_at=1,
                                 times=FAST.max_attempts)
    res = engine.infer("dense@host", forest, algorithm=FUSED, plan="udf",
                       batch_pages=4, injector=inj, retry_policy=FAST)
    sc = res.scan
    assert sc.batch_resubmits == 1        # one batch split into halves
    assert sc.faults_injected == FAST.max_attempts
    assert sc.retries == FAST.max_attempts - 1
    assert sc.batches == 4                # 3 planned: 1 split into 2 + 2
    assert np.array_equal(np.asarray(res.predictions),
                          ref("dense@host", "udf", FUSED))


def test_dma_ladder_floor_raises_structured_scanfault(env):
    """At one data-axis unit the halving ladder has no rung left: the
    scan must raise a ScanFault carrying site/attempts/rows/cause."""
    engine, forest, _, _ = env
    inj = FaultInjector().inject("page_dma_in", fail_at=1, times=10_000)
    with pytest.raises(ScanFault) as ei:
        engine.infer("dense@host", forest, algorithm=FUSED, plan="udf",
                     batch_pages=2, injector=inj, retry_policy=FAST)
    e = ei.value
    assert e.site == "page_dma_in"
    assert e.attempts == FAST.max_attempts
    assert e.rows_completed == 0
    assert isinstance(e.cause, InjectedFault)


def test_disk_reenqueue_ladder_recovers(env):
    """Disk-read faults that exhaust the retries re-enqueue the batch
    once at the end of the plan; deterministic slots make the reordered
    completion bit-identical."""
    engine, forest, ref, _ = env
    inj = FaultInjector().inject("disk_page_read", fail_at=1,
                                 times=FAST.max_attempts)
    res = engine.infer("dense@disk", forest, algorithm=FUSED, plan="udf",
                       batch_pages=2, injector=inj, retry_policy=FAST)
    sc = res.scan
    assert sc.batch_resubmits == 1
    assert sc.faults_injected == FAST.max_attempts
    assert np.array_equal(np.asarray(res.predictions),
                          ref("dense@disk", "udf", FUSED))


def test_disk_reenqueue_exhaustion_raises_structured_scanfault(env):
    """A persistently failing disk read fails structured after the one
    re-enqueue: 2 x max_attempts at the site, zero rows silently wrong."""
    engine, forest, _, _ = env
    inj = FaultInjector().inject("disk_page_read", fail_at=1, times=10**6)
    with pytest.raises(ScanFault) as ei:
        engine.infer("dense@disk", forest, algorithm=FUSED, plan="udf",
                     batch_pages=2, injector=inj, retry_policy=FAST)
    e = ei.value
    assert e.site == "disk_page_read"
    assert e.attempts == 2 * FAST.max_attempts
    assert e.rows_completed == 0
    assert isinstance(e.cause, InjectedFault)


@pytest.mark.parametrize("site", ["kernel_launch", "drain_copy_out"])
def test_unladdered_site_exhaustion_raises_structured_scanfault(env, site):
    """kernel_launch / drain_copy_out have no degradation rung below the
    retries: exhaustion surfaces as ScanFault (for the drain: carried
    off the worker thread and re-raised on the compute thread)."""
    engine, forest, _, _ = env
    inj = FaultInjector().inject(site, fail_at=1, times=10**6)
    with pytest.raises(ScanFault) as ei:
        engine.infer("dense@host", forest, algorithm=FUSED, plan="udf",
                     batch_pages=2, injector=inj, retry_policy=FAST)
    e = ei.value
    assert e.site == site
    assert e.attempts == FAST.max_attempts
    assert isinstance(e.cause, InjectedFault)


# ---------------------------------------------------------------------------
# deadlines: partial results with exact accounting
# ---------------------------------------------------------------------------


class _CountingDeadline(Deadline):
    """Expires after a fixed number of expiry checks — a deterministic
    mid-scan deadline with no wall-clock flakiness (the executor checks
    once per batch iteration)."""

    def __init__(self, checks_allowed: int):
        super().__init__(None)
        self.checks_allowed = checks_allowed
        self.checks = 0

    @property
    def expired(self) -> bool:
        self.checks += 1
        return self.checks > self.checks_allowed


def test_deadline_partial_scored_rows_match_reference_exactly():
    """The deadline contract: rows drained before expiry are BIT-exact
    against the unbounded run, missing rows are NaN, and the mask is
    precise — batch boundaries, nothing torn."""
    x = np.arange(256 * 5, dtype=np.float32).reshape(256, 5)
    store = TensorBlockStore(default_page_rows=16)
    ds = store.put("p", x, tier="host")
    full, _, _ = StreamingScanExecutor(_sum_stages()).execute(ds, 2)
    ex = StreamingScanExecutor(_sum_stages(),
                               deadline=_CountingDeadline(3))
    part, _, st = ex.execute(ds, 2)
    assert st.deadline_hit
    assert st.batches == 3               # 3 of the 8 planned batches ran
    mask = ex.last_mask
    assert mask is not None and mask.shape == (256,)
    assert mask.sum() == 3 * 2 * 16      # whole batches only
    np.testing.assert_array_equal(part[mask], full[mask])
    assert np.isnan(part[~mask]).all()


def test_deadline_zero_budget_returns_empty_partial(env):
    """An already-expired budget still returns gracefully: an all-NaN
    partial with a fully populated degraded report, not an exception."""
    engine, forest, _, _ = env
    res = engine.infer("dense@host", forest, algorithm=FUSED, plan="udf",
                       batch_pages=2, deadline_s=0.0)
    assert res.scan.deadline_hit
    d = res.degraded
    assert d is not None and bool(d)
    assert d.cause == "deadline" and d.deadline_s == 0.0
    assert d.rows_scored == 0 and d.rows_missing == N
    assert d.row_mask is not None and d.row_mask.shape == (N,)
    assert not d.row_mask.any()
    assert np.isnan(np.asarray(res.predictions)).all()


def test_generous_deadline_is_not_a_degradation(env):
    """A budget the scan fits inside must leave no trace: full result,
    no degraded report, deadline_hit False."""
    engine, forest, ref, _ = env
    res = engine.infer("dense@host", forest, algorithm=FUSED, plan="udf",
                       batch_pages=2, deadline_s=3600.0)
    assert not res.scan.deadline_hit and res.degraded is None
    assert np.array_equal(np.asarray(res.predictions),
                          ref("dense@host", "udf", FUSED))


# ---------------------------------------------------------------------------
# store.move: guarded disk reads + rollback (no leaks, exact accounting)
# ---------------------------------------------------------------------------


def test_move_rolls_back_on_disk_read_fault():
    """A move whose disk-tier source read exhausts its retries must roll
    back completely: catalog tier, per-tier nbytes, and spill files all
    unchanged — and succeed once the fault clears."""
    x = np.arange(128 * 4, dtype=np.float32).reshape(128, 4)
    inj = FaultInjector().inject("disk_page_read", fail_at=1,
                                 times=FAST.max_attempts)
    store = TensorBlockStore(default_page_rows=16, injector=inj,
                             retry_policy=FAST)
    ds = store.put("d", x, tier="disk")
    files = sorted(os.listdir(store.spill_dir))
    disk0 = store.disk_nbytes
    assert disk0 == ds.nbytes
    with pytest.raises(ScanFault) as ei:
        store.move("d", "host")
    e = ei.value
    assert e.site == "disk_page_read" and e.attempts == FAST.max_attempts
    assert isinstance(e.cause, InjectedFault)
    assert store.get("d").tier == "disk"
    assert store.disk_nbytes == disk0 and store.host_nbytes == 0
    assert sorted(os.listdir(store.spill_dir)) == files
    # the injector disarmed after `times` fires: the retried move works
    moved = store.move("d", "host")
    assert moved.tier == "host"
    np.testing.assert_array_equal(np.asarray(moved.data), x)
    assert store.disk_nbytes == 0 and store.host_nbytes == moved.nbytes
    assert os.listdir(store.spill_dir) == []


def test_move_to_disk_failure_leaks_no_files(monkeypatch):
    """The spill-file-leak regression: a fault midway through a move
    ONTO the disk tier (first CSR page file written, second write dies)
    must unlink the partial files and leave accounting intact."""
    x = np.arange(64 * 3, dtype=np.float32).reshape(64, 3)
    xs = x.copy()
    xs[::3] = np.nan
    store = TensorBlockStore(default_page_rows=16)
    store.put_sparse("s", xs, tier="host")
    host0 = store.host_nbytes
    real = store_mod.mmap_array
    cnt = {"n": 0}

    def flaky(path, arr):
        cnt["n"] += 1
        if cnt["n"] == 2:
            raise OSError("synthetic: disk full")
        return real(path, arr)

    monkeypatch.setattr(store_mod, "mmap_array", flaky)
    with pytest.raises(OSError):
        store.move("s", "disk")
    assert store.get("s").tier == "host"
    assert store.host_nbytes == host0 and store.disk_nbytes == 0
    assert os.listdir(store.spill_dir) == []     # partial file unlinked
    assert "s" not in store._disk_paths
    monkeypatch.setattr(store_mod, "mmap_array", real)
    moved = store.move("s", "disk")              # filesystem recovered
    assert moved.tier == "disk"
    assert store.disk_nbytes == moved.nbytes and store.host_nbytes == 0
    assert len(os.listdir(store.spill_dir)) == 3


# ---------------------------------------------------------------------------
# injector + retry primitives
# ---------------------------------------------------------------------------


def test_injector_fail_at_and_times():
    inj = FaultInjector().inject("kernel_launch", fail_at=3, times=2)
    fired = []
    for i in range(1, 8):
        try:
            inj.fire("kernel_launch")
            fired.append(False)
        except InjectedFault as e:
            assert e.site == "kernel_launch" and e.call == i
            fired.append(True)
    assert fired == [False, False, True, True, False, False, False]
    assert inj.total_fired == 2
    assert inj.calls["kernel_launch"] == 7


def test_injector_probability_mode_is_seed_deterministic():
    def trace(seed: int) -> list[int]:
        inj = FaultInjector(seed=seed).inject("page_dma_in",
                                              probability=0.5, times=10**9)
        out = []
        for _ in range(64):
            try:
                inj.fire("page_dma_in")
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out

    a = trace(5)
    assert a == trace(5)                 # replay-stable
    assert 0 < sum(a) < 64               # actually probabilistic
    assert trace(6) != a                 # seed-sensitive


def test_injector_validation():
    inj = FaultInjector()
    with pytest.raises(ValueError):
        inj.inject("bogus_site", fail_at=1)
    with pytest.raises(ValueError):
        inj.inject("kernel_launch")                      # neither mode
    with pytest.raises(ValueError):
        inj.inject("kernel_launch", fail_at=1, probability=0.5)  # both


def test_retry_policy_recovers_and_counts():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    retries = []
    assert FAST.run(flaky, site="disk_page_read",
                    on_retry=lambda: retries.append(1)) == "ok"
    assert calls["n"] == 3 and len(retries) == 2


def test_retry_policy_exhaustion_and_non_retryable():
    def always():
        raise OSError("permanent")

    with pytest.raises(OSError):
        FAST.run(always, site="disk_page_read")

    calls = {"n": 0}

    def bug():
        calls["n"] += 1
        raise ValueError("a bug, not a fault")

    with pytest.raises(ValueError):
        FAST.run(bug, site="kernel_launch")
    assert calls["n"] == 1               # bugs are never retried


def test_retry_backoff_is_deterministic_and_capped():
    p = RetryPolicy()
    assert p.backoff_s("page_dma_in", 1) == p.backoff_s("page_dma_in", 1)
    assert p.backoff_s("page_dma_in", 1) != p.backoff_s("drain_copy_out", 1)
    assert p.backoff_s("page_dma_in", 2) > p.backoff_s("page_dma_in", 1) / 4
    assert p.backoff_s("page_dma_in", 30) \
        <= p.max_backoff_s * (1 + p.jitter_frac)


def test_retry_under_expired_deadline_raises_deadline_exceeded():
    def always():
        raise OSError("x")

    with pytest.raises(DeadlineExceeded):
        FAST.run(always, site="page_dma_in", deadline=Deadline(0.0))


# ---------------------------------------------------------------------------
# mesh half of the matrix (skips without 8 forced CPU devices)
# ---------------------------------------------------------------------------

NDEV = len(jax.devices())
needs_mesh = pytest.mark.skipif(
    NDEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh_engine(x, xs):
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
    store = TensorBlockStore(mesh, default_page_rows=PAGE)
    for tier in TIERS:
        store.put(f"dense@{tier}", x, tier=tier)
        store.put_sparse(f"csr@{tier}", xs, tier=tier)
    return ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                             plan_cache=ModelReuseCache())


@needs_mesh
@pytest.mark.parametrize("site", ["page_dma_in", "disk_page_read",
                                  "drain_worker"])
def test_mesh_transient_fault_recovers(env, site):
    """The shard_map plans under injected faults: recovery must stay
    bit-identical to the clean mesh run on the disk tier."""
    engine, forest, _, (x, xs) = env
    m = _mesh_engine(x, xs)
    kw = dict(algorithm=FUSED, plan="rel", batch_pages=4)
    clean = m.infer("dense@disk", forest, **kw)
    inj = FaultInjector().inject(site, fail_at=2)
    res = m.infer("dense@disk", forest, injector=inj, retry_policy=FAST,
                  **kw)
    sc = res.scan
    assert sc.faults_injected == 1
    if site == "drain_worker":
        assert sc.degraded_to_sync
    else:
        assert sc.retries == 1
    assert np.array_equal(np.asarray(res.predictions),
                          np.asarray(clean.predictions)), site


@needs_mesh
def test_mesh_halving_ladder_stays_data_axis_aligned(env):
    """Halved batches must stay divisible by the data axis (2): the
    ladder floor is the mesh unit, not one page."""
    engine, forest, _, (x, xs) = env
    m = _mesh_engine(x, xs)
    kw = dict(algorithm=FUSED, plan="udf", batch_pages=4)
    clean = m.infer("dense@host", forest, **kw)
    inj = FaultInjector().inject("page_dma_in", fail_at=1,
                                 times=FAST.max_attempts)
    res = m.infer("dense@host", forest, injector=inj, retry_policy=FAST,
                  **kw)
    sc = res.scan
    assert sc.batch_resubmits == 1
    assert np.array_equal(np.asarray(res.predictions),
                          np.asarray(clean.predictions))
