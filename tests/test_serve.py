"""Serving engine: continuous batching correctness + router behaviour.

The strongest test: the engine's greedy generations (per-slot indices,
slot reuse, staggered admission) must match a lockstep single-request
reference loop token-for-token.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.dist.sharding import make_plan
from repro.models import get_bundle
from repro.serve.engine import ServeEngine
from repro.serve.router import (TIER_BATCH, ForestRouter, RouterConfig,
                                request_features, synth_router_trace)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def served():
    cfg = reduced(get_config("olmo-1b"))
    bundle = get_bundle(cfg)
    params = bundle.init(cfg, KEY, dtype=jnp.float32)
    return cfg, bundle, params


def _reference_generate(cfg, bundle, params, prompt, bucket, max_new):
    """Single-request greedy loop with the same left-pad bucketing."""
    splan = make_plan(cfg, None)
    toks = np.zeros((1, bucket), np.int32)
    toks[0, bucket - len(prompt):] = prompt
    from repro.models import lm as LM
    MAXC = 96
    logits, caches = LM.lm_prefill(cfg, params, jnp.asarray(toks),
                                   splan=splan, ctx=MAXC)
    out = [int(jnp.argmax(logits[0]))]
    cur = jnp.asarray([[out[-1]]], jnp.int32)
    for _ in range(max_new - 1):
        logits, caches = bundle.decode(cfg, params, caches, cur, splan)
        out.append(int(jnp.argmax(logits[0])))
        cur = jnp.asarray([[out[-1]]], jnp.int32)
    return out


def test_engine_matches_reference(served):
    cfg, bundle, params = served
    rng = np.random.default_rng(0)
    engine = ServeEngine(cfg, params, slots=2, max_ctx=96,
                         prompt_buckets=(16,), dtype=jnp.float32)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(4)]   # 4 requests through 2 slots
    for p in prompts:
        engine.submit(p, max_new_tokens=6)
    done = engine.run_until_drained()
    assert len(done) == 4
    by_uid = {r.uid: r for r in done}
    for i, p in enumerate(prompts):
        want = _reference_generate(cfg, bundle, params, p, 16, 6)
        got = by_uid[i + 1].tokens
        assert got == want, f"req {i}: {got} vs {want}"


def test_engine_slot_reuse(served):
    cfg, _, params = served
    rng = np.random.default_rng(1)
    engine = ServeEngine(cfg, params, slots=2, max_ctx=64,
                         prompt_buckets=(8,), dtype=jnp.float32)
    for _ in range(5):
        engine.submit(rng.integers(0, cfg.vocab_size, 6),
                      max_new_tokens=3)
    done = engine.run_until_drained()
    assert len(done) == 5
    s = engine.stats()
    assert s["requests"] == 5 and s["tokens"] == 15


def test_engine_priority_admission(served):
    cfg, _, params = served
    rng = np.random.default_rng(2)
    engine = ServeEngine(cfg, params, slots=1, max_ctx=64,
                         prompt_buckets=(8,), dtype=jnp.float32)
    engine.submit(rng.integers(0, cfg.vocab_size, 4), max_new_tokens=2,
                  priority=1)
    engine.submit(rng.integers(0, cfg.vocab_size, 4), max_new_tokens=2,
                  priority=1)
    # interactive request jumps the queue
    uid3 = engine.submit(rng.integers(0, cfg.vocab_size, 4),
                         max_new_tokens=2, priority=0)
    done = engine.run_until_drained()
    order = [r.uid for r in done]
    assert order.index(uid3) < order.index(2)


def test_admission_timeout_sheds_to_batch_tier(served):
    """The serve plane's degradation ladder: an interactive request
    whose admission timeout lapses while queued is SHED to the batch
    tier (queue back, ``shed`` flagged, counted in stats) instead of
    jumping ahead of earlier batch-tier work."""
    cfg, _, params = served
    rng = np.random.default_rng(3)
    engine = ServeEngine(cfg, params, slots=1, max_ctx=64,
                         prompt_buckets=(8,), dtype=jnp.float32)
    uid1 = engine.submit(rng.integers(0, cfg.vocab_size, 4),
                         max_new_tokens=4, priority=1)
    uid2 = engine.submit(rng.integers(0, cfg.vocab_size, 4),
                         max_new_tokens=2, priority=1)
    # interactive, but its admission budget is already spent on arrival
    uid3 = engine.submit(rng.integers(0, cfg.vocab_size, 4),
                         max_new_tokens=2, priority=0, timeout_s=0.0)
    done = engine.run_until_drained()
    order = [r.uid for r in done]
    # shed behind BOTH earlier batch requests, not served first
    assert order.index(uid3) > order.index(uid1)
    assert order.index(uid3) > order.index(uid2)
    req3 = next(r for r in done if r.uid == uid3)
    assert req3.shed and req3.priority == TIER_BATCH
    assert engine.stats()["shed"] == 1
    assert len(done) == 3 and len(req3.tokens) == 2


def test_stats_percentiles_from_histograms(served):
    """stats() p50/p99 fields come from the per-engine fixed-bucket
    histograms (docs/observability.md): queue wait is submit ->
    admission, e2e is submit -> last token, tails ordered and clamped
    to the observed latency range."""
    cfg, _, params = served
    rng = np.random.default_rng(5)
    engine = ServeEngine(cfg, params, slots=2, max_ctx=64,
                         prompt_buckets=(8,), dtype=jnp.float32)
    for _ in range(4):
        engine.submit(rng.integers(0, cfg.vocab_size, 4), max_new_tokens=3)
    done = engine.run_until_drained()
    st = engine.stats()
    assert st["requests"] == len(done) == 4
    assert engine.metrics.counter("serve.requests").value == 4
    lat = sorted(r.finished_at - r.submitted_at for r in done)
    assert 0.0 <= st["p50_queue_wait_s"] <= st["p99_queue_wait_s"]
    assert 0.0 < st["p50_latency_s"] <= st["p99_latency_s"]
    # bucket interpolation is clamped to the observed min/max
    assert lat[0] <= st["p50_latency_s"] <= lat[-1]
    assert st["p99_latency_s"] <= lat[-1]
    h = engine.metrics.histogram("serve.e2e_latency_s")
    assert h.count == 4 and h.summary()["p99"] == st["p99_latency_s"]


# ---------------------------------------------------------------------------
# forest router
# ---------------------------------------------------------------------------


def test_router_learns_cost_rule():
    router = ForestRouter(RouterConfig(num_trees=32, max_depth=6))
    x, y = synth_router_trace(n=512, seed=99)
    tiers = router.route(x)
    acc = (tiers == y).mean()
    assert acc > 0.9, f"router accuracy {acc}"


def test_router_single_request():
    router = ForestRouter()
    cheap = request_features(4, 2, 0, 0, 32.0)
    costly = request_features(500, 250, 60, 8, 250.0)
    assert router.route(cheap) == 0
    assert router.route(costly) == 1
