"""Per-architecture smoke tests (deliverable f): every assigned arch at a
REDUCED config runs one forward/train step on CPU — shapes + no NaNs.
The FULL configs are exercised only via the dry-run (no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, reduced
from repro.dist.sharding import make_plan
from repro.models import get_bundle, input_specs
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.trainer import init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    if cfg.encoder_layers:
        Sd = S // cfg.dec_len_ratio
        return {"frames": rng.normal(size=(B, S, cfg.d_model)
                                     ).astype(np.float32),
                "tokens": toks[:, :Sd], "labels": toks[:, :Sd]}
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_finite(arch):
    cfg = reduced(get_config(arch))
    bundle = get_bundle(cfg)
    params = bundle.init(cfg, KEY, dtype=jnp.float32)
    loss = bundle.loss(cfg, params, _batch(cfg), make_plan(cfg, None))
    assert np.isfinite(float(loss))
    # random-init loss should be near ln(vocab_padded)
    assert abs(float(loss) - np.log(cfg.vocab_padded)) < 1.5


@pytest.mark.parametrize("arch", ["olmo-1b", "mamba2-2.7b",
                                  "llama4-scout-17b-a16e", "zamba2-2.7b",
                                  "seamless-m4t-large-v2"])
def test_one_train_step(arch):
    cfg = reduced(get_config(arch))
    opt = make_optimizer(OptimizerConfig(lr=1e-3, warmup_steps=1))
    splan = make_plan(cfg, None)
    step = jax.jit(make_train_step(cfg, opt, splan))
    state = init_state(cfg, opt, KEY, dtype=jnp.float32)
    state2, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["gnorm"]))
    assert int(state2["step"]) == 1
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state["params"], state2["params"])
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch):
    cfg = reduced(get_config(arch))
    bundle = get_bundle(cfg)
    params = bundle.init(cfg, KEY, dtype=jnp.float32)
    splan = make_plan(cfg, None)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    logits, caches = bundle.prefill(
        cfg, params, {k: v for k, v in batch.items() if k != "labels"},
        splan)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, caches2 = bundle.decode(cfg, params, caches, tok, splan)
    assert logits2.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits2)).all()
    assert int(caches2["index"]) == int(caches["index"]) + 1


def test_input_specs_cover_all_cells():
    """Every (arch × shape) cell has well-defined input specs."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            leaves = jax.tree_util.tree_leaves(specs)
            assert leaves, (arch, shape.name)
            for leaf in leaves:
                assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_param_counts_match_published_scale():
    """Sanity: full-config param counts land in the advertised ballpark."""
    expect = {
        "yi-34b": (30e9, 40e9),
        "olmo-1b": (0.9e9, 1.6e9),
        "qwen2-7b": (6e9, 9e9),
        "minitron-4b": (3.5e9, 5.5e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "llama4-scout-17b-a16e": (80e9, 120e9),     # total (16 experts)
        "llama4-maverick-400b-a17b": (330e9, 480e9),
        "zamba2-2.7b": (2.2e9, 3.5e9),
        "chameleon-34b": (30e9, 40e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}-{hi/1e9}]"


def test_active_params_moe():
    cfg = get_config("llama4-maverick-400b-a17b")
    active = cfg.active_param_count()
    total = cfg.param_count()
    assert active < 0.2 * total       # top-1 of 128 experts
    assert 12e9 < active < 30e9       # "A17B"
