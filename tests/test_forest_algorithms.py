"""Cross-equivalence of the four inference algorithms (paper F1 axis).

``naive_predict`` (per-sample while_loop — the most literal transcription
of tree traversal) is the root oracle; every vectorized backend must match
it bit-for-bit on the same dense forest, including NaN (missing-value)
inputs routed by default_left.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.algorithms import ALGORITHMS, naive_predict, predict_raw
from repro.core.forest import make_forest, pad_trees, tree_slice
from repro.core import postprocess as post

from conftest import random_forest_arrays

BACKENDS = ["predicated", "compiled", "hummingbird", "quickscorer"]


def _forest(rng, depth, T=5, F=9, seed=0):
    fe, th, dl, lv = random_forest_arrays(rng, T=T, depth=depth, F=F,
                                          seed=seed)
    return make_forest(fe, th, lv, default_left=dl, n_features=F)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("depth", [1, 2, 4, 6, 8])
def test_backend_matches_naive(rng, backend, depth):
    forest = _forest(rng, depth, seed=depth)
    x = rng.normal(size=(17, 9)).astype(np.float32)
    want = naive_predict(forest, jnp.asarray(x))
    got = predict_raw(forest, jnp.asarray(x), backend)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_nan_handling(rng, backend):
    forest = _forest(rng, 5, seed=7)
    x = rng.normal(size=(23, 9)).astype(np.float32)
    x[rng.random(x.shape) < 0.3] = np.nan     # missing features
    want = naive_predict(forest, jnp.asarray(x))
    got = predict_raw(forest, jnp.asarray(x), backend)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_unknown_algorithm_raises(random_forest):
    with pytest.raises(ValueError, match="unknown algorithm"):
        predict_raw(random_forest, jnp.zeros((1, 11)), "nope")


# ---------------------------------------------------------------------------
# phase 2: aggregation semantics (paper Sec. 2)
# ---------------------------------------------------------------------------


def test_postprocess_xgboost_sigmoid(random_forest):
    x = jnp.asarray(np.random.default_rng(1).normal(size=(8, 11)),
                    jnp.float32)
    raw = predict_raw(random_forest, x, "predicated")
    p = post.predict_proba(random_forest, x)
    manual = 1.0 / (1.0 + np.exp(-np.asarray(raw).sum(-1)))
    np.testing.assert_allclose(np.asarray(p), manual, rtol=1e-5)
    labels = post.predict_label(random_forest, x)
    np.testing.assert_array_equal(np.asarray(labels),
                                  (manual >= 0.5).astype(np.int32))


def test_postprocess_rf_mean(rng):
    import dataclasses
    forest = _forest(rng, 3, T=4, seed=3)
    forest = dataclasses.replace(forest, model_type="randomforest")
    # clip leaves into [0, 1] (RF leaves are class-1 probabilities)
    forest = dataclasses.replace(
        forest, leaf_value=jnp.clip(forest.leaf_value, 0.0, 1.0))
    x = jnp.asarray(rng.normal(size=(6, 9)), jnp.float32)
    raw = predict_raw(forest, x, "predicated")
    p = post.predict_proba(forest, x)
    np.testing.assert_allclose(np.asarray(p), np.asarray(raw).mean(-1),
                               rtol=1e-5)


def test_pad_trees_sum_invariant(rng):
    """Padding trees (relation-centric partitioning) must not change the
    summed raw score — pass-through trees carry zero leaves."""
    forest = _forest(rng, 4, T=5, seed=11)
    x = jnp.asarray(rng.normal(size=(9, 9)), jnp.float32)
    base = np.asarray(predict_raw(forest, x, "predicated")).sum(-1)
    padded, true_T = pad_trees(forest, 8)
    assert padded.num_trees == 8 and true_T == 5
    got = np.asarray(predict_raw(padded, x, "predicated")).sum(-1)
    np.testing.assert_allclose(got, base, rtol=1e-6)


def test_tree_slice_partition_sums_match(rng):
    """Model partitioning: per-partition partial sums == whole-forest sum
    (the relation-centric AGGREGATE's legality)."""
    forest = _forest(rng, 4, T=6, seed=13)
    x = jnp.asarray(rng.normal(size=(5, 9)), jnp.float32)
    whole = np.asarray(predict_raw(forest, x, "predicated")).sum(-1)
    parts = [tree_slice(forest, s, 2) for s in (0, 2, 4)]
    partial = sum(np.asarray(predict_raw(p, x, "predicated")).sum(-1)
                  for p in parts)
    np.testing.assert_allclose(partial, whole, rtol=1e-6)


def test_all_algorithms_registered():
    assert set(ALGORITHMS) == {"naive", "predicated", "compiled",
                               "hummingbird", "quickscorer"}
