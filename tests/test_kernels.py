"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, allclose.

Kernels run in interpret mode on CPU (the kernel body executes in Python);
on a real TPU the same ``pallas_call`` compiles.  Each kernel is checked
against its matching jnp algorithm (ref.py), which is itself checked
against the naive while_loop in test_forest_algorithms.py.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.forest import make_forest
from repro.kernels.ops import KERNEL_ALGORITHMS, predict_raw_pallas
from repro.kernels.ref import REFERENCES

from conftest import random_forest_arrays

KERNELS = sorted(KERNEL_ALGORITHMS)

SHAPE_GRID = [
    # (B, T, depth, F, block_b, block_t)
    (8, 4, 3, 8, 8, 4),
    (16, 5, 4, 11, 8, 2),        # padding on both axes
    (32, 8, 6, 16, 16, 4),
    (7, 3, 2, 5, 4, 2),          # tiny, non-aligned
    (24, 10, 8, 30, 8, 2),       # paper's depth-8 regime
]


@pytest.mark.parametrize("kernel", KERNELS)
@pytest.mark.parametrize("shape", SHAPE_GRID,
                         ids=[f"B{b}T{t}d{d}F{f}" for b, t, d, f, _, _
                              in SHAPE_GRID])
def test_kernel_matches_ref(rng, kernel, shape):
    B, T, depth, F, bb, bt = shape
    fe, th, dl, lv = random_forest_arrays(rng, T=T, depth=depth, F=F,
                                          seed=hash((kernel, shape)) % 9973)
    forest = make_forest(fe, th, lv, default_left=dl, n_features=F)
    x = rng.normal(size=(B, F)).astype(np.float32)
    want = REFERENCES[kernel](forest, jnp.asarray(x))
    got = KERNEL_ALGORITHMS[kernel](forest, jnp.asarray(x),
                                    block_b=bb, block_t=bt, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kernel", KERNELS)
def test_kernel_nan_inputs(rng, kernel):
    fe, th, dl, lv = random_forest_arrays(rng, T=4, depth=4, F=9, seed=31)
    forest = make_forest(fe, th, lv, default_left=dl, n_features=9)
    x = rng.normal(size=(12, 9)).astype(np.float32)
    x[rng.random(x.shape) < 0.25] = np.nan
    want = REFERENCES[kernel](forest, jnp.asarray(x))
    got = KERNEL_ALGORITHMS[kernel](forest, jnp.asarray(x),
                                    block_b=4, block_t=2, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtype_sweep(rng, dtype):
    """bf16 thresholds/leaves: kernels must stay allclose to the jnp ref
    evaluated at the same precision."""
    fe, th, dl, lv = random_forest_arrays(rng, T=4, depth=4, F=8, seed=77)
    forest = make_forest(fe, th, lv, default_left=dl, n_features=8)
    forest = forest.astype(dtype).astype(jnp.float32)  # quantize once
    x = rng.normal(size=(8, 8)).astype(np.float32)
    want = REFERENCES["predicated_pallas"](forest, jnp.asarray(x))
    got = predict_raw_pallas(forest, jnp.asarray(x),
                             "predicated_pallas", block_b=8, block_t=2,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def _model_words(bb, bt, I, L, F, f_eff):
    return (bb * F + 3 * bt * I + bt * I * f_eff + 2 * bb * bt * I
            + bt * L + bb * bt)


def test_block_heuristics_fit_budget():
    from repro.kernels.common import block_heuristics
    bb, bt = block_heuristics(4096, 1600, 255, 256, 2000)
    assert bb >= 1 and bt >= 1
    # the returned blocks actually fit the budget (one-hot modeled at the
    # per-tree used-feature cap, min(F, I) = 255)
    words = _model_words(bb, bt, 255, 256, 2000, 255)
    assert words * 4 <= 12 * 1024 * 1024 or (bb == 1 or bt == 1)


def test_block_heuristics_wide_sparse_sane():
    """criteo scale (F = 10k): the naive bt*I*F one-hot term drove blocks
    to (8, 1), starving the MXU; capping the modeled F at the per-tree
    used-feature bound (<= I) must keep sample blocks large."""
    from repro.kernels.common import block_heuristics
    bb, bt = block_heuristics(4096, 1600, 255, 256, 10_000)
    assert bb >= 64, (bb, bt)
    assert bt >= 2, (bb, bt)
    words = _model_words(bb, bt, 255, 256, 10_000, 255)
    assert words * 4 <= 12 * 1024 * 1024
    # an explicit per-tree used-feature count tightens the cap further
    bb2, bt2 = block_heuristics(4096, 1600, 255, 256, 10_000,
                                used_features=64)
    assert bb2 >= bb and bt2 >= bt
