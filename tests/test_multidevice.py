"""Multi-device fused inference: shard_map tree-parallel partials.

Runs only under a forced multi-device CPU topology:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_multidevice.py

(the CI ``multi-device-smoke`` job does exactly that; under the plain
tier-1 run these tests skip).

The contracts under test:
  * rel plan on a (data x model) mesh == mesh-less unrolled template with
    the SAME partition count, BIT-identically in f32 (the mesh-less
    aggregate folds partials in partition order — the association
    XLA:CPU's all-reduce uses);
  * udf plan on a mesh == mesh-less udf, bit-identically (pure data
    parallelism; per-row math is batch-placement-independent);
  * the rel plan's kernel stage lowers to ONE shard_map-wrapped fused
    kernel call plus a single psum — no [B, T] intermediate, no
    per-partition unrolled launches (asserted on the jaxpr, recursively);
  * plan-cache correctness across meshes: same model on 1-device and
    8-device topologies -> DISTINCT cache entries, identical predictions;
  * the CSR feature-gather prepass runs inside the shard_map body: the
    compact tile exists only at the LOCAL batch.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.reuse import ModelReuseCache
from repro.core.train import TrainConfig, train_forest
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore

NDEV = len(jax.devices())
pytestmark = pytest.mark.skipif(
    NDEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

FUSED = ["predicated_pallas_fused", "hummingbird_pallas_fused",
         "quickscorer_pallas_fused"]
B, F, T, PAGE = 512, 16, 24, 64


def _mesh(n_data, n_model):
    devs = np.array(jax.devices()[: n_data * n_model])
    return Mesh(devs.reshape(n_data, n_model), ("data", "model"))


@pytest.fixture(scope="module")
def data_and_forest():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(B, F)).astype(np.float32)
    w = rng.normal(size=F).astype(np.float32)
    y = (x @ w > 0).astype(np.float32)
    forest = train_forest(x, y, TrainConfig(model_type="xgboost",
                                            num_trees=T, max_depth=4))
    return x, y, forest


def _engine(x, mesh, *, plan_cache=None, page_rows=PAGE):
    store = TensorBlockStore(mesh, default_page_rows=page_rows)
    store.put("d", x)
    return ForestQueryEngine(
        store, reuse_cache=ModelReuseCache(),
        plan_cache=plan_cache if plan_cache is not None else ModelReuseCache())


# ---------------------------------------------------------------------------
# bitwise parity: mesh vs mesh-less template
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algorithm", FUSED)
def test_rel_mesh_bitwise_matches_meshless(data_and_forest, algorithm):
    """(data=2, model=4) mesh rel == mesh-less rel with n_parts=4, bitwise."""
    x, _, forest = data_and_forest
    em = _engine(x, _mesh(2, 4))
    es = _engine(x, None)
    rm = em.infer("d", forest, algorithm=algorithm, plan="rel")
    rs = es.infer("d", forest, algorithm=algorithm, plan="rel", n_parts=4)
    assert rm.n_parts == 4 and rs.n_parts == 4
    assert rm.mesh_devices == 8 and rs.mesh_devices == 1
    assert np.array_equal(np.asarray(rm.predictions),
                          np.asarray(rs.predictions)), "f32 bitwise parity"


def test_rel_mesh_shapes(data_and_forest):
    """All-model (1, 8) and all-data (8, 1)-style topologies agree too."""
    x, _, forest = data_and_forest
    alg = FUSED[0]
    es = _engine(x, None)
    rs8 = es.infer("d", forest, algorithm=alg, plan="rel", n_parts=8)
    rm18 = _engine(x, _mesh(1, 8)).infer("d", forest, algorithm=alg,
                                         plan="rel")
    assert rm18.n_parts == 8
    assert np.array_equal(np.asarray(rm18.predictions),
                          np.asarray(rs8.predictions))
    # data-only mesh: rel falls back to the unrolled template (no model
    # axis), x stays sharded — predictions still match the template
    mesh_d = Mesh(np.array(jax.devices()), ("data",))
    rmd = _engine(x, mesh_d).infer("d", forest, algorithm=alg, plan="rel",
                                   n_parts=8)
    assert np.array_equal(np.asarray(rmd.predictions),
                          np.asarray(rs8.predictions))


@pytest.mark.parametrize("algorithm", FUSED[:1])
def test_udf_mesh_bitwise_matches_meshless(data_and_forest, algorithm):
    x, _, forest = data_and_forest
    rm = _engine(x, _mesh(2, 4)).infer("d", forest, algorithm=algorithm,
                                       plan="udf")
    rs = _engine(x, None).infer("d", forest, algorithm=algorithm, plan="udf")
    assert np.array_equal(np.asarray(rm.predictions),
                          np.asarray(rs.predictions))


def test_unfused_algorithm_under_mesh_rel(data_and_forest):
    """jnp (non-pallas) backends run through the same shard_map body:
    local predict+sum then psum — parity within f32 reassociation."""
    x, _, forest = data_and_forest
    from repro.core.postprocess import predict_proba
    rm = _engine(x, _mesh(2, 4)).infer("d", forest, algorithm="predicated",
                                       plan="rel")
    want = predict_proba(forest, jnp.asarray(x), algorithm="predicated")
    np.testing.assert_allclose(np.asarray(rm.predictions),
                               np.asarray(want), rtol=1e-5, atol=1e-6)


def test_batch_pages_round_to_data_axis(data_and_forest):
    """Odd page batches round up to the data-axis multiple (shard_map
    needs even division) — batched == whole-dataset, bitwise."""
    x, _, forest = data_and_forest
    em = _engine(x, _mesh(2, 4))
    whole = em.infer("d", forest, algorithm=FUSED[0], plan="udf")
    batched = em.infer("d", forest, algorithm=FUSED[0], plan="udf",
                       batch_pages=3)
    assert np.array_equal(np.asarray(batched.predictions),
                          np.asarray(whole.predictions))


# ---------------------------------------------------------------------------
# jaxpr structure: one fused launch per device + a single psum
# ---------------------------------------------------------------------------


def _walk(jaxpr, depth=0, out=None):
    if out is None:
        out = []
    for eqn in jaxpr.eqns:
        out.append((depth, eqn.primitive.name,
                    [tuple(getattr(v.aval, "shape", ()))
                     for v in eqn.outvars]))
        for v in eqn.params.values():
            if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                _walk(v.jaxpr, depth + 1, out)
            elif hasattr(v, "eqns"):
                _walk(v, depth + 1, out)
    return out


def test_rel_mesh_kernel_stage_jaxpr(data_and_forest):
    """The acceptance assertion: the rel plan's kernel stage is ONE
    shard_map containing ONE fused pallas_call and ONE psum — no
    [B, T]-shaped intermediate anywhere, no unrolled per-partition
    launches."""
    x, _, forest = data_and_forest
    em = _engine(x, _mesh(2, 4))
    alg = "predicated_pallas_fused"
    mat = em._partition_model(forest, alg, 4)
    ops = em._rel_ops(mat, alg, 4)
    cp = next(op for op in ops if op.name.startswith("cross-product"))
    ds = em.store.get("d")
    state = {"x": ds.page_slice(0, ds.num_pages)}
    eqns = _walk(jax.make_jaxpr(cp.fn)(state).jaxpr)

    assert sum(1 for _, n, _ in eqns if n == "shard_map") == 1
    assert sum(1 for _, n, _ in eqns if n == "pallas_call") == 1, \
        "per-partition unrolled kernel launches leaked into the mesh path"
    assert sum(1 for _, n, _ in eqns if n == "psum") == 1

    T_pad = mat.forest.num_trees
    b_padded = ds.data.shape[0]
    banned = {(b_padded, T_pad), (b_padded // 2, T_pad),
              (b_padded, T), (b_padded // 2, T)}
    seen = {s for _, _, shapes in eqns for s in shapes}
    assert not (seen & banned), f"[B, T] materialization: {seen & banned}"


# ---------------------------------------------------------------------------
# plan-cache correctness across meshes
# ---------------------------------------------------------------------------


def test_plan_cache_distinct_entries_across_meshes(data_and_forest):
    """Same model on 1-device and 8-device topologies: distinct compiled
    plans (no false sharing), identical f32 predictions bit for bit."""
    x, _, forest = data_and_forest
    shared_plans = ModelReuseCache()
    alg = FUSED[0]
    e1 = _engine(x, None, plan_cache=shared_plans)
    e8 = _engine(x, _mesh(1, 8), plan_cache=shared_plans)
    kw = dict(algorithm=alg, model_id="xmesh")

    r1u = e1.infer("d", forest, plan="udf", **kw)
    r8u = e8.infer("d", forest, plan="udf", **kw)
    assert not r8u.plan_reuse_hit, "8-device udf plan hit the 1-device entry"
    r1r = e1.infer("d", forest, plan="rel+reuse", n_parts=8, **kw)
    r8r = e8.infer("d", forest, plan="rel+reuse", **kw)
    assert not r8r.plan_reuse_hit, "8-device rel plan hit the 1-device entry"
    assert len(shared_plans) == 4

    assert np.array_equal(np.asarray(r1u.predictions),
                          np.asarray(r8u.predictions))
    assert np.array_equal(np.asarray(r1r.predictions),
                          np.asarray(r8r.predictions))

    # steady state on both topologies stays hit-separated
    assert e1.infer("d", forest, plan="udf", **kw).plan_reuse_hit
    assert e8.infer("d", forest, plan="udf", **kw).plan_reuse_hit


# ---------------------------------------------------------------------------
# sparse plane under the mesh
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sparse_setup():
    rng = np.random.default_rng(7)
    Fw = 400
    x = rng.normal(size=(256, Fw)).astype(np.float32)
    x[rng.random(x.shape) < 0.9] = np.nan
    w = rng.normal(size=Fw).astype(np.float32)
    y = (np.nan_to_num(x) @ w > 0).astype(np.float32)
    forest = train_forest(np.nan_to_num(x[:, :64]), y,
                          TrainConfig(model_type="xgboost", num_trees=12,
                                      max_depth=4))
    forest = dataclasses.replace(forest, n_features=Fw)
    return x, forest


@pytest.mark.parametrize("plan", ["udf", "rel"])
def test_sparse_mesh_parity(sparse_setup, plan):
    """CSR pages through the mesh plans: gather runs inside the shard_map
    body, predictions bit-identical to the mesh-less CSR path and equal
    to the dense plane."""
    x, forest = sparse_setup
    alg = "hummingbird_pallas_fused"

    def put_both(mesh):
        store = TensorBlockStore(mesh, default_page_rows=32)
        store.put("d", x)
        store.put_sparse("d@csr", x)
        return ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                                 plan_cache=ModelReuseCache())

    em, es = put_both(_mesh(2, 4)), put_both(None)
    kw = dict(algorithm=alg, plan=plan)
    if plan == "rel":
        rm = em.infer("d@csr", forest, **kw)
        rs = es.infer("d@csr", forest, n_parts=rm.n_parts, **kw)
    else:
        rm = em.infer("d@csr", forest, **kw)
        rs = es.infer("d@csr", forest, **kw)
    assert rm.storage_format == "csr"
    assert np.array_equal(np.asarray(rm.predictions),
                          np.asarray(rs.predictions))
    dense = es.infer("d", forest, algorithm=alg, plan="udf")
    np.testing.assert_allclose(np.asarray(rm.predictions),
                               np.asarray(dense.predictions),
                               rtol=1e-5, atol=1e-6)


def test_sparse_gather_is_local_in_jaxpr(sparse_setup):
    """The compact tile inside the shard_map body is [B_LOCAL, f_used]:
    no global-batch-sized gather output exists in the kernel stage."""
    x, forest = sparse_setup
    alg = "hummingbird_pallas_fused"
    mesh = _mesh(2, 4)
    store = TensorBlockStore(mesh, default_page_rows=32)
    store.put_sparse("d@csr", x)
    em = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                           plan_cache=ModelReuseCache())
    mat = em._partition_model(forest, alg, 4, storage_format="csr")
    f_used = mat.aux["f_used"]
    ops = em._rel_ops(mat, alg, 4)
    cp = next(op for op in ops if op.name.startswith("cross-product"))
    ds = store.get("d@csr")
    state = {"x": ds.page_slice(0, ds.num_pages)}
    eqns = _walk(jax.make_jaxpr(cp.fn)(state).jaxpr)

    rows_global = ds.num_pages * ds.page_rows
    rows_local = rows_global // 2                     # n_data = 2
    seen = {s for _, _, shapes in eqns for s in shapes}
    assert (rows_global, f_used) not in seen, \
        "CSR gather ran at the GLOBAL batch"
    assert any(s == (rows_local, f_used) for s in seen), \
        f"expected a [B_local, f_used]=({rows_local}, {f_used}) tile"


def test_stage_reports_record_device_span(data_and_forest):
    x, _, forest = data_and_forest
    em = _engine(x, _mesh(2, 4))
    res = em.infer("d", forest, algorithm=FUSED[0], plan="rel")
    kernel_stages = [r for r in res.stage_reports
                     if any("cross-product" in o for o in r.operators)]
    assert kernel_stages and all(r.devices == 8 for r in kernel_stages)
    partition = [r for r in res.stage_reports if "partition" in r.name]
    assert partition and partition[0].devices == 8
