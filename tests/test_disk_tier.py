"""Disk tier + async drain: the bottom rung of the tier ladder.

The contracts under test:
  * disk-tier, host-tier, and device-tier executions are BIT-identical
    in f32, for dense and CSR storage, through udf and rel plans,
    mesh-less and (in the multi-device section, which skips without 8
    forced CPU devices) on a (data x model) mesh;
  * ``tier="auto"`` CASCADES device-budget -> host-budget -> disk: an
    ingest past both budgets lands on page-aligned mmap files, with
    per-tier nbytes accounting (``disk_nbytes``) and catalog tiers;
  * a disk dataset's ``page_slice`` is a lazy ``np.memmap`` VIEW (the
    whole array is never loaded host-resident);
  * ``store.move`` round-trips through ``disk`` (device -> disk -> host
    -> device) preserving predictions bitwise, and deletes the spill
    files it wrote when a dataset leaves the disk tier (or is dropped);
  * ``load_libsvm_csr_external(tier="disk")`` parses straight into page
    files with ``transfer_s == 0`` and hands back memmaps that
    ``put_sparse(..., tier="disk")`` registers zero-copy;
  * the ASYNC DRAIN (a dedicated worker thread consuming
    ``copy_to_host_async`` results into the preallocated buffer) keeps
    the <=2-device-page-buffer invariant — re-probed with live arrays —
    and its ``ScanStats`` accounting distinguishes worker write time
    (``drain_s``) from the compute thread's exposed wait
    (``drain_wait_s``); ``prefetch_depth=1`` stays fully synchronous.
"""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.reuse import ModelReuseCache
from repro.core.train import TrainConfig, train_forest
from repro.db import loader as ld
from repro.db.executor import MAX_IN_FLIGHT, StreamingScanExecutor
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore

N, F, T, PAGE = 384, 16, 24, 32
FUSED = "predicated_pallas_fused"
SPARSE_ALGO = "hummingbird_pallas_fused"
TIERS = ("device", "host", "disk")


@pytest.fixture(scope="module")
def data_and_forest():
    rng = np.random.default_rng(7)
    x = rng.normal(size=(N, F)).astype(np.float32)
    w = rng.normal(size=F).astype(np.float32)
    y = (x @ w > 0).astype(np.float32)
    forest = train_forest(x, y, TrainConfig(model_type="xgboost",
                                            num_trees=T, max_depth=4))
    xs = x.copy()
    xs[rng.random(x.shape) < 0.7] = np.nan
    return x, xs, forest


def _engine(store):
    return ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                             plan_cache=ModelReuseCache())


def _put_all_tiers(x, xs, *, mesh=None, page_rows=PAGE):
    """One store holding every (format, tier) combination of the data."""
    store = TensorBlockStore(mesh, default_page_rows=page_rows)
    for tier in TIERS:
        store.put(f"dense@{tier}", x, tier=tier)
        store.put_sparse(f"csr@{tier}", xs, tier=tier)
    return store


# ---------------------------------------------------------------------------
# the auto cascade: device budget -> host budget -> disk
# ---------------------------------------------------------------------------


def test_auto_cascade_device_host_disk():
    """Three same-sized auto ingests walk the whole ladder: the first
    fits the device budget, the second spills to host, the third busts
    the host budget too and lands on disk — with per-tier accounting."""
    x = np.ones((256, 8), np.float32)
    store = TensorBlockStore(default_page_rows=32,
                             device_budget_bytes=int(x.nbytes * 1.5),
                             host_budget_bytes=int(x.nbytes * 1.5))
    a, b, c = store.put("a", x), store.put("b", x), store.put("c", x)
    assert (a.tier, b.tier, c.tier) == ("device", "host", "disk")
    assert isinstance(c.data, np.memmap)
    assert store.device_nbytes == a.nbytes
    assert store.host_nbytes == b.nbytes
    assert store.disk_nbytes == c.nbytes
    cat = store.catalog()
    assert [cat[k]["tier"] for k in "abc"] == ["device", "host", "disk"]
    # a fourth ingest keeps landing on disk (the ladder has no floor cap)
    assert store.put("d", x).tier == "disk"
    # explicit tier= still overrides the cascade in any direction
    assert store.put("e", x, tier="device").tier == "device"
    assert store.put("f", x, tier="disk").tier == "disk"


def test_sparse_auto_cascade(data_and_forest):
    """CSR ingests cascade identically; all three page arrays are mmap."""
    _, xs, _ = data_and_forest
    store = TensorBlockStore(default_page_rows=PAGE,
                             device_budget_bytes=1, host_budget_bytes=1)
    ds = store.put_sparse("s", xs)
    assert ds.tier == "disk" and ds.pages.tier == "disk"
    for arr in (ds.pages.indptr, ds.pages.indices, ds.pages.values):
        assert isinstance(arr, np.memmap)
    assert store.disk_nbytes == ds.nbytes
    assert store.device_nbytes == 0 and store.host_nbytes == 0
    assert store.catalog()["s"]["tier"] == "disk"


def test_disk_page_slice_is_lazy_mmap_view(data_and_forest):
    """page_slice on the disk tier must NOT load the whole array: it is
    an np.memmap view whose buffer is the spill file itself."""
    x, xs, _ = data_and_forest
    store = _put_all_tiers(x, xs)
    dd = store.get("dense@disk")
    blk = dd.page_slice(2, 3)
    assert isinstance(blk, np.memmap)
    assert blk.base is not None                 # a view, not a copy
    np.testing.assert_array_equal(np.asarray(blk),
                                  x[2 * PAGE: 5 * PAGE])
    sd = store.get("csr@disk")
    sblk = sd.page_slice(1, 2)
    assert sblk.tier == "disk"
    for arr in (sblk.indptr, sblk.indices, sblk.values):
        assert isinstance(arr, np.memmap) and arr.base is not None
    # staging a disk view is a plain device transfer of just those pages
    dev = dd.to_device(blk, None)
    assert isinstance(dev, jax.Array)


# ---------------------------------------------------------------------------
# bit-identical disk vs host vs device predictions (mesh-less half; the
# mesh half of the grid is in the multi-device section below)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("plan", ["udf", "rel"])
@pytest.mark.parametrize("fmt,algo", [("dense", FUSED),
                                      ("csr", SPARSE_ALGO)])
def test_disk_tier_bitwise_parity(data_and_forest, plan, fmt, algo):
    x, xs, forest = data_and_forest
    engine = _engine(_put_all_tiers(x, xs))
    kw = dict(algorithm=algo, plan=plan, batch_pages=3)
    res = {t: engine.infer(f"{fmt}@{t}", forest, **kw) for t in TIERS}
    assert [res[t].tier for t in TIERS] == list(TIERS)
    rd = res["disk"]
    assert rd.storage_format == fmt
    assert rd.scan.batches > 1 and rd.scan.bytes_streamed > 0
    assert rd.scan.drain_async                   # worker-thread drain ran
    for t in ("host", "disk"):
        assert np.array_equal(np.asarray(res[t].predictions),
                              np.asarray(res["device"].predictions)), \
            f"{t} f32 bitwise parity"


def test_larger_than_both_budgets_streams_from_disk(data_and_forest):
    """The acceptance shape: an ingest larger than device AND host
    budgets cascades to disk, infer() derives an out-of-core batch size
    (2 in-flight buffers fit the device budget), the scan never falls
    back to a resident tier, and predictions are bit-identical to the
    device-resident run."""
    x, xs, forest = data_and_forest
    dev = _engine(_put_all_tiers(x, xs))
    store = TensorBlockStore(default_page_rows=PAGE,
                             device_budget_bytes=x.nbytes // 4,
                             host_budget_bytes=x.nbytes // 4)
    ds = store.put("big", x)
    assert ds.tier == "disk"
    assert ds.nbytes >= 4 * (x.nbytes // 4)
    engine = _engine(store)
    for plan in ("udf", "rel"):
        res = engine.infer("big", forest, algorithm=FUSED, plan=plan)
        assert res.tier == "disk" and res.scan.tier == "disk"
        assert res.scan.batches > 1
        assert res.scan.max_in_flight <= MAX_IN_FLIGHT
        assert 2 * res.scan.batch_pages * ds.page_nbytes \
            <= store.device_budget_bytes
        ref = dev.infer("dense@device", forest, algorithm=FUSED, plan=plan,
                        batch_pages=res.scan.batch_pages)
        assert np.array_equal(np.asarray(res.predictions),
                              np.asarray(ref.predictions))


# ---------------------------------------------------------------------------
# move: round-trips through disk + spill-file lifecycle
# ---------------------------------------------------------------------------


def test_move_roundtrip_through_disk(data_and_forest):
    """device -> disk -> host -> device: page layout (and therefore every
    prediction) survives the full ladder round-trip bitwise, for dense
    AND CSR datasets."""
    x, xs, forest = data_and_forest
    store = _put_all_tiers(x, xs)
    engine = _engine(store)
    kw = dict(algorithm=FUSED, plan="udf", batch_pages=2)
    ref = engine.infer("dense@device", forest, **kw)
    for tier in ("disk", "host", "device"):
        moved = store.move("dense@device", tier)
        assert moved.tier == tier
        r = engine.infer("dense@device", forest, **kw)
        assert r.tier == tier
        assert np.array_equal(np.asarray(r.predictions),
                              np.asarray(ref.predictions)), tier
    skw = dict(algorithm=SPARSE_ALGO, plan="rel", batch_pages=2)
    ref_s = engine.infer("csr@device", forest, **skw)
    for tier in ("disk", "host", "device"):
        moved = store.move("csr@device", tier)
        assert moved.tier == tier and moved.pages.tier == tier
        r = engine.infer("csr@device", forest, **skw)
        assert np.array_equal(np.asarray(r.predictions),
                              np.asarray(ref_s.predictions)), tier


def test_spill_file_lifecycle(data_and_forest):
    """The store deletes the spill files it wrote: on move off the disk
    tier and on drop.  A store that never spills touches no filesystem."""
    x, xs, _ = data_and_forest
    store = TensorBlockStore(default_page_rows=PAGE)
    assert store._spill_dir is None              # lazy: no dir yet
    store.put("d", x, tier="disk")
    store.put_sparse("s", xs, tier="disk")
    files = set(os.listdir(store.spill_dir))
    assert len(files) == 4                       # 1 dense + 3 CSR arrays
    store.move("d", "host")
    assert len(os.listdir(store.spill_dir)) == 3
    store.move("d", "disk")                      # re-spill recreates it
    assert len(os.listdir(store.spill_dir)) == 4
    store.drop("d")
    store.drop("s")
    assert os.listdir(store.spill_dir) == []


# ---------------------------------------------------------------------------
# loader tier="disk" (the criteo-scale ingest path)
# ---------------------------------------------------------------------------


def test_libsvm_disk_tier_ingest(tmp_path, data_and_forest):
    _, xs, forest = data_and_forest
    y = np.zeros(xs.shape[0], np.float32)
    p = str(tmp_path / "d.svm")
    ld.write_libsvm(p, xs, y)
    pages, labels, t = ld.load_libsvm_csr_external(
        p, xs.shape[1], page_rows=PAGE, tier="disk",
        spill_dir=str(tmp_path))
    assert t.transfer_s == 0.0, "disk-tier ingest must not transfer"
    assert t.parse_s > 0 and t.convert_s > 0 and t.total_s > 0
    assert pages.tier == "disk"
    for arr in (pages.indptr, pages.indices, pages.values):
        assert isinstance(arr, np.memmap)
    assert {f for f in os.listdir(tmp_path) if f.endswith(".bin")} == \
        {"d.indptr.bin", "d.indices.bin", "d.values.bin"}
    # zero-copy registration + bit-parity with the device-tier load
    store = TensorBlockStore(default_page_rows=PAGE)
    ds = store.put_sparse("k", pages=pages, num_rows=len(labels),
                          tier="disk")
    assert ds.tier == "disk"
    assert ds.pages.indptr is pages.indptr       # zero-copy handoff
    pages_d, _, t_d = ld.load_libsvm_csr_external(p, xs.shape[1],
                                                  page_rows=PAGE)
    assert t_d.transfer_s > 0.0
    store.put_sparse("dev", pages=pages_d, num_rows=len(labels))
    engine = _engine(store)
    rk = engine.infer("k", forest, algorithm=SPARSE_ALGO, plan="udf",
                      batch_pages=2)
    rd = engine.infer("dev", forest, algorithm=SPARSE_ALGO, plan="udf",
                      batch_pages=2)
    assert rk.tier == "disk" and rk.storage_format == "csr"
    assert np.array_equal(np.asarray(rk.predictions),
                          np.asarray(rd.predictions))


# ---------------------------------------------------------------------------
# async drain: off-thread accounting + the <=2-buffer invariant re-probed
# ---------------------------------------------------------------------------


def test_async_drain_stats_and_serial_reference(data_and_forest):
    """Depth 2 drains on the worker (drain_async, exposed wait accounted
    separately from worker write time); depth 1 is the fully synchronous
    reference (no worker, every write exposed, zero hidden overlap) —
    and both produce identical predictions."""
    x, xs, forest = data_and_forest
    engine = _engine(_put_all_tiers(x, xs))
    kw = dict(algorithm=FUSED, plan="udf", batch_pages=2)
    res = engine.infer("dense@disk", forest, prefetch_depth=2, **kw)
    assert res.scan.drain_async
    assert res.scan.drain_s > 0.0
    assert res.scan.drain_overlap_s >= 0.0
    ser = engine.infer("dense@disk", forest, prefetch_depth=1, **kw)
    assert not ser.scan.drain_async
    assert ser.scan.max_in_flight == 1
    # inline drain: every write is exposed, nothing can hide
    assert ser.scan.drain_overlap_s == 0.0
    assert ser.scan.drain_wait_s >= ser.scan.drain_s
    assert np.array_equal(np.asarray(ser.predictions),
                          np.asarray(res.predictions))


def test_live_buffer_probe_under_async_drain():
    """The live-array probe, re-run against the ASYNC drain: with the
    drain off the compute thread, still at most 2 page-block-shaped
    device arrays ever exist (the drain worker holds [rows]-sized
    predictions, never page buffers), on a DISK-tier source."""
    from repro.db.operators import Operator, split_into_stages
    F_odd = 19                       # unique shape: nothing else matches
    x = np.arange(256 * F_odd, dtype=np.float32).reshape(256, F_odd)
    store = TensorBlockStore(default_page_rows=16)
    ds = store.put("probe", x, tier="disk")
    batch_pages = 2
    block_shape = (batch_pages * ds.page_rows, F_odd)
    seen = []

    def probe(state):
        seen.append(sum(1 for a in jax.live_arrays()
                        if tuple(a.shape) == block_shape
                        and not a.is_deleted()))
        return state

    def udf(state):
        state = dict(state)
        state["pred"] = jnp.sum(state["x"], axis=1)   # keeps "x" threaded
        return state

    stages = split_into_stages(
        [Operator("probe", probe), Operator("udf", udf),
         Operator("write", lambda s: s, breaker=True)], jit=False)
    out, _, stats = StreamingScanExecutor(stages).execute(ds, batch_pages)
    assert stats.batches == len(seen) == 8
    assert stats.drain_async
    assert max(seen) <= MAX_IN_FLIGHT == 2, \
        f"3+ page buffers were live: {seen}"
    assert seen[-1] == 1             # no prefetch past the last batch
    np.testing.assert_allclose(out, x.sum(axis=1), rtol=1e-6)


def test_drain_worker_error_propagates():
    """A failure inside the drain worker must surface on the compute
    thread (after the join), not hang the queue or get swallowed."""
    from repro.db.operators import Operator, split_into_stages

    x = np.ones((128, 4), np.float32)
    store = TensorBlockStore(default_page_rows=16)
    ds = store.put("e", x, tier="disk")

    def udf(state):
        state = dict(state)
        # wrong-sized prediction: the worker's slot write cannot broadcast
        state["pred"] = jnp.zeros((3,), jnp.float32)
        return state

    stages = split_into_stages(
        [Operator("udf", udf),
         Operator("write", lambda s: s, breaker=True)], jit=False)
    with pytest.raises(ValueError):
        StreamingScanExecutor(stages).execute(ds, 2)


def test_compute_error_shuts_drain_worker_down():
    """The converse leak: a stage failing on the COMPUTE thread must
    still shut the drain worker down (sentinel + join on the error
    path), not strand the daemon thread in q.get() pinning the result
    buffer for the process lifetime."""
    import threading

    from repro.db.operators import Operator, split_into_stages

    x = np.ones((128, 4), np.float32)
    store = TensorBlockStore(default_page_rows=16)
    ds = store.put("c", x, tier="disk")
    calls = []

    def udf(state):
        if len(calls) == 2:          # fail mid-stream, drain queue warm
            raise RuntimeError("stage blew up")
        calls.append(1)
        state = dict(state)
        state["pred"] = jnp.sum(state["x"], axis=1)
        return state

    stages = split_into_stages(
        [Operator("udf", udf),
         Operator("write", lambda s: s, breaker=True)], jit=False)
    before = {t.name for t in threading.enumerate()}
    with pytest.raises(RuntimeError, match="stage blew up"):
        StreamingScanExecutor(stages).execute(ds, 2)
    leaked = [t for t in threading.enumerate()
              if t.name.startswith("scan-drain") and t.is_alive()]
    assert not leaked, f"drain worker leaked: {leaked} (before: {before})"


def test_drain_worker_death_queue_full_no_deadlock():
    """The latent deadlock this PR fixes: the drain worker dying while
    the bounded queue is FULL used to leave the compute thread blocked
    forever in ``queue.put``.  The put now times out, re-checks worker
    liveness, and the scan degrades mid-flight to the synchronous drain
    path — completing with the correct answer and honest stats."""
    import threading
    import time as _time

    from repro.db.faults import FaultInjector
    from repro.db.operators import Operator, split_into_stages

    class _SlowDeath(FaultInjector):
        """Holds the worker inside its first drain item long enough for
        the compute thread to fill the maxsize-2 queue, THEN kills it —
        deterministically exercising the blocked-put path."""

        def fire(self, site):
            if site == "drain_worker" and self.calls.get(site, 0) == 0:
                _time.sleep(0.4)
            super().fire(site)

    x = np.arange(512 * 3, dtype=np.float32).reshape(512, 3)
    store = TensorBlockStore(default_page_rows=16)
    ds = store.put("dd", x, tier="disk")     # 32 pages -> 16 batches of 2

    def udf(state):
        state = dict(state)
        state["pred"] = jnp.sum(state["x"], axis=1)
        return state

    stages = split_into_stages(
        [Operator("udf", udf),
         Operator("write", lambda s: s, breaker=True)], jit=False)
    inj = _SlowDeath().inject("drain_worker", fail_at=1)
    out, _, stats = StreamingScanExecutor(stages, injector=inj).execute(
        ds, 2)
    assert stats.degraded_to_sync
    assert stats.faults_injected == 1
    assert stats.batches == 16               # every batch still executed
    np.testing.assert_allclose(np.asarray(out), x.sum(axis=1), rtol=1e-6)
    leaked = [t for t in threading.enumerate()
              if t.name.startswith("scan-drain") and t.is_alive()]
    assert not leaked, f"drain worker leaked: {leaked}"


# ---------------------------------------------------------------------------
# multi-device half of the parity grid
# ---------------------------------------------------------------------------

NDEV = len(jax.devices())
needs_mesh = pytest.mark.skipif(
    NDEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh(n_data, n_model):
    devs = np.array(jax.devices()[: n_data * n_model])
    from jax.sharding import Mesh
    return Mesh(devs.reshape(n_data, n_model), ("data", "model"))


@needs_mesh
@pytest.mark.parametrize("plan", ["udf", "rel"])
@pytest.mark.parametrize("fmt,algo", [("dense", FUSED),
                                      ("csr", SPARSE_ALGO)])
def test_mesh_disk_tier_bitwise_parity(data_and_forest, plan, fmt, algo):
    """Disk-tier mmap pages DMA'd under data_sharding through the
    shard_map plans, drained async: bit-identical to the device-resident
    mesh run."""
    x, xs, forest = data_and_forest
    mesh = _mesh(2, 4)
    engine = _engine(_put_all_tiers(x, xs, mesh=mesh))
    kw = dict(algorithm=algo, plan=plan, batch_pages=4)
    rd = engine.infer(f"{fmt}@device", forest, **kw)
    rk = engine.infer(f"{fmt}@disk", forest, **kw)
    assert rk.tier == "disk" and rk.mesh_devices == 8
    assert rk.scan.batches > 1 and rk.scan.max_in_flight == 2
    assert rk.scan.drain_async
    assert np.array_equal(np.asarray(rk.predictions),
                          np.asarray(rd.predictions)), "f32 bitwise parity"
