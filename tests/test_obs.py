"""Observability plane (``src/repro/obs``): tracer, metrics, export.

The contracts under test, per ``docs/observability.md``:

  * DISABLED IS FREE — a disabled tracer returns the shared
    ``NULL_SPAN`` singleton from every ``span()`` call (identity
    asserted: zero allocation per trace point) and records nothing;
    ``QueryResult.trace`` stays ``None``.
  * SPANS NEST, ACROSS THREADS TOO — thread-stack nesting on one
    thread, explicit ``parent=`` for the drain worker's writes, which
    must nest under the owning batch span even though that span lives
    (and may have closed) on the compute thread.
  * EVENTS ARE EXACT — one injected fault produces exactly one
    ``fault.injected`` and one ``retry`` in the query's summary; the
    drain-death ladder produces its ``degrade.sync_drain``.
  * THE EXPORT IS VALID — Chrome trace-event JSON round-trips, every
    span row carries ``ph``/``ts``/``dur``/``tid``, parent chains
    resolve (validated by ``benchmarks.bench_obs.validate_chrome_trace``,
    the same checker the CI obs-smoke job runs).
  * SUMMARIES AGREE WITH STATS — ``trace.phase("scan.compute")`` clocks
    the same region as ``ScanStats.compute_s``.
"""

import json
import pathlib
import sys
import threading

import numpy as np
import pytest

from repro.core.reuse import ModelReuseCache
from repro.core.train import TrainConfig, train_forest
from repro.db.faults import FaultInjector, RetryPolicy
from repro.db.operators import TRACE_STATS
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore
from repro.obs import (METRICS, NULL_SPAN, TRACER, Counter, Histogram,
                       MetricsRegistry)

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks.bench_obs import validate_chrome_trace  # noqa: E402

N, F, T, PAGE = 384, 16, 24, 32
FUSED = "predicated_pallas_fused"
FAST = RetryPolicy(backoff_base_s=0.0, max_backoff_s=0.0)


@pytest.fixture(autouse=True)
def _tracer_clean():
    """Every test starts and ends with the tracer disarmed and empty."""
    TRACER.disable()
    TRACER.reset()
    yield
    TRACER.disable()
    TRACER.reset()


@pytest.fixture(scope="module")
def env():
    rng = np.random.default_rng(7)
    x = rng.normal(size=(N, F)).astype(np.float32)
    w = rng.normal(size=F).astype(np.float32)
    y = (x @ w > 0).astype(np.float32)
    forest = train_forest(x, y, TrainConfig(model_type="xgboost",
                                            num_trees=T, max_depth=4))
    store = TensorBlockStore(default_page_rows=PAGE)
    store.put("dense@host", x, tier="host")
    store.put("dense@disk", x, tier="disk")
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                               plan_cache=ModelReuseCache())
    return engine, forest, x


def traced_infer(engine, forest, name, **kw):
    TRACER.reset()
    TRACER.enable()
    try:
        return engine.infer(name, forest, algorithm=FUSED, **kw)
    finally:
        TRACER.disable()


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------


def test_counter_inc_value_reset():
    c = Counter("t")
    assert c.value == 0
    c.inc()
    c.inc(4)
    assert c.value == 5
    c.reset()
    assert c.value == 0


def test_histogram_percentiles_interpolate_within_observed_range():
    h = Histogram("lat", bounds=(0.001, 0.01, 0.1, 1.0))
    assert np.isnan(h.percentile(50))
    for v in (0.002, 0.003, 0.004, 0.05, 0.5):
        h.record(v)
    assert h.count == 5 and h.min == 0.002 and h.max == 0.5
    p50 = h.percentile(50)
    assert 0.001 <= p50 <= 0.01       # median lands in the second bucket
    p99 = h.percentile(99)
    assert 0.1 <= p99 <= 0.5          # clamped to the observed max
    s = h.summary()
    assert s["count"] == 5 and s["p50"] == p50 and s["max"] == 0.5
    h.record(100.0)                   # overflow bucket (past last bound)
    assert h.percentile(100) == 100.0


def test_registry_get_or_create_and_reset_keep_instances():
    reg = MetricsRegistry()
    c1 = reg.counter("a")
    c1.inc(3)
    assert reg.counter("a") is c1             # get-or-create, same object
    h1 = reg.histogram("h")
    h1.record(0.5)
    assert reg.counter_values() == {"a": 3}
    snap = reg.snapshot()
    assert snap["a"] == 3 and snap["h"]["count"] == 1
    reg.reset()
    assert reg.counter("a") is c1 and c1.value == 0
    assert reg.histogram("h") is h1 and h1.count == 0


def test_trace_stats_alias_mirrors_plan_traces_counter():
    """The pre-obs ``TRACE_STATS`` dict is a live view over the
    ``plan.traces`` counter: reads, ``+=`` writes, both directions."""
    c = METRICS.counter("plan.traces")
    before = TRACE_STATS["traces"]
    assert before == c.value
    c.inc(2)
    assert TRACE_STATS["traces"] == before + 2
    TRACE_STATS["traces"] += 1                # legacy increment style
    assert c.value == before + 3


# ---------------------------------------------------------------------------
# tracer: disabled path, nesting, events
# ---------------------------------------------------------------------------


def test_disabled_tracer_returns_the_null_span_singleton():
    assert not TRACER.enabled
    s1 = TRACER.span("anything", attr=1)
    s2 = TRACER.span("else")
    assert s1 is NULL_SPAN and s2 is NULL_SPAN    # identity: no allocation
    with s1 as s:
        s.set(x=1).event("noop")
        assert s.duration_s == 0.0
    TRACER.event("orphan")                         # no-op while disabled
    assert TRACER.finished() == []
    assert TRACER.export_chrome()["traceEvents"][-1]["ph"] == "M"


def test_span_nesting_attrs_and_summary():
    TRACER.enable()
    with TRACER.span("root", kind="test") as root:
        with TRACER.span("child") as child:
            TRACER.event("ping", n=1)          # attaches to innermost
        with TRACER.span("child"):
            pass
        root.set(late=True)
    assert child.parent_id == root.span_id
    assert root.parent_id is None
    assert root.attrs == {"kind": "test", "late": True}
    summ = TRACER.summarize(root)
    assert summ.num_spans == 3
    assert summ.span_counts == {"root": 1, "child": 2}
    assert summ.event_counts == {"ping": 1}
    assert summ.phase("child") <= summ.wall_s
    assert summ.phase("absent") == 0.0


def test_cross_thread_parenting_survives_parent_close():
    """The drain-worker pattern: the child opens on another thread with
    an explicit ``parent=`` AFTER the parent span already closed, and
    must still nest (summaries use the id map, not close order)."""
    TRACER.enable()
    with TRACER.span("query") as root:
        with TRACER.span("batch") as batch:
            pass

    def worker():
        with TRACER.span("drain", parent=batch):
            pass

    t = threading.Thread(target=worker, name="fake-drain")
    t.start()
    t.join()
    drain = [s for s in TRACER.finished() if s.name == "drain"][0]
    assert drain.parent_id == batch.span_id
    assert drain.tid != batch.tid
    summ = TRACER.summarize(root)
    assert summ.num_spans == 3 and summ.span_counts["drain"] == 1
    shape = validate_chrome_trace(TRACER.export_chrome())
    assert shape["cross_thread"] == 1 and shape["threads"] == 2


def test_null_span_parent_means_no_parent():
    """A parent handle captured while the tracer was disabled is the
    NULL_SPAN; a span opened with it (tracer now enabled) is a root."""
    parent = TRACER.span("captured-disabled")      # NULL_SPAN
    TRACER.enable()
    with TRACER.span("child", parent=parent) as ch:
        pass
    assert ch.parent_id is None


def test_orphan_events_are_exported():
    TRACER.enable()
    TRACER.event("free-standing", why="no open span")
    payload = TRACER.export_chrome()
    inst = [e for e in payload["traceEvents"] if e["ph"] == "i"]
    assert len(inst) == 1 and inst[0]["name"] == "free-standing"


# ---------------------------------------------------------------------------
# the instrumented data plane
# ---------------------------------------------------------------------------


def test_disabled_infer_leaves_no_trace(env):
    engine, forest, _ = env
    res = engine.infer("dense@host", forest, algorithm=FUSED,
                       batch_pages=2)
    assert res.trace is None
    assert TRACER.finished() == []


def test_traced_infer_spans_cross_thread_drain_and_stats_agree(env):
    """One traced host-tier streamed query: per-batch spans counted
    exactly, the drain worker's writes parented cross-thread, the
    export structurally valid, and the trace's compute phase clocking
    the same region as ``ScanStats.compute_s``."""
    engine, forest, x = env
    res = traced_infer(engine, forest, "dense@host", batch_pages=2)
    tr = res.trace
    assert tr is not None and tr.root == "query.infer"
    sc = res.scan
    assert tr.span_counts["scan.execute"] == 1
    assert tr.span_counts["scan.batch"] == sc.batches
    assert tr.span_counts["scan.dma_in"] == sc.batches
    assert tr.span_counts["scan.compute"] == sc.batches
    assert tr.span_counts["scan.drain_write"] == sc.batches
    assert tr.event_counts == {"plan.cache": 1}    # no faults, one lookup
    # phase totals vs ScanStats: same code region, same clock
    assert abs(tr.phase("scan.compute") - sc.compute_s) \
        <= max(0.5 * sc.compute_s, 0.05)
    assert tr.wall_s >= tr.phase("scan.execute") > 0
    # counters are per-query deltas
    assert tr.counters["scan.batches"] == sc.batches
    assert tr.counters["scan.bytes_streamed"] == sc.bytes_streamed
    assert "scan.retries" not in tr.counters       # zero deltas dropped
    # the export: valid, nested, and the drain edge is cross-thread
    payload = TRACER.export_chrome()
    shape = validate_chrome_trace(payload)
    spans = {e["args"]["span_id"]: e for e in payload["traceEvents"]
             if e["ph"] == "X"}
    drains = [e for e in spans.values() if e["name"] == "scan.drain_write"]
    assert len(drains) == sc.batches
    for d in drains:
        parent = spans[d["args"]["parent_id"]]
        assert parent["name"] == "scan.batch"
        assert parent["tid"] != d["tid"]           # async drain thread
    assert shape["threads"] >= 2


def test_traced_rerun_reports_plan_cache_hit(env):
    engine, forest, _ = env
    traced_infer(engine, forest, "dense@host", batch_pages=2)
    res = traced_infer(engine, forest, "dense@host", batch_pages=2)
    assert res.reuse_hit
    assert res.trace.counters.get("plan.cache_hits") == 1
    assert "plan.cache_misses" not in res.trace.counters


def test_fault_events_exact_counts(env):
    """One transient dma fault: exactly one ``fault.injected`` and one
    ``retry`` instant in the query's summary, mirrored by the counter
    deltas, predictions unchanged."""
    engine, forest, _ = env
    ref = np.asarray(engine.infer("dense@host", forest, algorithm=FUSED,
                                  batch_pages=2).predictions)
    inj = FaultInjector().inject("page_dma_in", fail_at=2)
    res = traced_infer(engine, forest, "dense@host", batch_pages=2,
                       injector=inj, retry_policy=FAST)
    tr = res.trace
    assert tr.event_counts["fault.injected"] == 1
    assert tr.event_counts["retry"] == 1
    assert tr.counters["scan.faults_injected"] == 1
    assert tr.counters["scan.retries"] == 1
    assert np.array_equal(np.asarray(res.predictions), ref)


def test_drain_death_emits_degrade_event(env):
    engine, forest, _ = env
    inj = FaultInjector().inject("drain_worker", fail_at=1)
    res = traced_infer(engine, forest, "dense@host", batch_pages=2,
                       injector=inj, retry_policy=FAST)
    assert res.scan.degraded_to_sync
    assert res.trace.event_counts["degrade.sync_drain"] == 1
    assert res.trace.counters["scan.degraded_to_sync"] == 1


def test_disk_tier_trace_has_disk_read_spans(env):
    engine, forest, _ = env
    res = traced_infer(engine, forest, "dense@disk", batch_pages=2)
    tr = res.trace
    assert tr.span_counts["scan.disk_read"] == res.scan.batches
    validate_chrome_trace(TRACER.export_chrome())


def test_export_chrome_writes_loadable_json(env, tmp_path):
    engine, forest, _ = env
    traced_infer(engine, forest, "dense@host", batch_pages=2)
    out = tmp_path / "trace.json"
    TRACER.enable()                    # export works regardless; reset not
    payload = TRACER.export_chrome(str(out))
    on_disk = json.loads(out.read_text())
    assert on_disk == json.loads(json.dumps(payload))
    validate_chrome_trace(on_disk)
    names = {e["name"] for e in on_disk["traceEvents"] if e["ph"] == "M"}
    assert {"thread_name", "process_name"} <= names


def test_store_and_loader_spans(env):
    engine, forest, _ = env
    store = engine.store
    rng = np.random.default_rng(3)
    TRACER.enable()
    store.put("obs-put", rng.normal(size=(64, F)).astype(np.float32))
    store.move("obs-put", "host")
    TRACER.disable()
    names = [s.name for s in TRACER.finished()]
    assert "store.put" in names and "store.move" in names
    move = [s for s in TRACER.finished() if s.name == "store.move"][0]
    assert move.attrs["src"] == "device" and move.attrs["dst"] == "host"
    store.drop("obs-put")
