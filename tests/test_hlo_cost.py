"""Unit tests for the trip-count-aware HLO cost parser (launch/hlo_cost)
— the §Roofline measurement instrument gets its own oracle."""

import pytest

from repro.launch import hlo_cost
from repro.launch.roofline import model_flops
from repro.configs import SHAPES, get_config

# a minimal synthetic post-SPMD module: ENTRY calls a while loop with
# known_trip_count=4 whose body holds a dot and an all-gather, plus a
# stacked scan-xs buffer (leading dim == trip) read via fusion.
HLO = """
HloModule test

%body (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%arg), index=1
  %w = f32[16,32]{1,0} parameter(1)
  %xs = f32[4,8,16]{2,1,0} parameter(2)
  %slice = f32[8,16]{1,0} fusion(%xs, %i), kind=kLoop, calls=%fused_slice
  %d = f32[8,32]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,64]{0,1} all-gather(%d), channel_id=1, replica_groups={{0,1}}, dimensions={1}
  ROOT %t = (s32[], f32[8,16]) tuple(%i, %x)
}

%cond (arg2: (s32[], f32[8,16])) -> pred[] {
  %arg2 = (s32[], f32[8,16]) parameter(0)
  ROOT %p = pred[] constant(true)
}

%fused_slice (p0: f32[4,8,16], p1: s32[]) -> f32[8,16] {
  %p0 = f32[4,8,16]{2,1,0} parameter(0)
  %p1 = s32[] parameter(1)
  ROOT %ds = f32[8,16]{1,0} dynamic-slice(%p0, %p1), dynamic_slice_sizes={1,8,16}
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %init = (s32[], f32[8,16]) tuple(%a)
  %w2 = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_parse_module_structure():
    comps, shapes, entry = hlo_cost.parse_module(HLO)
    assert entry == "main"
    assert "body" in comps and "fused_slice" in comps
    assert shapes["d"].startswith("f32[8,32]")


def test_multiplicities_trip_count():
    comps, shapes, entry = hlo_cost.parse_module(HLO)
    mult, trips = hlo_cost._multiplicities(comps, entry)
    assert mult["main"] == 1.0
    assert mult["body"] == 4.0          # known_trip_count
    assert mult["fused_slice"] == 4.0   # fusion called from the body
    assert trips["body"] == 4.0


def test_dot_flops_scaled_by_trip():
    res = hlo_cost.analyze(HLO)
    # dot: 2 * (8*32) * 16 = 8192 flops, x4 iterations
    assert res["flops"] == pytest.approx(4 * 2 * 8 * 32 * 16)


def test_collective_scaled_by_trip():
    res = hlo_cost.analyze(HLO)
    # all-gather result f32[8,64] = 2048 B, group 2 -> operand 1024 B, x4
    assert res["collective_bytes"] == 4 * 1024
    assert res["collective_counts"] == {"all-gather": 4}


def test_scan_xs_amortization():
    """The stacked xs buffer (leading dim == trip) is charged ONCE across
    the loop, not x4."""
    res = hlo_cost.analyze(HLO)
    xs_bytes = 4 * 8 * 16 * 4
    # total bytes must include xs only ~once (amortized /4 per iter x4)
    # upper bound check: well below the naive 4x charge
    assert res["bytes"] < 4 * xs_bytes + 4 * (
        8 * 16 * 4 + 8 * 32 * 4 + 16 * 32 * 4 + 8 * 64 * 4) * 2


def test_model_flops_kinds():
    cfg = get_config("olmo-1b")
    n = cfg.active_param_count()
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    dc = model_flops(cfg, SHAPES["decode_32k"])
    assert tr == pytest.approx(6 * n * 4096 * 256)
    assert pf == pytest.approx(2 * n * 32768 * 32)
    assert dc == pytest.approx(2 * n * 128)
    # moe: active, not total
    moe = get_config("llama4-maverick-400b-a17b")
    assert model_flops(moe, SHAPES["train_4k"]) < \
        6 * moe.param_count() * 4096 * 256 * 0.2
