"""Sharding policy unit tests (no multi-device execution needed — specs
are pure data; the dry-run exercises the real 512-device lowering)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.dist.sharding import make_plan, param_specs, cache_specs

pytestmark = pytest.mark.filterwarnings("ignore:.*axis_types.*")


class FakeMesh:
    """Stand-in with .shape/.axis_names (spec rules only consume these)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})


def test_attn_mode_selection():
    expect_tp = {"olmo-1b", "seamless-m4t-large-v2", "zamba2-2.7b"}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        plan = make_plan(cfg, MESH)  # type: ignore[arg-type]
        want = "tp" if arch in expect_tp else "cp"
        if cfg.num_heads == 0:       # mamba2: attention-free, mode unused
            continue
        assert plan.attn_mode == want, arch


def test_plan_single_device_is_empty_specs():
    plan = make_plan(get_config("olmo-1b"), None)
    assert plan.hidden == P() and plan.mesh is None


def test_param_specs_rules():
    params = {
        "embed": jnp.zeros((50304, 2048)),
        "blocks": {"p0": {"attn": {"wq": jnp.zeros((16, 2048, 2048)),
                                   "bq": jnp.zeros((16, 2048))},
                          "moe": {"wi": jnp.zeros((16, 32, 2048, 4096)),
                                  "router": jnp.zeros((2048, 32))}}},
        "final_norm": {"scale": jnp.zeros((2048,))},
    }
    specs = param_specs(params, MESH)  # type: ignore[arg-type]
    # stacked dim 0 never sharded
    wq = specs["blocks"]["p0"]["attn"]["wq"]
    assert wq[0] is None and set(wq) >= {"model", "data", None}
    # MoE expert dim pinned to model
    wi = specs["blocks"]["p0"]["moe"]["wi"]
    assert wi[1] == "model"
    # embed: d_model on model, vocab on data
    assert specs["embed"] == P("data", "model")
    # small leaves replicated
    assert specs["final_norm"]["scale"] == P()
    assert specs["blocks"]["p0"]["moe"]["router"] == P(None, "model") or \
        specs["blocks"]["p0"]["moe"]["router"] == P()


def test_param_specs_divisibility():
    """Every sharded dim must divide the axis size (the dry-run's
    lowering would reject uneven shards for these rules)."""
    from repro.models import get_bundle
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        bundle = get_bundle(cfg)
        sds = jax.eval_shape(
            lambda k: bundle.init(cfg, k, dtype=jnp.bfloat16),
            jax.random.PRNGKey(0))
        specs = param_specs(sds, MESH)  # type: ignore[arg-type]

        def check(path, leaf, spec):
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                size = {"data": 16, "model": 16}[ax]
                assert leaf.shape[dim] % size == 0, (arch, path, leaf.shape)
        jax.tree_util.tree_map_with_path(
            lambda p, l, s: check(p, l, s), sds, specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def test_cache_specs_shapes():
    cfg = get_config("zamba2-2.7b")
    from repro.models.lm import init_caches
    caches = jax.eval_shape(lambda: init_caches(cfg, 128, 1024))
    plan = make_plan(cfg, MESH, decode_batch=128)  # type: ignore[arg-type]
    specs = cache_specs(caches, plan)

    def check(path, leaf, spec):
        name = str(getattr(path[-1], "key", ""))
        if hasattr(leaf, "ndim") and leaf.ndim:
            assert len(spec) <= leaf.ndim, (name, leaf.shape, spec)
    jax.tree_util.tree_map_with_path(
        check, caches, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def test_decode_small_batch_plan():
    cfg = get_config("llama4-scout-17b-a16e")
    plan = make_plan(cfg, MESH, decode_batch=1)  # type: ignore[arg-type]
    # cache S axis sharded over everything, batch replicated
    assert plan.decode_cache[0] is None
    assert plan.decode_cache[1] == ("data", "model")
    assert plan.ssm_state[0] is None


def test_roofline_collective_parser():
    from repro.launch.roofline import parse_collectives
    hlo = """
  %ag = f32[512,1024]{0,1} all-gather(%x), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %ar = bf16[256]{0} all-reduce(%y), replica_groups=[4,64]<=[256], to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = u32[8,2]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
"""
    stats = parse_collectives(hlo)
    assert stats.counts == {"all-gather": 1, "all-reduce": 1,
                            "reduce-scatter": 1, "collective-permute": 1}
    ag = 512 * 1024 * 4
    assert stats.bytes_by_kind["all-gather"] == ag // 4
    assert stats.bytes_by_kind["all-reduce"] == 256 * 2
    assert stats.bytes_by_kind["reduce-scatter"] == 64 * 4 * 4
    assert stats.bytes_by_kind["collective-permute"] == 8 * 2 * 4
    assert stats.total_wire_bytes > 0


def test_roofline_terms_dominance():
    from repro.launch.roofline import roofline_terms
    t = roofline_terms(flops_per_chip=197e12, bytes_per_chip=1.0,
                       coll_bytes_per_chip=1.0)
    assert t["dominant"] == "compute_s"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    t2 = roofline_terms(flops_per_chip=1.0, bytes_per_chip=819e9 * 2,
                        coll_bytes_per_chip=1.0)
    assert t2["dominant"] == "memory_s"
