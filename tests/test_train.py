"""Trainer/optimizer behaviour: losses decrease, accumulation is exact,
data is replay-deterministic, compression bounds error."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.dist.sharding import make_plan
from repro.train.data import DataConfig, synthetic_batch
from repro.train.optimizer import (OptimizerConfig, clip_by_global_norm,
                                   global_norm, make_optimizer)
from repro.train.trainer import init_state, make_train_step

KEY = jax.random.PRNGKey(0)
CFG = reduced(get_config("olmo-1b"))


def _run(opt_name, steps=12, **okw):
    opt = make_optimizer(OptimizerConfig(name=opt_name, lr=3e-3,
                                         warmup_steps=2, total_steps=100,
                                         **okw))
    splan = make_plan(CFG, None)
    step = jax.jit(make_train_step(CFG, opt, splan))
    state = init_state(CFG, opt, KEY, dtype=jnp.float32)
    dc = DataConfig(seed=3, vocab_size=CFG.vocab_size, batch=8, seq_len=64)
    losses = []
    for k in range(steps):
        state, m = step(state, synthetic_batch(dc, k))
        losses.append(float(m["loss"]))
    return losses


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor", "sgd"])
def test_loss_decreases(opt_name):
    losses = _run(opt_name)
    assert losses[-1] < losses[0], f"{opt_name}: {losses[0]} -> {losses[-1]}"
    assert all(np.isfinite(l) for l in losses)


def test_grad_accumulation_matches_single_batch():
    """2 microbatches of B/2 must equal one batch of B (same grads)."""
    opt = make_optimizer(OptimizerConfig(name="sgd", lr=1e-2,
                                         warmup_steps=0, grad_clip=1e9))
    splan = make_plan(CFG, None)
    step1 = jax.jit(make_train_step(CFG, opt, splan, microbatches=1))
    step2 = jax.jit(make_train_step(CFG, opt, splan, microbatches=2))
    state = init_state(CFG, opt, KEY, dtype=jnp.float32)
    dc = DataConfig(seed=1, vocab_size=CFG.vocab_size, batch=8, seq_len=32)
    batch = synthetic_batch(dc, 0)
    s1, m1 = step1(state, batch)
    s2, m2 = step2(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), s1["params"],
        s2["params"])
    assert max(jax.tree_util.tree_leaves(diffs)) < 2e-5


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(800.0), rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_data_determinism():
    dc = DataConfig(seed=11, vocab_size=100, batch=4, seq_len=16)
    b1 = synthetic_batch(dc, 7)
    b2 = synthetic_batch(dc, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synthetic_batch(dc, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_labels_are_shifted_tokens():
    dc = DataConfig(seed=2, vocab_size=50, batch=2, seq_len=10)
    b = synthetic_batch(dc, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_int8_quantization_error_bound():
    from repro.dist.compression import dequantize_int8, quantize_int8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_mean_preserving():
    """EF: accumulated quantized grads converge to the true mean."""
    from repro.dist.compression import (compress_with_error_feedback,
                                        init_error_feedback)
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(32,)).astype(np.float32))}
    ef = init_error_feedback(g)
    total = np.zeros(32, np.float32)
    for _ in range(50):
        qg, ef = compress_with_error_feedback(g, ef)
        total += np.asarray(qg["w"])
    np.testing.assert_allclose(total / 50, np.asarray(g["w"]),
                               atol=2e-3)


def test_chunked_xent_matches_dense():
    from repro.models.lm import chunked_xent
    rng = np.random.default_rng(5)
    B, S, D, V = 2, 6, 16, 103
    h = jnp.asarray(rng.normal(size=(B, S, D)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(D, V)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, V, (B, S)).astype(np.int32))
    labels = labels.at[0, 0].set(-1)  # a padded position
    got = chunked_xent(h, w, labels, vocab_chunk=32)
    logits = np.asarray(h) @ np.asarray(w)
    lse = np.log(np.exp(logits - logits.max(-1, keepdims=True)).sum(-1)) \
        + logits.max(-1)
    lab = np.asarray(labels)
    nll = lse - np.take_along_axis(logits, np.maximum(lab, 0)[..., None],
                                   -1)[..., 0]
    mask = lab >= 0
    want = (nll * mask).sum() / mask.sum()
    np.testing.assert_allclose(float(got), want, rtol=1e-5)


def test_chunked_xent_gradient_matches_dense():
    from repro.models.lm import chunked_xent
    rng = np.random.default_rng(6)
    B, S, D, V = 2, 4, 8, 33
    h = jnp.asarray(rng.normal(size=(B, S, D)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(D, V)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, V, (B, S)).astype(np.int32))

    def dense_loss(w_):
        logits = jnp.einsum("bsd,dv->bsv", h, w_)
        lse = jax.nn.logsumexp(logits, -1)
        tgt = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        return jnp.mean(lse - tgt)

    g1 = jax.grad(lambda w_: chunked_xent(h, w_, labels, vocab_chunk=8))(w)
    g2 = jax.grad(dense_loss)(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-5)
