"""In-JAX forest training: the three model families must actually learn."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.postprocess import predict_label, predict_proba
from repro.core.train import TrainConfig, bin_features, quantile_bin_edges, \
    train_forest


def _blobs(n=600, f=6, seed=0, nan_frac=0.0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, f)).astype(np.float32)
    w = rng.normal(size=f).astype(np.float32)
    y = (x @ w + 0.1 * rng.normal(size=n) > 0).astype(np.float32)
    if nan_frac:
        x[rng.random(x.shape) < nan_frac] = np.nan
    return x, y


@pytest.mark.parametrize("model_type", ["randomforest", "xgboost",
                                        "lightgbm"])
def test_classification_learns(model_type):
    x, y = _blobs(seed=1)
    cfg = TrainConfig(model_type=model_type, num_trees=20, max_depth=5,
                      learning_rate=0.3, seed=0)
    forest = train_forest(x, y, cfg)
    pred = np.asarray(predict_label(forest, jnp.asarray(x)))
    acc = (pred == y).mean()
    assert acc > 0.85, f"{model_type} train acc {acc}"


def test_regression_learns():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(500, 5)).astype(np.float32)
    y = (x[:, 0] * 2 - x[:, 1]).astype(np.float32)
    cfg = TrainConfig(model_type="xgboost", task="regression",
                      num_trees=30, max_depth=4, learning_rate=0.3)
    forest = train_forest(x, y, cfg)
    pred = np.asarray(predict_proba(forest, jnp.asarray(x)))
    mse0 = np.mean((y - y.mean()) ** 2)
    mse = np.mean((y - pred) ** 2)
    assert mse < 0.5 * mse0, f"regression mse {mse} vs baseline {mse0}"


def test_missing_values_learned_default_direction():
    """Sparsity-aware splits (the Bosch/Criteo regime): NaN-heavy features
    must not break training, and inference must route NaN via the learned
    default direction."""
    x, y = _blobs(seed=3, nan_frac=0.3)
    cfg = TrainConfig(model_type="xgboost", num_trees=25, max_depth=5,
                      learning_rate=0.3)
    forest = train_forest(x, y, cfg)
    pred = np.asarray(predict_label(forest, jnp.asarray(x)))
    acc = (pred == y).mean()
    assert acc > 0.75, f"acc with 30% missing {acc}"


def test_binning_roundtrip():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(200, 3)).astype(np.float32)
    edges = quantile_bin_edges(x, 16)
    assert edges.shape == (3, 15)
    b = np.asarray(bin_features(x, edges))
    assert b.min() >= 0 and b.max() <= 15
    xn = x.copy()
    xn[0, 0] = np.nan
    bn = np.asarray(bin_features(xn, edges))
    assert bn[0, 0] == 16  # MISSING slot


def test_deterministic_given_seed():
    x, y = _blobs(seed=5)
    cfg = TrainConfig(model_type="lightgbm", num_trees=5, max_depth=4,
                      seed=9)
    f1 = train_forest(x, y, cfg)
    f2 = train_forest(x, y, cfg)
    for k, a in f1.arrays().items():
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(getattr(f2, k)), err_msg=k)
