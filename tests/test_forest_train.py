"""In-JAX forest training: the three model families must actually learn."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.postprocess import predict_label, predict_proba
from repro.core.train import TrainConfig, bin_features, quantile_bin_edges, \
    train_forest


def _blobs(n=600, f=6, seed=0, nan_frac=0.0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, f)).astype(np.float32)
    w = rng.normal(size=f).astype(np.float32)
    y = (x @ w + 0.1 * rng.normal(size=n) > 0).astype(np.float32)
    if nan_frac:
        x[rng.random(x.shape) < nan_frac] = np.nan
    return x, y


@pytest.mark.parametrize("model_type", ["randomforest", "xgboost",
                                        "lightgbm"])
def test_classification_learns(model_type):
    x, y = _blobs(seed=1)
    cfg = TrainConfig(model_type=model_type, num_trees=20, max_depth=5,
                      learning_rate=0.3, seed=0)
    forest = train_forest(x, y, cfg)
    pred = np.asarray(predict_label(forest, jnp.asarray(x)))
    acc = (pred == y).mean()
    assert acc > 0.85, f"{model_type} train acc {acc}"


def test_regression_learns():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(500, 5)).astype(np.float32)
    y = (x[:, 0] * 2 - x[:, 1]).astype(np.float32)
    cfg = TrainConfig(model_type="xgboost", task="regression",
                      num_trees=30, max_depth=4, learning_rate=0.3)
    forest = train_forest(x, y, cfg)
    pred = np.asarray(predict_proba(forest, jnp.asarray(x)))
    mse0 = np.mean((y - y.mean()) ** 2)
    mse = np.mean((y - pred) ** 2)
    assert mse < 0.5 * mse0, f"regression mse {mse} vs baseline {mse0}"


def test_missing_values_learned_default_direction():
    """Sparsity-aware splits (the Bosch/Criteo regime): NaN-heavy features
    must not break training, and inference must route NaN via the learned
    default direction."""
    x, y = _blobs(seed=3, nan_frac=0.3)
    cfg = TrainConfig(model_type="xgboost", num_trees=25, max_depth=5,
                      learning_rate=0.3)
    forest = train_forest(x, y, cfg)
    pred = np.asarray(predict_label(forest, jnp.asarray(x)))
    acc = (pred == y).mean()
    assert acc > 0.75, f"acc with 30% missing {acc}"


def test_binning_roundtrip():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(200, 3)).astype(np.float32)
    edges = quantile_bin_edges(x, 16)
    assert edges.shape == (3, 15)
    b = np.asarray(bin_features(x, edges))
    assert b.min() >= 0 and b.max() <= 15
    xn = x.copy()
    xn[0, 0] = np.nan
    bn = np.asarray(bin_features(xn, edges))
    assert bn[0, 0] == 16  # MISSING slot


def test_deterministic_given_seed():
    x, y = _blobs(seed=5)
    cfg = TrainConfig(model_type="lightgbm", num_trees=5, max_depth=4,
                      seed=9)
    f1 = train_forest(x, y, cfg)
    f2 = train_forest(x, y, cfg)
    for k, a in f1.arrays().items():
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(getattr(f2, k)), err_msg=k)


# ---------------------------------------------------------------------------
# GOSS sampling (LightGBM's a/b keep-top + upweight-rest scheme)
# ---------------------------------------------------------------------------


def _goss_weights(seed=11, n=4000, a=0.2, b=0.1):
    """The per-row GOSS weight w implied by (g_goss / g_plain)."""
    import jax
    import jax.numpy as jnp2
    from repro.core.train import _tree_gradients
    rng = np.random.default_rng(seed)
    margin = rng.normal(size=n).astype(np.float32)
    y = (rng.random(n) < 0.5).astype(np.float32)
    cfg = TrainConfig(model_type="lightgbm", goss_top=a, goss_rest=b)
    cfg_x = TrainConfig(model_type="xgboost")
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    g, h = _tree_gradients(margin, jnp2.asarray(y), cfg, 1,
                           keys[0], keys[1])
    g0, h0 = _tree_gradients(margin, jnp2.asarray(y), cfg_x, 1,
                             keys[0], keys[1])
    with np.errstate(divide="ignore", invalid="ignore"):
        w = np.where(np.abs(g0) > 0, g / g0, h / np.maximum(h0, 1e-12))
    return w, g0, a, b


def test_goss_keep_and_sample_mass():
    """Top-a rows by |g| ALL survive at weight 1; of the rest, ~b are
    kept; everything else is dropped (weight 0)."""
    w, g0, a, b = _goss_weights()
    n = w.shape[0]
    order = np.argsort(-np.abs(g0))
    top, rest = order[: int(a * n)], order[int(a * n):]
    np.testing.assert_allclose(w[top], 1.0, atol=1e-5)
    kept_rest = np.abs(w[rest]) > 1e-6
    assert abs(kept_rest.mean() - b) < 0.02, kept_rest.mean()
    assert (np.abs(w[rest][~kept_rest]) < 1e-6).all()


def test_goss_rest_upweighting():
    """Sampled rest rows carry the (1-a)/b compensation weight, so the
    rest stratum's expected total mass is preserved."""
    w, g0, a, b = _goss_weights()
    n = w.shape[0]
    rest = np.argsort(-np.abs(g0))[int(a * n):]
    kept = w[rest][np.abs(w[rest]) > 1e-6]
    np.testing.assert_allclose(kept, (1 - a) / b, rtol=1e-4)
    # expected stratum mass: each rest row contributes b * (1-a)/b = 1-a
    # in expectation, so the mean rest weight concentrates around 1-a
    assert abs(w[rest].mean() - (1 - a)) < 0.1


def test_goss_first_tree_sees_all_rows():
    """LightGBM convention: tree 0 trains on the full gradient set."""
    import jax
    import jax.numpy as jnp2
    from repro.core.train import _tree_gradients
    rng = np.random.default_rng(12)
    margin = rng.normal(size=500).astype(np.float32)
    y = (rng.random(500) < 0.5).astype(np.float32)
    keys = jax.random.split(jax.random.PRNGKey(3), 2)
    g_l, h_l = _tree_gradients(margin, jnp2.asarray(y),
                               TrainConfig(model_type="lightgbm"), 0,
                               keys[0], keys[1])
    g_x, h_x = _tree_gradients(margin, jnp2.asarray(y),
                               TrainConfig(model_type="xgboost"), 0,
                               keys[0], keys[1])
    np.testing.assert_array_equal(g_l, g_x)
    np.testing.assert_array_equal(h_l, h_x)


# ---------------------------------------------------------------------------
# NaN default-direction learning
# ---------------------------------------------------------------------------


def test_nan_default_direction_actually_routes_missing():
    """Label depends ONLY on missingness of feature 0: the split search
    must learn the default direction that routes NaN rows to their own
    side (XGBoost's sparsity-aware split), or accuracy stays ~0.5."""
    rng = np.random.default_rng(13)
    n = 800
    x = rng.normal(size=(n, 4)).astype(np.float32)
    miss = rng.random(n) < 0.5
    x[miss, 0] = np.nan
    y = miss.astype(np.float32)
    cfg = TrainConfig(model_type="xgboost", num_trees=10, max_depth=2,
                      learning_rate=0.5)
    forest = train_forest(x, y, cfg)
    pred = np.asarray(predict_label(forest, jnp.asarray(x)))
    assert (pred == y).mean() > 0.97
    # fresh NaN rows (never seen) must route to the missing side too
    x_new = rng.normal(size=(64, 4)).astype(np.float32)
    x_new[:, 0] = np.nan
    assert np.asarray(predict_label(forest, jnp.asarray(x_new))).mean() \
        > 0.97


def test_regression_lightgbm_learns():
    rng = np.random.default_rng(14)
    x = rng.normal(size=(600, 5)).astype(np.float32)
    y = (np.sin(x[:, 0]) + 0.5 * x[:, 1]).astype(np.float32)
    cfg = TrainConfig(model_type="lightgbm", task="regression",
                      num_trees=40, max_depth=4, learning_rate=0.2)
    forest = train_forest(x, y, cfg)
    pred = np.asarray(predict_proba(forest, jnp.asarray(x)))
    mse0 = np.mean((y - y.mean()) ** 2)
    assert np.mean((y - pred) ** 2) < 0.4 * mse0


# ---------------------------------------------------------------------------
# reg_lambda: monotone leaf shrinkage
# ---------------------------------------------------------------------------


def test_reg_lambda_monotone_leaf_shrinkage():
    """With the tree structure pinned (one strong feature), growing L2
    shrinks every leaf weight monotonically: |leaf| ~ |G| / (H + lam)."""
    rng = np.random.default_rng(15)
    x = rng.normal(size=(500, 1)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.float32)
    prev_feat = prev_thr = None
    prev_mag = np.inf
    mags = []
    for lam in (0.0, 1.0, 10.0, 100.0):
        cfg = TrainConfig(model_type="xgboost", num_trees=1, max_depth=1,
                          reg_lambda=lam, learning_rate=1.0)
        f = train_forest(x, y, cfg)
        if prev_feat is not None:  # same split, only the weights move
            np.testing.assert_array_equal(np.asarray(f.feature), prev_feat)
            np.testing.assert_array_equal(np.asarray(f.threshold), prev_thr)
        prev_feat = np.asarray(f.feature)
        prev_thr = np.asarray(f.threshold)
        mag = np.abs(np.asarray(f.leaf_value)).max()
        assert mag < prev_mag or np.isclose(mag, prev_mag), (lam, mag)
        prev_mag = mag
        mags.append(mag)
    # |leaf| = |G| / (H + lam): lam=100 against leaf hessians of ~60
    # must shrink the lam=0 weight by well over half
    assert mags[-1] < 0.5 * mags[0], mags


# ---------------------------------------------------------------------------
# depth / node-budget edge cases
# ---------------------------------------------------------------------------


def test_depth_one_stump():
    x, y = _blobs(seed=16)
    cfg = TrainConfig(model_type="xgboost", num_trees=8, max_depth=1,
                      learning_rate=0.5)
    forest = train_forest(x, y, cfg)
    assert forest.depth == 1 and forest.leaf_value.shape == (8, 2)
    pred = np.asarray(predict_label(forest, jnp.asarray(x)))
    assert (pred == y).mean() > 0.6  # stumps on a linear blob


def test_min_split_gain_makes_all_nodes_terminal():
    """An unreachable gain floor collapses every tree to one leaf: the
    terminal chain passes all rows left, so predictions are constant."""
    x, y = _blobs(seed=17)
    cfg = TrainConfig(model_type="xgboost", num_trees=3, max_depth=3,
                      min_split_gain=1e9)
    forest = train_forest(x, y, cfg)
    assert np.asarray(forest.node_is_leaf).all()
    assert np.isinf(np.asarray(forest.threshold)).all()
    raw = np.asarray(predict_proba(forest, jnp.asarray(x)))
    np.testing.assert_allclose(raw, raw[0], atol=0)


def test_min_child_weight_blocks_splits():
    """A child-hessian floor above the dataset's total weight forbids
    every split (the OTHER node-budget path to a terminal root)."""
    x, y = _blobs(n=200, seed=18)
    cfg = TrainConfig(model_type="xgboost", num_trees=2, max_depth=3,
                      min_child_weight=1e6)
    forest = train_forest(x, y, cfg)
    assert np.asarray(forest.node_is_leaf).all()


def test_rf_colsample_restricts_split_features():
    """Per-tree feature subsampling: each RF tree may only split on its
    drawn half of the features (terminal nodes record feature 0)."""
    x, y = _blobs(n=800, f=8, seed=19)
    cfg = TrainConfig(model_type="randomforest", num_trees=6, max_depth=4,
                      colsample=0.5, seed=2)
    forest = train_forest(x, y, cfg)
    feat = np.asarray(forest.feature)
    leaf = np.asarray(forest.node_is_leaf)
    k = int(round(0.5 * 8))
    masks = set()
    for t in range(cfg.num_trees):
        used = frozenset(np.unique(feat[t][~leaf[t]]).tolist())
        assert len(used) <= k, f"tree {t} split on {sorted(used)}"
        masks.add(used)
    assert len(masks) > 1, "every tree drew the same feature subset"


def test_rf_trees_differ_by_bootstrap():
    """Poisson bagging: RF trees must not be clones of each other."""
    x, y = _blobs(seed=20)
    cfg = TrainConfig(model_type="randomforest", num_trees=4, max_depth=4)
    forest = train_forest(x, y, cfg)
    lv = np.asarray(forest.leaf_value)
    assert any(not np.array_equal(lv[0], lv[t]) for t in range(1, 4))


def test_explicit_edges_match_internal_binning():
    """train_forest(edges=...) with the exact-quantile edges is the
    identity — the hook the streamed trainer's parity contract uses."""
    x, y = _blobs(seed=21, nan_frac=0.1)
    cfg = TrainConfig(model_type="xgboost", num_trees=4, max_depth=3)
    f1 = train_forest(x, y, cfg)
    f2 = train_forest(x, y, cfg, edges=quantile_bin_edges(x, cfg.num_bins))
    for k, a in f1.arrays().items():
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(getattr(f2, k)), err_msg=k)
