"""LM -> forest ranking fusion: the paper's motivating scenario (search
ranking with decision forests over learned features) end-to-end and
device-resident — the 'in-database' story applied to an LLM serving
stack.

    PYTHONPATH=src python examples/rank_fusion.py

Pipeline: a reduced LM encodes candidate documents into features (mean
hidden state); a forest ranker trained on those features scores
query-document pairs; BOTH stages run where the data lives (no host
round-trip between LM features and forest scoring — the paper's
data-management gap, closed).  Compare against the 'decoupled' path that
writes features to a file and reloads them (what Sklearn/ONNX-class
deployments do).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.postprocess import predict_proba
from repro.core.train import TrainConfig, train_forest
from repro.core.reuse import ModelReuseCache
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore
from repro.models import get_bundle
from repro.models.lm import lm_hidden


def main():
    rng = np.random.default_rng(0)
    cfg = reduced(get_config("olmo-1b"))
    bundle = get_bundle(cfg)
    params = bundle.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

    # 1. encode 512 'documents' (token sequences) into LM features
    docs = rng.integers(0, cfg.vocab_size, (512, 32)).astype(np.int32)
    encode = jax.jit(lambda t: jnp.mean(
        lm_hidden(cfg, params, t), axis=1))      # [N, D]
    feats = np.asarray(encode(jnp.asarray(docs)))
    print(f"encoded {feats.shape[0]} docs -> {feats.shape[1]}-d features")

    # 2. train a forest ranker on (features, relevance) pairs
    w = rng.normal(size=feats.shape[1]).astype(np.float32)
    relevance = (feats @ w > np.median(feats @ w)).astype(np.float32)
    ranker = train_forest(feats[:384], relevance[:384], TrainConfig(
        model_type="lightgbm", num_trees=64, max_depth=5,
        learning_rate=0.3))

    # 3a. FUSED in-database path: features stay device-resident
    store = TensorBlockStore(default_page_rows=64)
    t0 = time.perf_counter()
    store.put("doc_feats", feats[384:])
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
    res = engine.infer("doc_feats", ranker, algorithm="quickscorer",
                       plan="udf")
    fused_s = time.perf_counter() - t0
    scores = np.asarray(res.predictions)

    # 3b. DECOUPLED path: features -> file -> reload -> score
    t0 = time.perf_counter()
    np.save("/tmp/feats.npy", feats[384:])
    reloaded = jnp.asarray(np.load("/tmp/feats.npy"))
    scores2 = np.asarray(predict_proba(ranker, reloaded,
                                       algorithm="quickscorer"))
    decoupled_s = time.perf_counter() - t0

    np.testing.assert_allclose(scores, scores2, rtol=1e-5, atol=1e-6)
    acc = ((scores > 0.5) == relevance[384:]).mean()
    top = np.argsort(-scores)[:5]
    print(f"ranker holdout accuracy: {acc:.3f}")
    print(f"top-5 docs: {top.tolist()}")
    print(f"fused in-db path: {fused_s*1e3:.1f} ms | decoupled "
          f"file path: {decoupled_s*1e3:.1f} ms")


if __name__ == "__main__":
    main()
