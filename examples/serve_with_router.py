"""Serve a small LM with batched requests through the forest router.

    PYTHONPATH=src python examples/serve_with_router.py

A synthetic request trace flows through: forest router (tier decision,
in-process) -> continuous-batching engine (per-slot caches, priority
admission) -> greedy decode.  Prints tiering + latency/throughput stats.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import get_bundle
from repro.serve.engine import ServeEngine
from repro.serve.router import ForestRouter, request_features


def main():
    cfg = reduced(get_config("qwen2-7b"))
    bundle = get_bundle(cfg)
    params = bundle.init(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    engine = ServeEngine(cfg, params, slots=4, max_ctx=128,
                         prompt_buckets=(16, 32), dtype=jnp.float32)
    router = ForestRouter(seed=0)

    rng = np.random.default_rng(0)
    tiers = [0, 0]
    for i in range(16):
        plen = int(rng.integers(4, 30))
        mnt = int(rng.integers(2, 12))
        feats = request_features(plen, mnt, len(engine._queue),
                                 len(engine._active), 16.0)
        tier = router.route(feats)
        tiers[tier] += 1
        engine.submit(rng.integers(0, cfg.vocab_size, plen),
                      max_new_tokens=mnt, priority=tier)

    done = engine.run_until_drained()
    assert len(done) == 16
    print(f"routed: {tiers[0]} interactive, {tiers[1]} batch")
    for k, v in engine.stats().items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
