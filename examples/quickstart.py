"""Quickstart: train a forest in-JAX, store data in the tensor-block
store, run the paper's three physical plans end-to-end, and stream a
larger-than-device-budget dataset through the host tier — then one
larger than the host budget too through disk-tier mmap pages.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core.postprocess import predict_proba
from repro.core.train import TrainConfig, train_forest
from repro.core.reuse import ModelReuseCache
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore


def main():
    # 1. data + a ground-truth rule
    rng = np.random.default_rng(0)
    x = rng.normal(size=(5000, 16)).astype(np.float32)
    w = rng.normal(size=16).astype(np.float32)
    y = (x @ w > 0).astype(np.float32)

    # 2. train an XGBoost-style forest (paper Sec. 4 hyper-params)
    forest = train_forest(x[:4000], y[:4000], TrainConfig(
        model_type="xgboost", num_trees=100, max_depth=6,
        learning_rate=0.3))
    test_x, test_y = x[4000:], y[4000:]
    acc = float(((np.asarray(predict_proba(forest, jnp.asarray(test_x)))
                  > 0.5) == test_y).mean())
    print(f"test accuracy: {acc:.3f}")

    # 3. in-database inference: store the test set, run all three plans
    store = TensorBlockStore(default_page_rows=256)
    store.put("testset", test_x)
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
    for plan in ("udf", "rel", "rel+reuse", "rel+reuse"):
        res = engine.infer("testset", forest, algorithm="predicated",
                           plan=plan, write_as="predictions")
        print(f"plan={plan:10s} stages={res.num_stages} "
              f"reuse_hit={res.reuse_hit} "
              f"breakdown={res.breakdown()}")

    # 4. algorithm backends agree (paper F1 axis)
    for algo in ("naive", "predicated", "compiled", "hummingbird",
                 "quickscorer"):
        p = predict_proba(forest, jnp.asarray(test_x[:64]), algorithm=algo)
        print(f"algo={algo:12s} first-8 preds: "
              f"{np.round(np.asarray(p[:8]), 3)}")

    # 5. out-of-core: a dataset LARGER than the device budget auto-spills
    # to host-tier pages and streams through the double-buffered scan
    # executor — same plans, same predictions, no HBM ceiling
    big_x = rng.normal(size=(40_000, 16)).astype(np.float32)
    big_store = TensorBlockStore(default_page_rows=256,
                                 device_budget_bytes=big_x.nbytes // 4)
    big = big_store.put("bigset", big_x)       # tier="auto" -> spills
    print(f"\ndataset {big.nbytes // 1024} KiB vs "
          f"{big_store.device_budget_bytes // 1024} KiB device budget "
          f"-> tier={big.tier}")
    big_engine = ForestQueryEngine(big_store,
                                   reuse_cache=ModelReuseCache())
    res = big_engine.infer("bigset", forest, algorithm="predicated",
                           plan="udf")
    s = res.scan
    print(f"streamed {s.batches} page batches "
          f"({s.batch_pages} pages/batch, {s.bytes_streamed // 1024} KiB "
          f"host->device), max {s.max_in_flight} buffers in flight, "
          f"exposed transfer wait {s.transfer_wait_s * 1e3:.2f} ms")

    # 6. the bottom rung: a HOST budget too sends the ingest to disk-tier
    # mmap page files — the scan reads lazy memmap views and the async
    # drain fills the result buffer off the compute thread
    disk_store = TensorBlockStore(default_page_rows=256,
                                  device_budget_bytes=big_x.nbytes // 4,
                                  host_budget_bytes=big_x.nbytes // 4)
    disk = disk_store.put("bigset", big_x)     # auto cascade -> disk
    print(f"\nsame dataset vs device AND host budgets -> tier={disk.tier}")
    disk_engine = ForestQueryEngine(disk_store,
                                    reuse_cache=ModelReuseCache())
    res_d = disk_engine.infer("bigset", forest, algorithm="predicated",
                              plan="udf")
    sd = res_d.scan
    same = np.array_equal(np.asarray(res_d.predictions),
                          np.asarray(res.predictions))
    print(f"streamed {sd.batches} batches from mmap pages, drain "
          f"async={sd.drain_async} (worker wrote {sd.drain_s * 1e3:.2f} ms, "
          f"compute thread blocked {sd.drain_wait_s * 1e3:.2f} ms), "
          f"bit-identical to host-tier run: {same}")


if __name__ == "__main__":
    main()
