"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the fault-tolerant loop (checkpoint + deterministic replay).

    PYTHONPATH=src python examples/train_lm.py --steps 200

Uses a width-reduced olmo-1b (~100M params at d_model=512, 8 layers) on
the deterministic synthetic pipeline; loss drops from ~ln(V) as the model
learns the pattern structure.  The same entry points run the full configs
on a pod (launch/train.py).
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.dist.sharding import make_plan
from repro.train.data import DataConfig, synthetic_batch
from repro.train.fault import TrainLoop
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.trainer import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("olmo-1b"), num_layers=args.layers,
        d_model=args.d_model, num_heads=args.d_model // 64,
        num_kv_heads=args.d_model // 64, head_dim=64,
        d_ff=4 * args.d_model, vocab_size=50304, remat=False)
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model})")

    opt = make_optimizer(OptimizerConfig(
        name="adamw", lr=3e-4, warmup_steps=20, total_steps=args.steps))
    splan = make_plan(cfg, None)
    step_fn = jax.jit(make_train_step(cfg, opt, splan))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))

    dc = DataConfig(seed=0, vocab_size=cfg.vocab_size, batch=args.batch,
                    seq_len=args.seq)
    loop = TrainLoop(step_fn, lambda k: synthetic_batch(dc, k),
                     ckpt_dir=args.ckpt_dir, ckpt_every=100)
    state, report = loop.run(state, args.steps)
    print(f"loss: {report.losses[0]:.3f} -> {report.losses[-1]:.3f} "
          f"over {args.steps} steps "
          f"({sum(report.step_times)/len(report.step_times):.2f}s/step)")
    assert report.losses[-1] < report.losses[0]


if __name__ == "__main__":
    main()
