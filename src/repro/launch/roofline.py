"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh) cell, all in SECONDS (per step,
per chip — the compiled SPMD module is the per-device program, so
``cost_analysis`` FLOPs/bytes and the HLO collective operand sizes are
already per-chip quantities):

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s          (197e12 bf16 v5e)
  memory     = HLO_bytes_per_chip / HBM_bandwidth        (819e9 B/s)
  collective = collective_operand_bytes_per_chip / ICI   (50e9 B/s/link)

collective bytes are parsed from ``compiled.as_text()``: the summed
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (the convention the task
spec fixes; ring-algorithm wire amplification is NOT applied — it is a
constant ≈(n-1)/n ≈ 1 factor at n=16).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

from repro.launch.mesh import V5E

__all__ = ["CollectiveStats", "parse_collectives", "roofline_terms",
           "model_flops"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# a result shape: dtype[dims]{layout}?  e.g.  bf16[16,512]{1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# instruction line:  %name = <shape or (tuple)> opcode(...)
_INSTR_RE = re.compile(
    r"=\s+(\([^)]*\)|[\w\[\]{},]+)\s+(" + "|".join(_COLLECTIVES) +
    r")(-start|-done)?\(")
# replica_groups={{0,1,..},{..}}  or iota form  replica_groups=[16,16]<=[256]
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(result_str: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result_str))


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))          # [n_groups, group_size]
    return 1


@dataclasses.dataclass
class CollectiveStats:
    """Per-device collective traffic, two conventions:

    operand bytes  — the spec's convention: what each device CONTRIBUTES
                     (all-gather: its shard; all-reduce: the full buffer;
                     reduce-scatter: the full input; all-to-all /
                     permute: the local buffer);
    wire bytes     — ring-algorithm estimate of what actually crosses each
                     device's links (all-reduce ≈ 2× buffer, etc.).
    """
    counts: dict[str, int]
    bytes_by_kind: dict[str, int]       # operand-bytes convention
    wire_bytes_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_wire_bytes(self) -> int:
        return sum(self.wire_bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    by_kind: dict[str, int] = {}
    wire: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        result_str, kind, startdone = m.group(1), m.group(2), m.group(3)
        if startdone == "-done":
            continue                     # paired with its -start
        r = _result_bytes(result_str)    # per-device result buffer bytes
        g = max(_group_size(line), 1)
        if kind == "all-gather":
            operand, w = r // max(g, 1), r * (g - 1) // max(g, 1)
        elif kind == "all-reduce":
            operand, w = r, 2 * r * (g - 1) // max(g, 1)
        elif kind == "reduce-scatter":
            operand, w = r * g, r * (g - 1)
        elif kind == "all-to-all":
            operand, w = r, r * (g - 1) // max(g, 1)
        else:                            # collective-permute
            operand, w = r, r
        counts[kind] = counts.get(kind, 0) + 1
        by_kind[kind] = by_kind.get(kind, 0) + operand
        wire[kind] = wire.get(kind, 0) + w
    return CollectiveStats(counts, by_kind, wire)


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) useful-model FLOPs per step.
    For decode shapes D = global_batch tokens (one step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens          # forward only
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/stream


def roofline_terms(*, flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float,
                   peak=V5E) -> dict[str, float]:
    compute_s = flops_per_chip / peak["peak_flops_bf16"]
    memory_s = bytes_per_chip / peak["hbm_bandwidth"]
    coll_s = coll_bytes_per_chip / peak["ici_bandwidth"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dominant
    terms["step_s_lower_bound"] = bound
    return terms
