"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh) cell, all in SECONDS (per step,
per chip — the compiled SPMD module is the per-device program, so
``cost_analysis`` FLOPs/bytes and the HLO collective operand sizes are
already per-chip quantities):

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s          (197e12 bf16 v5e)
  memory     = HLO_bytes_per_chip / HBM_bandwidth        (819e9 B/s)
  collective = collective_operand_bytes_per_chip / ICI   (50e9 B/s/link)

collective bytes are parsed from ``compiled.as_text()``: the summed
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (the convention the task
spec fixes; ring-algorithm wire amplification is NOT applied — it is a
constant ≈(n-1)/n ≈ 1 factor at n=16).
"""

from __future__ import annotations

import dataclasses
import re
import time
from typing import Any

from repro.launch.mesh import V5E

__all__ = ["CollectiveStats", "parse_collectives", "roofline_terms",
           "model_flops", "calibrate_peaks", "resolve_peaks"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# a result shape: dtype[dims]{layout}?  e.g.  bf16[16,512]{1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# instruction line:  %name = <shape or (tuple)> opcode(...)
_INSTR_RE = re.compile(
    r"=\s+(\([^)]*\)|[\w\[\]{},]+)\s+(" + "|".join(_COLLECTIVES) +
    r")(-start|-done)?\(")
# replica_groups={{0,1,..},{..}}  or iota form  replica_groups=[16,16]<=[256]
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(result_str: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result_str))


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))          # [n_groups, group_size]
    return 1


@dataclasses.dataclass
class CollectiveStats:
    """Per-device collective traffic, two conventions:

    operand bytes  — the spec's convention: what each device CONTRIBUTES
                     (all-gather: its shard; all-reduce: the full buffer;
                     reduce-scatter: the full input; all-to-all /
                     permute: the local buffer);
    wire bytes     — ring-algorithm estimate of what actually crosses each
                     device's links (all-reduce ≈ 2× buffer, etc.).
    """
    counts: dict[str, int]
    bytes_by_kind: dict[str, int]       # operand-bytes convention
    wire_bytes_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_wire_bytes(self) -> int:
        return sum(self.wire_bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    by_kind: dict[str, int] = {}
    wire: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        result_str, kind, startdone = m.group(1), m.group(2), m.group(3)
        if startdone == "-done":
            continue                     # paired with its -start
        r = _result_bytes(result_str)    # per-device result buffer bytes
        g = max(_group_size(line), 1)
        if kind == "all-gather":
            operand, w = r // max(g, 1), r * (g - 1) // max(g, 1)
        elif kind == "all-reduce":
            operand, w = r, 2 * r * (g - 1) // max(g, 1)
        elif kind == "reduce-scatter":
            operand, w = r * g, r * (g - 1)
        elif kind == "all-to-all":
            operand, w = r, r * (g - 1) // max(g, 1)
        else:                            # collective-permute
            operand, w = r, r
        counts[kind] = counts.get(kind, 0) + 1
        by_kind[kind] = by_kind.get(kind, 0) + operand
        wire[kind] = wire.get(kind, 0) + w
    return CollectiveStats(counts, by_kind, wire)


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) useful-model FLOPs per step.
    For decode shapes D = global_batch tokens (one step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens          # forward only
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/stream


# ---------------------------------------------------------------------------
# Backend-calibrated peaks.
#
# The v5e constants above the fold are the right model for the production
# TPU mesh the dry-run targets, but cost RANKING on the CI backend
# (XLA:CPU) is meaningless against 197 TFLOP/s: every candidate looks
# compute-free and memory ordering is off by ~two orders of magnitude.
# ``calibrate_peaks`` measures the *effective* peaks of the live backend
# once per process with a one-shot microbenchmark and caches the result;
# ``resolve_peaks`` is the lookup the optimizer uses (TPU → the published
# v5e table, anything else → the calibrated table).
# ---------------------------------------------------------------------------

_CALIBRATED: dict[str, dict[str, float]] = {}


def _time_best(fn, iters: int = 3) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def calibrate_peaks(backend: str | None = None, *,
                    force: bool = False) -> dict[str, float]:
    """Measure effective peaks of the live jax backend (cached per process).

    Returns a dict with the three keys :func:`roofline_terms` consumes
    (``peak_flops_bf16``, ``hbm_bandwidth``, ``ici_bandwidth`` — the names
    keep the v5e spelling so the tables are interchangeable) plus extras
    the cost model uses directly:

    ``gather_bandwidth``  effective B/s of a row gather (the access
                          pattern of tree traversal — usually differs
                          from streaming bandwidth, in either direction,
                          which is exactly why it is measured);
    ``h2d_bandwidth``     host→device transfer B/s (``device_put``);
    ``dispatch_s``        fixed overhead of one jitted dispatch — the
                          per-stage / per-batch launch constant.

    All measurements are min-of-3 on deliberately small operands
    (~10-50 MiB, one matmul) so the whole calibration stays well under a
    second; the numbers are *effective* throughputs (what a real kernel
    sees), not datasheet peaks.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    backend = backend or jax.default_backend()
    if not force and backend in _CALIBRATED:
        return _CALIBRATED[backend]

    # FLOP/s: one f32 [N,N]@[N,N] matmul, 2*N^3 useful flops.
    n = 512
    a = jnp.ones((n, n), jnp.float32)
    mm = jax.jit(lambda x: x @ x)
    mm(a).block_until_ready()
    t = _time_best(lambda: mm(a).block_until_ready())
    peak_flops = 2.0 * n ** 3 / max(t, 1e-9)

    # Streaming bandwidth: elementwise add over 32 MiB (1 read + 1 write).
    big = jnp.ones((8 << 20,), jnp.float32)          # 32 MiB
    add = jax.jit(lambda x: x + 1.0)
    add(big).block_until_ready()
    t = _time_best(lambda: add(big).block_until_ready())
    stream_bw = 2.0 * big.nbytes / max(t, 1e-9)

    # Gather bandwidth: row gather of 16 MiB through random indices —
    # the memory access pattern of node/threshold lookups.
    rows = 1 << 16
    table = jnp.ones((rows, 64), jnp.float32)        # 16 MiB
    idx = jnp.asarray(np.random.default_rng(0).integers(0, rows, rows),
                      jnp.int32)
    gat = jax.jit(lambda tb, ix: jnp.take(tb, ix, axis=0))
    gat(table, idx).block_until_ready()
    t = _time_best(lambda: gat(table, idx).block_until_ready())
    gather_bw = 2.0 * table.nbytes / max(t, 1e-9)

    # Host→device transfer: device_put of a 16 MiB numpy array.
    host = np.ones((4 << 20,), np.float32)
    jax.device_put(host).block_until_ready()
    t = _time_best(lambda: jax.device_put(host).block_until_ready())
    h2d_bw = host.nbytes / max(t, 1e-9)

    # Dispatch overhead: one tiny jitted call, end to end.
    tiny = jnp.ones((8,), jnp.float32)
    t = _time_best(lambda: add(tiny).block_until_ready(), iters=5)
    dispatch_s = t

    peaks = {
        "peak_flops_bf16": peak_flops,
        "hbm_bandwidth": stream_bw,
        # Single-host loopback: inter-"chip" traffic moves at memory
        # speed; keeps collective terms finite and comparable.
        "ici_bandwidth": stream_bw,
        "gather_bandwidth": gather_bw,
        "h2d_bandwidth": h2d_bw,
        "dispatch_s": dispatch_s,
        "backend": backend,
        "measured": True,
    }
    _CALIBRATED[backend] = peaks
    return peaks


def resolve_peaks(backend: str | None = None) -> dict[str, float]:
    """Peaks table for cost ranking on the live backend.

    TPU backends get the published v5e table (augmented with derived
    gather/h2d/dispatch entries); everything else gets the one-shot
    calibrated table from :func:`calibrate_peaks`.
    """
    import jax
    backend = backend or jax.default_backend()
    if backend == "tpu":
        peaks = dict(V5E)
        peaks.setdefault("gather_bandwidth", V5E["hbm_bandwidth"] / 8)
        peaks.setdefault("h2d_bandwidth", 25e9)      # PCIe-class
        peaks.setdefault("dispatch_s", 5e-6)
        peaks["backend"] = "tpu"
        peaks["measured"] = False
        return peaks
    return calibrate_peaks(backend)


def roofline_terms(*, flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float,
                   peak=V5E) -> dict[str, float]:
    compute_s = flops_per_chip / peak["peak_flops_bf16"]
    memory_s = bytes_per_chip / peak["hbm_bandwidth"]
    coll_s = coll_bytes_per_chip / peak["ici_bandwidth"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dominant
    terms["step_s_lower_bound"] = bound
    return terms
