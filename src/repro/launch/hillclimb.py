"""§Perf hillclimb driver: lower a cell with config overrides and report
the roofline-term deltas vs its baseline.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch llama4-scout-17b-a16e --shape decode_32k \
        --set moe_decode_ep=true --tag ep-psum-decode \
        --out experiments/hillclimb.jsonl

The 512-way host-device override is applied inside :func:`main` (before
jax is first imported via ``repro.launch.dryrun``) so that merely
importing this module has no side effects on ``XLA_FLAGS``.
"""

import argparse
import json
import os
import sys


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    if v.lower() in ("true", "false"):
        return k, v.lower() == "true"
    try:
        return k, int(v)
    except ValueError:
        pass
    try:
        return k, float(v)
    except ValueError:
        return k, v


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--vocab-chunk", type=int, default=16_384)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    # Must land before the first jax import (pulled in by dryrun below):
    # the dryrun models a 512-chip mesh on host devices.
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))

    from repro.launch.dryrun import run_cell
    overrides = dict(parse_override(kv) for kv in args.set)
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   opt_name=args.optimizer, vocab_chunk=args.vocab_chunk,
                   overrides=overrides or None,
                   microbatches=args.microbatches)
    rec["tag"] = args.tag
    rec["overrides"] = overrides
    rec["vocab_chunk"] = args.vocab_chunk
    rec["microbatches"] = args.microbatches
    line = json.dumps(rec)
    print(line[:500], flush=True)
    if args.out:
        with open(args.out, "a") as fh:
            fh.write(line + "\n")
    if rec["status"] == "failed":
        print(rec.get("traceback", ""), file=sys.stderr)
        return 1
    print(f"[{args.tag}] compute={rec['compute_s']:.4f}s "
          f"memory={rec['memory_s']:.4f}s "
          f"collective={rec['collective_s']:.4f}s "
          f"dominant={rec['dominant']} "
          f"roofline_fraction={rec['roofline_fraction']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
