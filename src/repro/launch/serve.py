"""Serving driver: ``python -m repro.launch.serve --arch olmo-1b``.

Spins the continuous-batching engine on a reduced model, routes a
synthetic request trace through the forest router, and prints
latency/throughput stats (the serving-side end-to-end example).
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models.registry import get_bundle
from repro.serve.engine import ServeEngine
from repro.serve.router import ForestRouter, request_features


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-ctx", type=int, default=160)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    bundle = get_bundle(cfg)
    params = bundle.init(cfg, jax.random.PRNGKey(args.seed),
                         dtype=jnp.float32)
    engine = ServeEngine(cfg, params, slots=args.slots,
                         max_ctx=args.max_ctx,
                         prompt_buckets=(16, 32, 64))
    router = ForestRouter(seed=args.seed)

    rng = np.random.default_rng(args.seed)
    tiers = {0: 0, 1: 0}
    for _ in range(args.requests):
        plen = int(rng.integers(4, 48))
        mnt = int(rng.integers(4, 24))
        feats = request_features(plen, mnt, len(engine._queue),
                                 len(engine._active), 32.0)
        tier = router.route(feats)
        tiers[tier] += 1
        prompt = rng.integers(0, cfg.vocab_size, plen)
        engine.submit(prompt, max_new_tokens=mnt, priority=tier)

    done = engine.run_until_drained()
    stats = engine.stats()
    stats["tier0_interactive"] = tiers[0]
    stats["tier1_batch"] = tiers[1]
    print(json.dumps(stats, indent=2))
    assert len(done) == args.requests, "engine dropped requests"


if __name__ == "__main__":
    main()
