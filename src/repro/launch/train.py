"""Training driver: ``python -m repro.launch.train --arch olmo-1b ...``.

Runs a real (CPU-sized or full) training job with the fault-tolerant loop:
deterministic data, periodic checkpoints, elastic restore on restart.
On this container it is exercised with reduced configs (examples/ and
tests/); on a pod the same entry point runs the full mesh.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, reduced
from repro.dist.sharding import make_plan
from repro.train.data import DataConfig, synthetic_batch
from repro.train.fault import TrainLoop
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.trainer import init_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    opt = make_optimizer(OptimizerConfig(
        name=args.optimizer, lr=args.lr, warmup_steps=10,
        total_steps=max(args.steps, 100)))
    splan = make_plan(cfg, None)
    step_fn = jax.jit(make_train_step(cfg, opt, splan,
                                      microbatches=args.microbatches))

    dc = DataConfig(seed=args.seed, vocab_size=cfg.vocab_size,
                    batch=args.batch, seq_len=args.seq)
    state = init_state(cfg, opt, jax.random.PRNGKey(args.seed),
                       dtype=jnp.float32)

    loop = TrainLoop(step_fn, lambda k: synthetic_batch(dc, k),
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    start = None
    if args.resume and args.ckpt_dir:
        try:
            state, start = loop.restore(jax.eval_shape(lambda: state),
                                        mesh=None)
            print(f"resumed from step {start}")
        except FileNotFoundError:
            pass
    state, report = loop.run(state, args.steps, start_step=start)
    print(json.dumps({
        "arch": args.arch, "steps": report.steps_run,
        "first_loss": report.losses[0], "last_loss": report.losses[-1],
        "mean_step_s": sum(report.step_times) / len(report.step_times),
        "stragglers": report.stragglers,
    }, indent=2))


if __name__ == "__main__":
    main()
