"""Production meshes (DESIGN.md §5).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests / benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "V5E"]


# TPU v5e hardware constants used by the roofline (per chip).
V5E = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bandwidth": 819e9,        # B/s
    "ici_bandwidth": 50e9,         # B/s per link
    "hbm_bytes": 16 * 1024**3,
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    n = len(jax.devices())
    assert data * model <= n, f"need {data * model} devices, have {n}"
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])
