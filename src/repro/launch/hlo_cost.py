"""Trip-count-aware cost extraction from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE, which
under-reports a scan-over-layers model by ~num_layers×.  This module
re-derives per-chip costs exactly from the HLO text:

  1. split the module into computations; map %name -> result shape;
  2. build the call-multiplicity map: ENTRY has ×1; a while body/cond
     inherits caller_mult × known_trip_count (backend_config annotation);
     fusion/call/conditional computations inherit caller_mult;
  3. FLOPs   = Σ dot ops: 2 · |result| · |contracted lhs dims| × mult
               (+ convolutions if present; elementwise flops are ignored —
               matmuls dominate every cell by ≥100×);
  4. bytes   = Σ over TOP-LEVEL instructions (entry + while bodies) of
               (result + operand bytes) × mult — fusions count as single
               instructions, i.e. internal intermediates stay in registers/
               cache, which matches how HBM traffic behaves on TPU;
  5. collectives = operand/wire bytes per kind × mult (same conventions
               as launch/roofline.parse_collectives).

Everything is per-device: the post-partitioning module is the per-chip
program.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from repro.launch.roofline import _DTYPE_BYTES, _group_size

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^()]*\))|(?:[\w\[\]{},]+))"
    r"\s+([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP = re.compile(r'known_trip_count[":{]+n[":]+(\d+)')
_CALL_KV = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_CALL_BRACE = re.compile(r"(?:calls|branch_computations)=\{([^}]*)\}")


def _callees(rest: str) -> list[str]:
    out = []
    for m in _CALL_BRACE.finditer(rest):
        out += [x.strip().lstrip("%") for x in m.group(1).split(",")]
    for m in _CALL_KV.finditer(rest):
        name = m.group(1)
        if name not in out:
            out.append(name)
    return [x for x in out if x]
_OPERAND = re.compile(r"%([\w\.\-]+)")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "iota", "after-all", "partition-id",
                   "replica-id", "while", "conditional", "call"}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str


def parse_module(hlo: str):
    """-> (comps: name -> [Instr], shapes: %name -> shape str,
    entry: str)."""
    comps: dict[str, list[Instr]] = {}
    shapes: dict[str, str] = {}
    entry = None
    cur: list[Instr] | None = None
    cur_name = None
    for line in hlo.splitlines():
        ms = _COMP_START.match(line.strip())
        if ms and line.rstrip().endswith("{"):
            cur_name = ms.group(2)
            comps[cur_name] = cur = []
            if ms.group(1):
                entry = cur_name
            # parameter shapes from the signature are also in the body as
            # `parameter(i)` instructions — no extra handling needed.
            continue
        if cur is None:
            continue
        s = line.strip()
        if s == "}":
            cur = None
            continue
        mi = _INSTR.match(line)
        if mi:
            name, shape, op, rest = mi.groups()
            cur.append(Instr(name, shape, op, rest))
            shapes[name] = shape
    return comps, shapes, entry


def _multiplicities(comps, entry) -> tuple[dict[str, float],
                                           dict[str, float]]:
    """caller-weighted execution counts per computation, plus the local
    while trip count of each body (for scan-xs byte amortization)."""
    trips: dict[str, float] = {}
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(16):
        changed = False
        for cname, instrs in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in instrs:
                callees = _callees(ins.rest)
                if not callees:
                    continue
                trip = 1.0
                if ins.op == "while":
                    mt = _TRIP.search(ins.rest)
                    trip = float(mt.group(1)) if mt else 1.0
                for callee in callees:
                    add = m * (trip if ins.op == "while" else 1.0)
                    if ins.op == "while":
                        trips[callee] = trip
                    else:
                        trips.setdefault(callee, trips.get(cname, 1.0))
                    if mult.get(callee, 0.0) < add:
                        mult[callee] = add
                        changed = True
        if not changed:
            break
    return dict(mult), trips


def _dot_flops(ins: Instr, shapes) -> float:
    ops = _OPERAND.findall(ins.rest.split("),")[0] + ")")
    result_elems = 1
    for d in _shape_dims(ins.shape):
        result_elems *= d
    mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if not mcd or not ops:
        return 0.0
    lhs_shape = shapes.get(ops[0])
    if lhs_shape is None:
        return 0.0
    lhs_dims = _shape_dims(lhs_shape)
    k = 1
    for d in (mcd.group(1).split(",") if mcd.group(1) else []):
        di = int(d)
        if di < len(lhs_dims):
            k *= lhs_dims[di]
    return 2.0 * result_elems * k


def analyze(hlo: str) -> dict:
    comps, shapes, entry = parse_module(hlo)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "collective_wire_bytes": 0.0, "collective_counts": {}}
    mult, trips = _multiplicities(comps, entry)

    # which computations are fusion bodies (bytes counted at call site)
    fusion_bodies: set[str] = set()
    for instrs in comps.values():
        for ins in instrs:
            if ins.op == "fusion":
                for callee in _callees(ins.rest):
                    fusion_bodies.add(callee)

    flops = 0.0
    nbytes = 0.0
    coll_b: dict[str, float] = defaultdict(float)
    coll_w: dict[str, float] = defaultdict(float)
    coll_n: dict[str, int] = defaultdict(int)

    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        top_level = cname not in fusion_bodies
        for ins in instrs:
            if ins.op in ("dot", "convolution"):
                flops += m * _dot_flops(ins, shapes)
            base = ins.op.replace("-start", "")
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                r = _shape_bytes(ins.shape)
                g = max(_group_size(ins.rest), 1)
                if base == "all-gather":
                    op_b, w = r // g, r * (g - 1) // g
                elif base == "all-reduce":
                    op_b, w = r, 2 * r * (g - 1) // g
                elif base == "reduce-scatter":
                    op_b, w = r * g, r * (g - 1)
                elif base == "all-to-all":
                    op_b, w = r, r * (g - 1) // g
                else:
                    op_b, w = r, r
                coll_b[base] += m * op_b
                coll_w[base] += m * w
                coll_n[base] += int(m)
            if top_level and ins.op not in _SKIP_BYTES_OPS:
                trip = trips.get(cname, 1.0)

                def buf_bytes(shape_str: str) -> float:
                    """Scan-xs amortization: a buffer whose leading dim
                    equals the enclosing loop's trip count is sliced one
                    step per iteration — physically read/written ONCE
                    across the loop, so charge bytes/trip here."""
                    b = _shape_bytes(shape_str)
                    if trip > 1:
                        dims = _shape_dims(shape_str)
                        if dims and abs(dims[0] - trip) < 0.5:
                            return b / trip
                    return float(b)

                b = buf_bytes(ins.shape)
                for opn in _OPERAND.findall(
                        ins.rest.split(")", 1)[0] + ")"):
                    b += buf_bytes(shapes.get(opn, ""))
                nbytes += m * b

    return {
        "flops": flops,
        "bytes": nbytes,
        "collective_bytes": sum(coll_b.values()),
        "collective_wire_bytes": sum(coll_w.values()),
        "collective_counts": dict(coll_n),
        "collective_bytes_by_kind": dict(coll_b),
    }
