import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware (task spec, MULTI-POD DRY-RUN): for each cell we build the
production mesh from 512 host placeholder devices, lower the cell's step
function against ShapeDtypeStruct inputs (no allocation), compile it, and
record ``memory_analysis`` (fits?), ``cost_analysis`` (FLOPs/bytes) and
the parsed collective schedule (→ EXPERIMENTS.md §Dry-run / §Roofline).

The two env lines above MUST run before any jax import — jax locks the
device count on first init.  Never set this flag globally.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.jsonl
  ... add --multi-pod for the (pod=2, data=16, model=16) mesh.
"""

import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.dist.sharding import (batch_specs, cache_specs, make_plan,
                                 param_specs, tree_named)
from repro.launch.mesh import V5E, make_production_mesh
from repro.launch.roofline import (model_flops, parse_collectives,
                                   roofline_terms)
from repro.models.registry import get_bundle, input_specs
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.trainer import make_train_step, state_shapes

# long_500k needs sub-quadratic attention: runnable for SSM/hybrid and the
# chunked-local iRoPE MoE archs; skipped (and recorded) for pure
# full-attention archs (DESIGN.md §4).
LONG_OK = {"mamba2-2.7b", "zamba2-2.7b", "llama4-scout-17b-a16e",
           "llama4-maverick-400b-a17b"}

# big models use adafactor so the optimizer state fits 16 GB/chip (§5)
ADAFACTOR_ARCHS = {"llama4-maverick-400b-a17b", "llama4-scout-17b-a16e",
                   "yi-34b", "chameleon-34b"}


def cell_skip_reason(arch: str, shape_name: str) -> str | None:
    if shape_name == "long_500k" and arch not in LONG_OK:
        return ("full-attention arch: 500k decode KV-scan is linear but the "
                "arch has no sub-quadratic path for its 500k context — "
                "skipped per assignment, recorded in EXPERIMENTS.md")
    return None


def _sharded_sds(tree, specs, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    def one(sds, spec):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree_util.tree_map(one, tree, specs,
                                  is_leaf=lambda x: isinstance(
                                      x, jax.ShapeDtypeStruct))


def build_lowerable(arch: str, shape_name: str, mesh, *,
                    opt_name: str | None = None, vocab_chunk: int = 16_384,
                    overrides=None, microbatches: int = 1):
    """Returns (fn, example_args) ready for jax.jit(...).lower(*args)."""
    cfg = get_config(arch)
    if overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    bundle = get_bundle(cfg)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        splan = make_plan(cfg, mesh)
        opt = make_optimizer(OptimizerConfig(
            name=opt_name or ("adafactor" if arch in ADAFACTOR_ARCHS
                              else "adamw")))
        step = make_train_step(cfg, opt, splan, vocab_chunk=vocab_chunk,
                               microbatches=microbatches)
        state_sds = state_shapes(cfg, opt)
        st_specs = {"params": param_specs(state_sds["params"], mesh),
                    "opt": param_specs(state_sds["opt"], mesh),
                    "step": P()}
        bspecs = {k: batch_specs(splan)[k] for k in specs}
        args = (_sharded_sds(state_sds, st_specs, mesh),
                _sharded_sds(specs, bspecs, mesh))
        return step, args, cfg, shape, splan

    splan = make_plan(cfg, mesh, decode_batch=(
        shape.global_batch if shape.kind == "decode" else None))
    params_sds = jax.eval_shape(
        partial(bundle.init, cfg, dtype=jnp.bfloat16), jax.random.PRNGKey(0))
    p_specs = param_specs(params_sds, mesh)
    params_arg = _sharded_sds(params_sds, p_specs, mesh)

    if shape.kind == "prefill":
        def fn(params, batch):
            return bundle.prefill(cfg, params, batch, splan)
        bspecs = {k: batch_specs(splan)[k] for k in specs}
        args = (params_arg, _sharded_sds(specs, bspecs, mesh))
        return fn, args, cfg, shape, splan

    # decode
    def fn(params, caches, token):
        return bundle.decode(cfg, params, caches, token, splan)
    c_specs = cache_specs(specs["caches"], splan)
    tok_spec = (P(None, None) if shape.global_batch <
                int(np.prod([mesh.shape[a] for a in splan.data_axes] or [1]))
                else batch_specs(splan)["tokens"])
    args = (params_arg,
            _sharded_sds(specs["caches"], c_specs, mesh),
            jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                 sharding=NamedSharding(mesh, tok_spec)))
    return fn, args, cfg, shape, splan


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             keep_hlo: bool = False, opt_name=None, vocab_chunk=16_384,
             overrides=None, unroll: bool = False,
             microbatches: int = 1) -> dict:
    """Lower + compile one cell; return the §Dry-run / §Roofline record.

    ``unroll=True`` fully unrolls every lax.scan so cost_analysis counts
    per-layer FLOPs/bytes/collectives exactly (XLA counts a while body
    once) — used for the §Roofline table; the rolled variant is the
    production program and the memory_analysis source."""
    from repro.models import scanctl
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "unrolled_costs": unroll}
    skip = cell_skip_reason(arch, shape_name)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec
    scanctl.UNROLL = unroll
    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    try:
        fn, args, cfg, shape, splan = build_lowerable(
            arch, shape_name, mesh, opt_name=opt_name,
            vocab_chunk=vocab_chunk, overrides=overrides,
            microbatches=microbatches)
        with mesh:
            lowered = jax.jit(fn).lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()

        # trip-count-aware per-chip costs (cost_analysis counts a while
        # body once — hlo_cost re-derives exact totals; see hlo_cost.py)
        from repro.launch import hlo_cost
        corrected = hlo_cost.analyze(hlo)
        flops_dev = float(corrected["flops"])
        bytes_dev = float(corrected["bytes"])
        coll_bytes = float(corrected["collective_bytes"])
        terms = roofline_terms(flops_per_chip=flops_dev,
                               bytes_per_chip=bytes_dev,
                               coll_bytes_per_chip=coll_bytes)
        mflops = model_flops(cfg, shape)
        rec.update({
            "status": "ok",
            "attn_mode": splan.attn_mode,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "n_chips": n_chips,
            "flops_per_chip": flops_dev,
            "bytes_per_chip": bytes_dev,
            "collective_bytes_per_chip": coll_bytes,
            "collective_wire_bytes_per_chip":
                float(corrected["collective_wire_bytes"]),
            "collective_counts": corrected["collective_counts"],
            "collective_bytes_by_kind":
                corrected["collective_bytes_by_kind"],
            "raw_cost_analysis_flops": float(cost.get("flops", 0.0)),
            "raw_cost_analysis_bytes":
                float(cost.get("bytes accessed", 0.0)),
            "memory_analysis": {
                k: getattr(mem, k) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)
            } if mem is not None else None,
            "model_flops_total": mflops,
            "model_flops_per_chip": mflops / n_chips,
            "useful_flops_ratio": (mflops / n_chips / flops_dev
                                   if flops_dev else 0.0),
            **{k: v for k, v in terms.items()},
            "roofline_fraction": (mflops / n_chips /
                                  V5E["peak_flops_bf16"] /
                                  terms["step_s_lower_bound"]
                                  if terms["step_s_lower_bound"] else 0.0),
        })
        if keep_hlo:
            rec["hlo_path"] = f"/tmp/hlo_{arch}_{shape_name}_{rec['mesh']}.txt"
            with open(rec["hlo_path"], "w") as fh:
                fh.write(hlo)
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    finally:
        scanctl.UNROLL = False
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--vocab-chunk", type=int, default=16_384)
    ap.add_argument("--unroll", action="store_true",
                    help="unroll scans for exact per-layer cost accounting")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    out = open(args.out, "a") if args.out else None
    failed = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp,
                               keep_hlo=args.keep_hlo,
                               opt_name=args.optimizer,
                               vocab_chunk=args.vocab_chunk,
                               unroll=args.unroll)
                line = json.dumps(rec)
                print(line[:400] + ("..." if len(line) > 400 else ""),
                      flush=True)
                if out:
                    out.write(line + "\n")
                    out.flush()
                if rec["status"] == "failed":
                    failed += 1
                    print(rec.get("traceback", ""), file=sys.stderr)
    if out:
        out.close()
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
