"""The canonical catalog of exported span / event / metric names.

Instrumentation across the data plane imports its names from nowhere —
names are string literals at the call sites — but THIS module is the
authoritative list of what the observability plane exports, and
``benchmarks/check_docs.py`` (stdlib-only CI gate) asserts every name
below appears, in backticks, in ``docs/observability.md``.  Add an
instrument without cataloging + documenting it and CI fails.

``SPAN_PREFIXES`` covers dynamically named spans (per-stage spans are
``stage:<stage name>`` — the stage names themselves are plan-derived).
"""

from __future__ import annotations

__all__ = ["SPAN_NAMES", "SPAN_PREFIXES", "EVENT_NAMES", "METRIC_NAMES"]

#: every statically named span the instrumentation can emit
SPAN_NAMES = (
    # query engine (db/query.py)
    "query.infer",
    "query.infer_rows",
    "plan.build",
    "plan.partition",
    "query.write",
    # streaming scan executor (db/executor.py)
    "scan.execute",
    "scan.batch",
    "scan.disk_read",
    "scan.dma_in",
    "scan.transfer_wait",
    "scan.compute",
    "scan.drain_submit",
    "scan.drain_write",
    # tensor-block store (db/store.py)
    "store.put",
    "store.put_sparse",
    "store.move",
    # external loaders (db/loader.py)
    "load.parse",
    "load.convert",
    "load.transfer",
    # serving plane (serve/engine.py, serve/forest.py)
    "serve.prefill",
    "serve.execute",
    "serve.tick",
    "serve.coalesce",
    # cost-based optimizer (db/optimizer.py)
    "optimizer.decide",
    "optimizer.autotune",
    # in-database streamed training (db/train.py)
    "train.forest",
    "train.sketch",
    "train.bin_ingest",
    "train.level",
)

#: prefixes of dynamically named spans
SPAN_PREFIXES = (
    "stage:",            # per-pipeline-stage spans (db/operators.Stage.run)
)

#: every span-event (instant) name
EVENT_NAMES = (
    "fault.injected",    # FaultInjector.fire hit an armed site
    "retry",             # RetryPolicy re-attempt at a site
    "degrade.sync_drain",   # drain-worker death -> mid-scan sync fallback
    "batch.resubmit",    # disk-read re-enqueue / transfer halving ladder
    "deadline.hit",      # cooperative deadline stopped the scan
    "plan.cache",        # compiled-plan cache consulted (hit= attr)
    "serve.shed",        # admission timeout demoted a request to batch
    "optimizer.decision",   # a decision was made + persisted (cell attrs)
)

#: every process-global METRICS counter (and the serve engine's
#: per-engine histogram names)
METRIC_NAMES = (
    # plan / tracing accounting (db/operators.py, db/query.py)
    "plan.traces",
    "plan.cache_hits",
    "plan.cache_misses",
    # streaming scan rollups (db/executor.py)
    "scan.batches",
    "scan.bytes_streamed",
    "scan.retries",
    "scan.faults_injected",
    "scan.batch_resubmits",
    "scan.degraded_to_sync",
    "scan.deadline_hits",
    # store / loader (db/store.py, db/loader.py)
    "store.puts",
    "store.moves",
    "load.external_loads",
    # serving plane (serve/engine.py + serve/forest.py; per-engine /
    # per-model registries except serve.queue_depth, which is the
    # process-global arrival-load gauge the router reads)
    "serve.requests",
    "serve.shed",
    "serve.queue_wait_s",
    "serve.e2e_latency_s",
    "serve.queue_depth",
    "serve.ticks",
    "serve.coalesce_width",
    "serve.padding_rows",
    "serve.plan_hits",
    "serve.plan_misses",
    # cost-based optimizer (db/optimizer.py)
    "optimizer.decisions",
    "optimizer.decision_cache_hits",
    "optimizer.decision_cache_misses",
    "optimizer.autotune_runs",
    "optimizer.measurements",
    # in-database streamed training (db/train.py)
    "train.runs",
    "train.trees_grown",
    "train.level_scans",
)
