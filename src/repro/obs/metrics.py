"""Process-global metrics registry: named counters + fixed-bucket histograms.

One half of the observability plane (``repro/obs``; the other half is
``trace.py``).  The paper's whole method is decomposed measurement —
where did the end-to-end latency go? — and before this module the repo
answered with five ad-hoc telemetry islands (``ScanStats``,
``StageReport``, ``TRACE_STATS``, ``LoadTiming``, ``ServeEngine
.stats()``).  The registry is the one backbone they roll up into:
every layer increments NAMED counters (``obs/names.py`` is the
canonical list, ``docs/observability.md`` the contract) and records
latencies into FIXED-BUCKET histograms, so cross-layer questions
("how many retries across all scans this process?", "serve-plane p99
queue wait?") are one ``snapshot()`` away instead of a grep.

Design rules, in the same spirit as ``db/faults.py``:

  * ZERO DEPENDENCIES — stdlib only, so ``benchmarks/check_docs.py``
    (a stdlib-only CI gate) can import the name catalog, and nothing
    here can ever end up traced into a jitted stage.
  * CHEAP WHEN IDLE — a counter is one lock + one int add; the
    registry has no background thread, no export loop, no string
    formatting on the hot path.  The measured cost of the fully armed
    plane is ``BENCH_obs.json`` (<5% bound, same gate discipline as
    ``BENCH_faults.json``).
  * FIXED BUCKETS — histograms never allocate per-sample; percentile
    queries interpolate inside the landing bucket, clamped to the
    observed min/max, which keeps p50/p99 honest at bucket resolution
    (log-spaced default bounds: ~19% worst-case relative error).

Thread safety: one ``threading.Lock`` per instrument (the drain
worker, the compute thread, and the serve loop all record into the
same process-global registry).
"""

from __future__ import annotations

import math
import threading
from bisect import bisect_left

__all__ = ["Counter", "Histogram", "MetricsRegistry", "METRICS",
           "DEFAULT_LATENCY_BOUNDS_S"]

#: default histogram bucket upper bounds for LATENCY instruments:
#: log-spaced, 4 buckets per decade, 10 microseconds .. 100 seconds
#: (plus the implicit overflow bucket).  Percentiles interpolate inside
#: a bucket, so the worst-case relative error is one quarter-decade.
DEFAULT_LATENCY_BOUNDS_S = tuple(
    round(10.0 ** (e / 4.0), 12) for e in range(-20, 9))


class Counter:
    """A named monotonic counter (resettable via the registry)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int | float = 1) -> None:
        with self._lock:
            self._value += n

    def set(self, value: int | float) -> None:
        """Back-compat escape hatch (the ``TRACE_STATS`` dict alias
        assigns); prefer ``inc``/``reset``."""
        with self._lock:
            self._value = value

    @property
    def value(self) -> int | float:
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"Counter({self.name}={self._value})"


class Histogram:
    """A fixed-bucket histogram: bounded memory, no per-sample allocation.

    ``bounds`` are the bucket UPPER bounds (sorted); one implicit
    overflow bucket catches everything past the last bound.  ``record``
    is a bisect + two adds; ``percentile`` walks the cumulative counts
    and interpolates linearly inside the landing bucket, clamped to the
    observed ``min``/``max`` so a single-bucket distribution still
    reports values inside its true range.
    """

    __slots__ = ("name", "bounds", "counts", "count", "sum",
                 "min", "max", "_lock")

    def __init__(self, name: str, bounds: tuple[float, ...] | None = None):
        self.name = name
        bounds = tuple(sorted(bounds if bounds is not None
                              else DEFAULT_LATENCY_BOUNDS_S))
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = threading.Lock()

    def record(self, value: float) -> None:
        v = float(value)
        idx = bisect_left(self.bounds, v)
        with self._lock:
            self.counts[idx] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def percentile(self, q: float) -> float:
        """Estimate the ``q``-th percentile (0..100) by in-bucket linear
        interpolation.  NaN on an empty histogram."""
        if self.count == 0:
            return math.nan
        target = (q / 100.0) * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else self.min
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = (target - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def reset(self) -> None:
        with self._lock:
            self.counts = [0] * (len(self.bounds) + 1)
            self.count = 0
            self.sum = 0.0
            self.min = math.inf
            self.max = -math.inf

    def summary(self) -> dict[str, float]:
        """Snapshot row: count / sum / min / max / mean / p50 / p99."""
        if self.count == 0:
            return {"count": 0, "sum": 0.0}
        return {"count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max, "mean": self.mean,
                "p50": self.percentile(50), "p99": self.percentile(99)}


class MetricsRegistry:
    """Named instruments, get-or-create, with snapshot/reset.

    Process-global as ``obs.METRICS`` (module-level singleton, like
    ``GLOBAL_CACHE`` in ``core/reuse.py``); subsystems that need
    isolated accounting (one ``ServeEngine`` per pod) hold their own
    instance — the class carries no global state.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._histograms: dict[str, Histogram] = {}

    # -- get-or-create -----------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def histogram(self, name: str,
                  bounds: tuple[float, ...] | None = None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(name,
                                                Histogram(name, bounds))
        return h

    # -- snapshot / reset ---------------------------------------------------
    def counter_values(self) -> dict[str, int | float]:
        """Flat {name: value} of every counter (the delta unit
        ``TraceSummary.counters`` is computed from)."""
        return {n: c.value for n, c in self._counters.items()}

    def snapshot(self) -> dict[str, object]:
        """Every instrument: counters as scalars, histograms as their
        ``summary()`` rows."""
        out: dict[str, object] = dict(self.counter_values())
        for n, h in self._histograms.items():
            out[n] = h.summary()
        return out

    def reset(self) -> None:
        """Zero every instrument (instrument objects stay registered, so
        references held by hot paths remain valid)."""
        for c in self._counters.values():
            c.reset()
        for h in self._histograms.values():
            h.reset()


#: the process-global registry every layer of the data plane reports to
METRICS = MetricsRegistry()
