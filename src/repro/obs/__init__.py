"""Unified observability plane: tracing + metrics for the data plane.

Zero-dependency (stdlib only).  ``TRACER`` records per-query spans —
tier-ladder I/O, per-batch DMA, kernel stages, async-drain writes —
nested across threads and exportable as Chrome trace-event JSON
(Perfetto); ``METRICS`` is the process-global counter/histogram
registry the scattered per-layer accounting rolls up into.  Span /
event / metric names are cataloged in ``obs/names.py`` and documented
(CI-enforced) in ``docs/observability.md``; armed overhead is measured
by ``benchmarks/bench_obs.py`` (``BENCH_obs.json``, <5% bound).
"""

from repro.obs.metrics import (DEFAULT_LATENCY_BOUNDS_S, METRICS, Counter,
                               Histogram, MetricsRegistry)
from repro.obs.names import (EVENT_NAMES, METRIC_NAMES, SPAN_NAMES,
                             SPAN_PREFIXES)
from repro.obs.trace import (NULL_SPAN, NullSpan, Span, SpanEvent, Tracer,
                             TraceSummary, TRACER)

__all__ = [
    "Counter", "Histogram", "MetricsRegistry", "METRICS",
    "DEFAULT_LATENCY_BOUNDS_S",
    "Span", "SpanEvent", "NullSpan", "NULL_SPAN", "Tracer", "TRACER",
    "TraceSummary",
    "SPAN_NAMES", "SPAN_PREFIXES", "EVENT_NAMES", "METRIC_NAMES",
]
