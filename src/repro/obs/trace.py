"""Per-query span tracing with Perfetto (Chrome trace-event) export.

The other half of the observability plane (see ``metrics.py``).  A
``Tracer`` records SPANS — named, attributed, monotonic-clocked wall
intervals — nested per thread via a thread-local stack, with explicit
cross-thread parenting so the ``scan-drain`` worker's result-buffer
writes nest under the compute-thread batch that produced them.  Span
EVENTS (instants: an injected fault, a retry, a degradation-ladder
transition) attach to whatever span is open on the firing thread.

The contract that keeps this off the hot path:

  * DISABLED BY DEFAULT, and the disabled path allocates NOTHING —
    ``tracer.span(...)`` returns the shared ``NULL_SPAN`` singleton
    whose enter/exit/event/set are no-ops (asserted by
    ``tests/test_obs.py``); arming is ``tracer.enable()``.
  * ARMED overhead is measured, not promised: ``benchmarks/bench_obs.py``
    runs the fused streamed scan traced vs untraced and RAISES past the
    5% bound (``BENCH_obs.json``), the same gate discipline as
    ``BENCH_faults.json``.
  * Monotonic clocks only (``time.perf_counter_ns``) — span math never
    sees wall-clock adjustments.
  * Stdlib only — nothing here can be traced into a jitted stage, and
    the CI docs gate can import the module without jax.

Export: ``tracer.export_chrome(path)`` writes Chrome trace-event JSON
(the ``traceEvents`` array format) loadable in Perfetto / chrome://
tracing — spans as ``ph: "X"`` complete events, span events as
``ph: "i"`` instants, one track per thread (``tid`` + ``M`` metadata
rows carrying the thread names), microsecond timestamps.  The async
drain's overlap is directly visible: ``scan.drain_write`` spans on the
``scan-drain`` track overlap ``scan.compute`` spans on the main track.

``TraceSummary`` is the per-query rollup attached to
``QueryResult.trace``: per-span-name wall totals, span/event counts,
and the ``METRICS`` counter deltas that accrued during the query.  The
span taxonomy and every exported name live in ``obs/names.py`` and are
documented (CI-enforced) in ``docs/observability.md``.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import threading
import time
from collections import deque
from typing import Any

__all__ = ["Span", "SpanEvent", "NullSpan", "NULL_SPAN", "Tracer",
           "TRACER", "TraceSummary"]


@dataclasses.dataclass
class SpanEvent:
    """An instant inside (or beside) a span: retries, injected faults,
    ladder transitions, cache hits."""

    name: str
    ts_ns: int
    tid: int
    thread_name: str
    attrs: dict[str, Any]


class Span:
    """One named wall interval.  Context manager: enter starts the
    clock and pushes onto the owning thread's stack; exit stops it,
    pops, and publishes the span to the tracer's finished list."""

    __slots__ = ("name", "attrs", "span_id", "parent_id", "start_ns",
                 "end_ns", "tid", "thread_name", "events", "_tracer",
                 "_parent")

    def __init__(self, tracer: "Tracer", name: str,
                 parent: "Span | None" = None, attrs: dict | None = None):
        self.name = name
        self.attrs = attrs or {}
        self.span_id = next(tracer._ids)
        self.parent_id: int | None = None
        self._parent = parent               # explicit cross-thread parent
        self.start_ns = 0
        self.end_ns = 0
        self.tid = 0
        self.thread_name = ""
        self.events: list[SpanEvent] = []
        self._tracer = tracer

    # -- context manager ----------------------------------------------------
    def __enter__(self) -> "Span":
        t = threading.current_thread()
        self.tid = t.ident or 0
        self.thread_name = t.name
        parent = self._parent
        if parent is None:
            parent = self._tracer._current()
        if isinstance(parent, Span):
            self.parent_id = parent.span_id
        self._tracer._push(self)
        self.start_ns = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.end_ns = time.perf_counter_ns()
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self._tracer._pop(self)
        self._tracer._finished.append(self)

    # -- in-flight mutation -------------------------------------------------
    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes (usable after close too — exports
        read lazily)."""
        self.attrs.update(attrs)
        return self

    def event(self, name: str, **attrs) -> None:
        """Record an instant event on this span (timestamped now, on the
        CALLING thread's track)."""
        t = threading.current_thread()
        self.events.append(SpanEvent(name=name,
                                     ts_ns=time.perf_counter_ns(),
                                     tid=t.ident or 0, thread_name=t.name,
                                     attrs=attrs))

    @property
    def duration_s(self) -> float:
        return (self.end_ns - self.start_ns) / 1e9


class NullSpan:
    """The disabled tracer's span: a shared no-op singleton.  Every
    method is a no-op and ``tracer.span(...)`` returns THE SAME object,
    so a disabled trace point allocates nothing per call."""

    __slots__ = ()

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None

    def set(self, **attrs) -> "NullSpan":
        return self

    def event(self, name: str, **attrs) -> None:
        return None

    @property
    def duration_s(self) -> float:
        return 0.0


NULL_SPAN = NullSpan()


@dataclasses.dataclass
class TraceSummary:
    """Per-query rollup: where did the time go? (``QueryResult.trace``)

    ``phase_s`` sums span wall seconds BY SPAN NAME over the query's
    span tree (so ``phase_s["scan.compute"]`` is comparable with
    ``ScanStats.compute_s`` — both clock the same code region);
    ``span_counts`` / ``event_counts`` count spans and events by name;
    ``counters`` holds the process-global ``METRICS`` counter deltas
    that accrued while the query ran.
    """

    root: str
    wall_s: float
    phase_s: dict[str, float]
    span_counts: dict[str, int]
    event_counts: dict[str, int]
    counters: dict[str, int | float]
    num_spans: int = 0

    def phase(self, name: str) -> float:
        """Total seconds of spans named ``name`` (0.0 when absent)."""
        return self.phase_s.get(name, 0.0)


def _jsonable(v):
    return v if isinstance(v, (str, int, float, bool, type(None))) \
        else str(v)


class Tracer:
    """Thread-safe span tracer, process-global as ``obs.TRACER``.

    Spans nest through a per-thread stack; cross-thread children pass
    ``parent=`` explicitly (the drain worker parents its writes under
    the owning batch's span even though that span lives — and may have
    already closed — on the compute thread).  Finished spans land in an
    append-only deque (GIL-atomic appends; ``mark()``/``finished()``
    window it), which ``export_chrome`` / ``summarize`` consume.
    """

    def __init__(self):
        self.enabled = False
        self._ids = itertools.count(1)
        self._finished: deque[Span] = deque()
        self._orphan_events: deque[SpanEvent] = deque()
        self._stacks = threading.local()
        self._epoch_ns = time.perf_counter_ns()

    # -- arming -------------------------------------------------------------
    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def reset(self) -> None:
        """Drop every recorded span/event and restart the export epoch
        (open spans on live stacks are unaffected — they will publish
        into the fresh window when they close)."""
        self._finished = deque()
        self._orphan_events = deque()
        self._epoch_ns = time.perf_counter_ns()

    # -- per-thread stack ---------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._stacks, "spans", None)
        if st is None:
            st = self._stacks.spans = []
        return st

    def _current(self) -> Span | None:
        st = getattr(self._stacks, "spans", None)
        return st[-1] if st else None

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        st = self._stack()
        if st and st[-1] is span:
            st.pop()
        elif span in st:                 # out-of-order exit: still correct
            st.remove(span)

    # -- recording ----------------------------------------------------------
    def span(self, name: str, parent: Span | NullSpan | None = None,
             **attrs):
        """Open a span (use as a context manager).  Disabled tracer:
        returns the shared ``NULL_SPAN`` — no allocation, no clock.
        ``parent=`` overrides the thread-stack parent (cross-thread
        nesting); a ``NullSpan`` parent (captured while disabled) is
        treated as no parent."""
        if not self.enabled:
            return NULL_SPAN
        if not isinstance(parent, Span):
            parent = None
        return Span(self, name, parent=parent, attrs=attrs)

    def event(self, name: str, **attrs) -> None:
        """Record an instant on the calling thread's open span (or as a
        free-standing orphan instant when no span is open)."""
        if not self.enabled:
            return
        cur = self._current()
        if cur is not None:
            cur.event(name, **attrs)
        else:
            t = threading.current_thread()
            self._orphan_events.append(SpanEvent(
                name=name, ts_ns=time.perf_counter_ns(),
                tid=t.ident or 0, thread_name=t.name, attrs=attrs))

    # -- consumption --------------------------------------------------------
    def mark(self) -> int:
        """Index into the finished-span window: ``finished(mark)`` /
        ``summarize(..., since=mark)`` scope to spans closed after it."""
        return len(self._finished)

    def finished(self, since: int = 0) -> list[Span]:
        return list(itertools.islice(self._finished, since, None))

    def summarize(self, root: Span, *, since: int = 0,
                  counters_before: dict | None = None,
                  counters_now: dict | None = None) -> TraceSummary:
        """Roll the span tree under ``root`` up into a ``TraceSummary``.

        Membership is by parent chain (children may close after their
        parent — the cross-thread drain writes do), so the walk uses the
        id->span map of the window, not append order.
        """
        window = self.finished(since)
        by_id = {s.span_id: s for s in window}
        under: set[int] = {root.span_id}
        # spans close child-before-parent on one thread but the window
        # can interleave threads; iterate to a fixpoint (tree depth is
        # tiny, this converges in 2-3 passes)
        changed = True
        while changed:
            changed = False
            for s in window:
                if s.span_id not in under and s.parent_id in under:
                    under.add(s.span_id)
                    changed = True
        phase_s: dict[str, float] = {}
        span_counts: dict[str, int] = {}
        event_counts: dict[str, int] = {}
        n = 0
        for s in window:
            if s.span_id not in under:
                continue
            n += 1
            phase_s[s.name] = phase_s.get(s.name, 0.0) + s.duration_s
            span_counts[s.name] = span_counts.get(s.name, 0) + 1
            for ev in s.events:
                event_counts[ev.name] = event_counts.get(ev.name, 0) + 1
        counters: dict[str, int | float] = {}
        if counters_now is not None:
            before = counters_before or {}
            for k, v in counters_now.items():
                d = v - before.get(k, 0)
                if d:
                    counters[k] = d
        return TraceSummary(root=root.name, wall_s=root.duration_s,
                            phase_s=phase_s, span_counts=span_counts,
                            event_counts=event_counts, counters=counters,
                            num_spans=n)

    # -- Perfetto / chrome://tracing export ---------------------------------
    def export_chrome(self, path: str | None = None,
                      since: int = 0) -> dict:
        """Serialize the finished-span window as Chrome trace-event JSON.

        One track per thread: ``tid`` is a dense index with an ``M``
        (metadata) row naming it after the Python thread, so Perfetto
        shows ``MainThread`` and ``scan-drain`` as separate lanes and
        the async drain's overlap is visible as overlapping spans.
        Spans are ``ph: "X"`` complete events (``ts``/``dur`` in
        microseconds since the tracer epoch); span events are
        ``ph: "i"`` thread-scoped instants.  Returns the payload dict;
        writes JSON to ``path`` when given.
        """
        tid_names: dict[int, tuple[int, str]] = {}

        def track(ident: int, name: str) -> int:
            if ident not in tid_names:
                tid_names[ident] = (len(tid_names) + 1, name)
            return tid_names[ident][0]

        def us(ts_ns: int) -> float:
            return (ts_ns - self._epoch_ns) / 1000.0

        events: list[dict] = []
        for sp in self.finished(since):
            args = {k: _jsonable(v) for k, v in sp.attrs.items()}
            args["span_id"] = sp.span_id
            if sp.parent_id is not None:
                args["parent_id"] = sp.parent_id
            events.append({"name": sp.name, "cat": "span", "ph": "X",
                           "ts": us(sp.start_ns), "dur": sp.duration_s * 1e6,
                           "pid": 1, "tid": track(sp.tid, sp.thread_name),
                           "args": args})
            for ev in sp.events:
                events.append({
                    "name": ev.name, "cat": "event", "ph": "i", "s": "t",
                    "ts": us(ev.ts_ns), "pid": 1,
                    "tid": track(ev.tid, ev.thread_name),
                    "args": dict(
                        {k: _jsonable(v) for k, v in ev.attrs.items()},
                        span_id=sp.span_id)})
        for ev in self._orphan_events:
            events.append({"name": ev.name, "cat": "event", "ph": "i",
                           "s": "t", "ts": us(ev.ts_ns), "pid": 1,
                           "tid": track(ev.tid, ev.thread_name),
                           "args": {k: _jsonable(v)
                                    for k, v in ev.attrs.items()}})
        for _, (tid, name) in sorted(tid_names.items(),
                                     key=lambda kv: kv[1][0]):
            events.append({"name": "thread_name", "ph": "M", "pid": 1,
                           "tid": tid, "args": {"name": name}})
        events.append({"name": "process_name", "ph": "M", "pid": 1,
                       "tid": 0, "args": {"name": "repro-data-plane"}})
        payload = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as fh:
                json.dump(payload, fh)
        return payload


#: the process-global tracer every layer of the data plane reports to
#: (disabled by default; ``TRACER.enable()`` arms it)
TRACER = Tracer()
