"""Feature-gather prepass: CSR pages -> dense compact sample tiles.

The missing link between the sparse data plane (``db/sparse.CSRPages``)
and the dense-tile Pallas kernels: instead of densifying a wide-sparse
row to full ``[B, F]`` (criteo: F = 10k+, 96% missing) and letting the
predicate one-hot explode to ``[BT, I, F]``, we scatter each CSR row into
the forest's COMPACT feature space ``[B, F_used]`` (``core.forest.
compact_forest``: F_used = the used-feature union, typically <= trees x
(2^depth - 1) and in practice a few hundred).  The existing fused
predicated/hummingbird/quickscorer kernels then run unchanged on the
compact tile with the remapped forest — the ``[BT, I, F]`` compare never
exists at full F, which is the acceptance check this subsystem is built
around.

The prepass is regular XLA (one scatter per page block), not a Pallas
kernel: data-dependent scatters are what the TPU kernels are designed to
avoid, and the scatter's output is exactly the dense tile the kernels
stream from VMEM anyway — so the prepass composes into the same jitted
stage as the kernel call and its cost is O(nnz), independent of F.

Missing-value contract: absent features become ``fill`` (NaN by default),
so ``default_left`` routing is identical to the dense plane's; page
padding rows come out all-NaN, mirroring the dense store's NaN pad rows.

Mesh contract: the prepass is shape-driven and page-local, so under
``shard_map`` (db/query's multi-device kernel stages) it is called INSIDE
the manual region on the device-LOCAL ``CSRPages`` shard with the
(replicated) inverse map — the dense compact tile only ever exists at
``[B_local, F_used]``, never at the global batch, and the scatter needs
no collectives (every CSR entry lands in its own page's rows).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.db.sparse import CSRPages

__all__ = ["gather_inverse_map", "csr_block_to_dense", "gather_columns"]


def gather_inverse_map(gather_idx: np.ndarray, n_features: int) -> np.ndarray:
    """[n_features + 1] int32: original column -> compact slot.

    Slot ``len(gather_idx)`` is the DUMP slot: unused features and the
    CSR capacity-padding sentinel (column id == n_features) land there
    and are sliced away.  Padding duplicates in ``gather_idx`` (slots
    repeating gather_idx[0]) must NOT shadow the real slot, so the first
    occurrence wins — the remapped forest reads the first slot only.
    """
    gather_idx = np.asarray(gather_idx, np.int64)
    f_used = int(gather_idx.size)
    inv = np.full(n_features + 1, f_used, np.int32)
    # reversed so the FIRST occurrence of a duplicated column wins
    inv[gather_idx[::-1]] = np.arange(f_used - 1, -1, -1, dtype=np.int32)
    return inv


def csr_block_to_dense(block: CSRPages, inv_map: jax.Array, f_used: int,
                       *, fill: float = np.nan) -> jax.Array:
    """CSR page block -> dense COMPACT tile [P * page_rows, f_used].

    ``inv_map`` is ``gather_inverse_map`` as a device array ([F+1] int32);
    ``f_used`` must equal ``inv_map``'s dump slot (= gather table size).
    Each stored entry (row r, column c, value v) scatters to
    ``out[r, inv_map[c]]``; dump-slot traffic (unused features, capacity
    padding) goes to a phantom column that is sliced off.  Rows keep
    ``fill`` everywhere no entry lands — missing stays missing.
    """
    R = block.page_rows
    C = block.capacity
    entry = jnp.arange(C, dtype=jnp.int32)

    def one(ip, ix, vl):
        # row of each entry: #(page-local row starts <= entry position);
        # capacity-padding entries (>= page nnz) fall off to phantom row R
        row = jnp.searchsorted(ip[1:], entry, side="right").astype(jnp.int32)
        col = inv_map[jnp.clip(ix, 0, inv_map.shape[0] - 1)]
        out = jnp.full((R + 1, f_used + 1), fill, vl.dtype)
        out = out.at[row, col].set(vl, mode="drop")
        return out[:R, :f_used]

    tiles = jax.vmap(one)(block.indptr, block.indices, block.values)
    return tiles.reshape(block.num_pages * R, f_used)


def gather_columns(x: jax.Array, gather_idx) -> jax.Array:
    """Dense-plane column gather: [B, F] -> [B, F_used] via the same
    index table (the cheap path when wide data is already dense)."""
    return jnp.take(x, jnp.asarray(gather_idx), axis=1)
