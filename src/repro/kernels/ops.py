"""Public jit'd wrappers for the forest Pallas kernels.

Handles everything the raw kernels assume away:
  * padding the sample axis (zeros) and tree axis (pass-through zero-leaf
    trees) to block multiples, and un-padding the output;
  * block-size selection against the VMEM budget (``common.block_heuristics``);
  * the structure-only side tensors (HummingBird C/D, QuickScorer
    bit-vectors) — built once per depth and LRU-cached;
  * ``interpret=`` defaulting to True off-TPU so the same call validates on
    CPU and runs compiled on real hardware.

The wrappers return RAW per-tree scores [B, T] like ``core.algorithms``;
phase-2 aggregation stays in ``core.postprocess`` so the kernels are
drop-in algorithm backends for the query planner.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import Forest, hb_path_matrix, qs_bitvectors
from repro.kernels.common import block_heuristics
from repro.kernels.forest_predicated import predicated_kernel_call
from repro.kernels.forest_hummingbird import hummingbird_kernel_call
from repro.kernels.forest_quickscorer import quickscorer_kernel_call

__all__ = [
    "predicated_pallas",
    "hummingbird_pallas",
    "quickscorer_pallas",
    "KERNEL_ALGORITHMS",
    "predict_raw_pallas",
]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_axis(x, axis, multiple, fill=0.0):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def _pad_forest_arrays(feature, threshold, default_left, leaf_value, block_t):
    """Tree-axis padding with pass-through zero-leaf trees."""
    feature = _pad_axis(feature, 0, block_t, 0)
    threshold = _pad_axis(threshold, 0, block_t, np.float32(np.inf))
    default_left = _pad_axis(default_left, 0, block_t, True)
    leaf_value = _pad_axis(leaf_value, 0, block_t, 0.0)
    return feature, threshold, default_left, leaf_value


@functools.lru_cache(maxsize=16)
def _hb_tensors(depth: int):
    C, D = hb_path_matrix(depth)
    return (jnp.asarray(C, jnp.float32),
            jnp.asarray(D[None, :], jnp.float32))


@functools.lru_cache(maxsize=16)
def _qs_tensors(depth: int):
    return jnp.asarray(qs_bitvectors(depth))


def _blocks(forest: Forest, B, block_b, block_t):
    T, I = forest.feature.shape
    if block_b is None or block_t is None:
        hb, ht = block_heuristics(B, T, I, forest.num_leaves,
                                  forest.n_features)
        block_b = block_b or hb
        block_t = block_t or ht
    return block_b, block_t


def _run(kind: str, forest: Forest, x: jax.Array, *, block_b=None,
         block_t=None, interpret=None) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    B = x.shape[0]
    T = forest.num_trees
    block_b, block_t = _blocks(forest, B, block_b, block_t)
    xp = _pad_axis(x, 0, block_b)
    fe, th, dl, lv = _pad_forest_arrays(
        forest.feature, forest.threshold, forest.default_left,
        forest.leaf_value, block_t)

    if kind == "predicated":
        raw = predicated_kernel_call(
            xp, fe, th, dl, lv, depth=forest.depth,
            block_b=block_b, block_t=block_t, interpret=interpret)
    elif kind == "hummingbird":
        C, D = _hb_tensors(forest.depth)
        raw = hummingbird_kernel_call(
            xp, fe, th, dl, lv, C, D,
            block_b=block_b, block_t=block_t, interpret=interpret)
    elif kind == "quickscorer":
        bv = _qs_tensors(forest.depth)
        raw = quickscorer_kernel_call(
            xp, fe, th, dl, lv, bv,
            block_b=block_b, block_t=block_t, interpret=interpret)
    else:
        raise ValueError(f"unknown kernel {kind!r}")
    return raw[:B, :T]


predicated_pallas = functools.partial(_run, "predicated")
hummingbird_pallas = functools.partial(_run, "hummingbird")
quickscorer_pallas = functools.partial(_run, "quickscorer")

KERNEL_ALGORITHMS = {
    "predicated_pallas": predicated_pallas,
    "hummingbird_pallas": hummingbird_pallas,
    "quickscorer_pallas": quickscorer_pallas,
}


def predict_raw_pallas(forest: Forest, x: jax.Array,
                       algorithm: str = "hummingbird_pallas", **kw) -> jax.Array:
    try:
        fn = KERNEL_ALGORITHMS[algorithm]
    except KeyError:
        raise ValueError(
            f"unknown kernel algorithm {algorithm!r}; "
            f"options {sorted(KERNEL_ALGORITHMS)}")
    return fn(forest, x, **kw)
