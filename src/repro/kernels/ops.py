"""Public jit'd wrappers for the forest Pallas kernels.

Handles everything the raw kernels assume away:
  * padding the sample axis (zeros) and tree axis (pass-through zero-leaf
    trees) to block multiples, and un-padding the output;
  * block-size selection against the VMEM budget (``common.block_heuristics``);
  * the structure-only side tensors (HummingBird C/D, QuickScorer
    bit-vectors) — built once per depth and LRU-cached;
  * ``interpret=`` defaulting to True off-TPU so the same call validates on
    CPU and runs compiled on real hardware.

Two backend families:

  ``KERNEL_ALGORITHMS`` (unfused) return RAW per-tree scores [B, T] like
  ``core.algorithms``; phase-2 aggregation stays in ``core.postprocess``.

  ``FUSED_KERNEL_ALGORITHMS`` (``*_pallas_fused``) return the phase-2 SUM
  [B] directly: aggregation happens in-kernel across the tree grid axis, so
  the [B, T] score matrix never round-trips HBM (the materialization cost
  the paper charges stage boundaries with, Sec. 3.3).  Tree padding is
  correct for both SUM and MEAN: padding trees carry zero leaves (add 0.0
  to the sum) and MEAN divides by the TRUE tree count downstream
  (``core.postprocess.postprocess(num_trees=...)``).

All wrappers are shape-driven, so they compose with ``shard_map``: inside
a manual-sharding region the forest argument is the device-LOCAL tree
shard and ``x`` the local sample shard — block selection, tree padding and
the in-kernel sum all operate on local counts, and because per-shard
padding trees still sum to exactly 0.0, a cross-device ``psum`` of the
per-shard fused sums equals the global SUM (MEAN again divides by the true
GLOBAL tree count downstream).  ``default_tree_block`` exposes the
heuristic tree-block size as the mesh-less tree-partition granularity.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import Forest, hb_path_matrix, qs_bitvectors
from repro.kernels.common import block_heuristics
from repro.kernels.forest_predicated import (predicated_fused_kernel_call,
                                             predicated_kernel_call)
from repro.kernels.forest_hummingbird import (hummingbird_fused_kernel_call,
                                              hummingbird_kernel_call)
from repro.kernels.forest_quickscorer import (quickscorer_fused_kernel_call,
                                              quickscorer_kernel_call)

__all__ = [
    "predicated_pallas",
    "hummingbird_pallas",
    "quickscorer_pallas",
    "predicated_pallas_fused",
    "hummingbird_pallas_fused",
    "quickscorer_pallas_fused",
    "KERNEL_ALGORITHMS",
    "FUSED_KERNEL_ALGORITHMS",
    "predict_raw_pallas",
    "predict_sum_pallas",
    "default_tree_block",
]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_axis(x, axis, multiple, fill=0.0):
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def _pad_forest_arrays(feature, threshold, default_left, leaf_value, block_t):
    """Tree-axis padding with pass-through zero-leaf trees."""
    feature = _pad_axis(feature, 0, block_t, 0)
    threshold = _pad_axis(threshold, 0, block_t, np.float32(np.inf))
    default_left = _pad_axis(default_left, 0, block_t, True)
    leaf_value = _pad_axis(leaf_value, 0, block_t, 0.0)
    return feature, threshold, default_left, leaf_value


# The structure-tensor caches hold HOST numpy arrays: the first call can
# happen inside a jit trace, and memoizing the jnp conversion there would
# leak a DynamicJaxprTracer into later traces.  jnp.asarray at the use site
# is a free constant embed under trace and a cached transfer in eager mode.
@functools.lru_cache(maxsize=16)
def _hb_tensors_np(depth: int):
    C, D = hb_path_matrix(depth)
    return (np.asarray(C, np.float32), np.asarray(D[None, :], np.float32))


def _hb_tensors(depth: int):
    C, D = _hb_tensors_np(depth)
    return jnp.asarray(C), jnp.asarray(D)


@functools.lru_cache(maxsize=16)
def _qs_tensors_np(depth: int):
    return qs_bitvectors(depth)


def _qs_tensors(depth: int):
    return jnp.asarray(_qs_tensors_np(depth))


def _blocks(forest: Forest, B, block_b, block_t, *, fused=False):
    """Block selection.  Fused kernels get a higher tree-block cap: their
    output tile is [BB, 1] regardless of BT (in-kernel aggregation), so
    enlarging the tree tile costs no output bandwidth and cuts the number
    of accumulator-block passes — strictly better as long as the predicate
    working set fits VMEM (``block_heuristics`` still shrinks on overflow).
    """
    T, I = forest.feature.shape
    if block_b is None or block_t is None:
        hb, ht = block_heuristics(B, T, I, forest.num_leaves,
                                  forest.n_features,
                                  max_block_t=32 if fused else 8)
        block_b = block_b or hb
        block_t = block_t or ht
    return block_b, block_t


def default_tree_block(forest: Forest, batch_rows: int = 128, *,
                       fused: bool = True) -> int:
    """The tree-block size ``block_heuristics`` would pick for this forest.

    This is the natural tree-PARTITION granularity for the mesh-less
    relation-centric plan: one partition per kernel tree block means the
    unrolled cross-product loop launches exactly the passes the fused
    kernel would make anyway (``db.query`` derives its ``n_parts``
    default from it, replacing the old magic ``4``).  ``batch_rows`` only
    matters when the VMEM budget forces a shrink; the tree block is
    batch-independent in the common case — which is also what keeps the
    per-shard kernel calls under ``shard_map`` (local tree counts)
    bit-compatible with the mesh-less unrolled template.
    """
    _, bt = block_heuristics(batch_rows, forest.num_trees,
                             forest.num_internal, forest.num_leaves,
                             forest.n_features,
                             max_block_t=32 if fused else 8)
    return bt


def _prepared(forest: Forest, x: jax.Array, block_b, block_t, interpret,
              *, fused=False, tree_dtype=None):
    """Shared padding + block selection for both backend families.

    ``tree_dtype`` (e.g. bf16) narrows the per-tree tiles — thresholds and
    leaves — AFTER padding (inf/0 fills survive the cast exactly); the
    kernels upcast on load and accumulate in f32 (InTreeger-style tree
    shrink: half the tree-tile VMEM footprint and HBM bandwidth).
    """
    if interpret is None:
        interpret = not _on_tpu()
    B = x.shape[0]
    block_b, block_t = _blocks(forest, B, block_b, block_t, fused=fused)
    xp = _pad_axis(x, 0, block_b)
    fe, th, dl, lv = _pad_forest_arrays(
        forest.feature, forest.threshold, forest.default_left,
        forest.leaf_value, block_t)
    if tree_dtype is not None:
        th = th.astype(tree_dtype)
        lv = lv.astype(tree_dtype)
    return xp, fe, th, dl, lv, block_b, block_t, interpret


def _run(kind: str, forest: Forest, x: jax.Array, *, block_b=None,
         block_t=None, interpret=None) -> jax.Array:
    B = x.shape[0]
    T = forest.num_trees
    xp, fe, th, dl, lv, block_b, block_t, interpret = _prepared(
        forest, x, block_b, block_t, interpret)

    if kind == "predicated":
        raw = predicated_kernel_call(
            xp, fe, th, dl, lv, depth=forest.depth,
            block_b=block_b, block_t=block_t, interpret=interpret)
    elif kind == "hummingbird":
        C, D = _hb_tensors(forest.depth)
        raw = hummingbird_kernel_call(
            xp, fe, th, dl, lv, C, D,
            block_b=block_b, block_t=block_t, interpret=interpret)
    elif kind == "quickscorer":
        bv = _qs_tensors(forest.depth)
        raw = quickscorer_kernel_call(
            xp, fe, th, dl, lv, bv,
            block_b=block_b, block_t=block_t, interpret=interpret)
    else:
        raise ValueError(f"unknown kernel {kind!r}")
    return raw[:B, :T]


def _run_fused(kind: str, forest: Forest, x: jax.Array, *, block_b=None,
               block_t=None, interpret=None, tree_dtype=None,
               acc_dtype=jnp.float32) -> jax.Array:
    """Fused predict + SUM: [B] raw-margin sums, no [B, T] materialization.

    ``tree_dtype=jnp.bfloat16`` stages the tree tiles (thresholds/leaves)
    at half width; accumulation stays ``acc_dtype`` (f32).
    """
    B = x.shape[0]
    xp, fe, th, dl, lv, block_b, block_t, interpret = _prepared(
        forest, x, block_b, block_t, interpret, fused=True,
        tree_dtype=tree_dtype)

    if kind == "predicated":
        summed = predicated_fused_kernel_call(
            xp, fe, th, dl, lv, depth=forest.depth,
            block_b=block_b, block_t=block_t, interpret=interpret,
            acc_dtype=acc_dtype)
    elif kind == "hummingbird":
        C, D = _hb_tensors(forest.depth)
        summed = hummingbird_fused_kernel_call(
            xp, fe, th, dl, lv, C, D,
            block_b=block_b, block_t=block_t, interpret=interpret,
            acc_dtype=acc_dtype)
    elif kind == "quickscorer":
        bv = _qs_tensors(forest.depth)
        summed = quickscorer_fused_kernel_call(
            xp, fe, th, dl, lv, bv,
            block_b=block_b, block_t=block_t, interpret=interpret,
            acc_dtype=acc_dtype)
    else:
        raise ValueError(f"unknown kernel {kind!r}")
    # padding trees sum to 0.0, so only the sample axis needs un-padding
    return summed[:B, 0]


predicated_pallas = functools.partial(_run, "predicated")
hummingbird_pallas = functools.partial(_run, "hummingbird")
quickscorer_pallas = functools.partial(_run, "quickscorer")

predicated_pallas_fused = functools.partial(_run_fused, "predicated")
hummingbird_pallas_fused = functools.partial(_run_fused, "hummingbird")
quickscorer_pallas_fused = functools.partial(_run_fused, "quickscorer")

KERNEL_ALGORITHMS = {
    "predicated_pallas": predicated_pallas,
    "hummingbird_pallas": hummingbird_pallas,
    "quickscorer_pallas": quickscorer_pallas,
}

FUSED_KERNEL_ALGORITHMS = {
    "predicated_pallas_fused": predicated_pallas_fused,
    "hummingbird_pallas_fused": hummingbird_pallas_fused,
    "quickscorer_pallas_fused": quickscorer_pallas_fused,
}


def predict_raw_pallas(forest: Forest, x: jax.Array,
                       algorithm: str = "hummingbird_pallas", **kw) -> jax.Array:
    try:
        fn = KERNEL_ALGORITHMS[algorithm]
    except KeyError:
        raise ValueError(
            f"unknown kernel algorithm {algorithm!r}; "
            f"options {sorted(KERNEL_ALGORITHMS)}")
    return fn(forest, x, **kw)


def predict_sum_pallas(forest: Forest, x: jax.Array,
                       algorithm: str = "hummingbird_pallas_fused",
                       **kw) -> jax.Array:
    """[B] summed raw margins via a fused backend (no [B, T] round-trip)."""
    try:
        fn = FUSED_KERNEL_ALGORITHMS[algorithm]
    except KeyError:
        raise ValueError(
            f"unknown fused kernel algorithm {algorithm!r}; "
            f"options {sorted(FUSED_KERNEL_ALGORITHMS)}")
    return fn(forest, x, **kw)
