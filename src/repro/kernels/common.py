"""Shared in-kernel building blocks for the forest Pallas kernels.

TPU-native design notes (DESIGN.md Sec. 3): the MXU wants 128-aligned
matmuls and the VPU wants dense 8x128 vector ops; data-dependent gathers are
the enemy.  All three kernels therefore share one gather-free primitive:

  dense predicate evaluation
      s[b, t, i] = "sample b at internal node i of tree t goes LEFT"
  computed as a one-hot MXU contraction:
      onehot[t, i, f] = (feature[t, i] == f)       (built from iota compares)
      xv = x @ onehot^T                            (MXU matmul, [BB, BT*I])
      s  = where(isnan(xv'), default_left, xv < threshold)

NaN note: the matmul contraction would turn a NaN feature into NaN only when
the one-hot row selects it — but 0 * NaN = NaN would poison the row, so NaN
inputs are pre-masked to 0 and a parallel "is-nan" indicator column is
contracted with the same one-hot to recover per-node missingness exactly.

The per-level one-hot *select* (fetch a value at a computed node index
without a gather) is an iota compare + masked sum over the node axis — depth
x I VPU work per (b, t), still far below the MXU predicate cost.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dense_predicates", "onehot_select", "block_heuristics"]


def dense_predicates(x, feature, threshold, default_left, *, acc_dtype=jnp.float32):
    """In-kernel dense predicate tensor.

    x            [BB, F]   samples (may contain NaN)
    feature      [BT, I]   int32
    threshold    [BT, I]   f32
    default_left [BT, I]   bool
    returns s    [BB, BT, I] bool  (True = go left)
    """
    BB, F = x.shape
    BT, I = feature.shape
    # one-hot over features, built from a broadcasted iota compare (no gather)
    f_iota = jax.lax.broadcasted_iota(jnp.int32, (BT, I, F), 2)
    onehot = (feature[:, :, None] == f_iota).astype(acc_dtype)  # [BT, I, F]
    x_nan = jnp.isnan(x)
    x_safe = jnp.where(x_nan, jnp.zeros_like(x), x).astype(acc_dtype)
    # MXU: [BB, F] @ [F, BT*I]
    oh2 = onehot.reshape(BT * I, F).T
    xv = jnp.dot(x_safe, oh2, preferred_element_type=acc_dtype)
    xv = xv.reshape(BB, BT, I)
    nanv = jnp.dot(x_nan.astype(acc_dtype), oh2,
                   preferred_element_type=acc_dtype).reshape(BB, BT, I)
    is_missing = nanv > 0.5
    lt = xv < threshold[None].astype(acc_dtype)
    return jnp.where(is_missing, default_left[None], lt)


def onehot_select(values, idx):
    """values [BT, N], idx [BB, BT] int32 -> out [BB, BT] = values[t, idx].

    Gather-free: iota compare + masked sum over N (VPU).
    """
    BT, N = values.shape
    BB = idx.shape[0]
    n_iota = jax.lax.broadcasted_iota(jnp.int32, (BB, BT, N), 2)
    mask = (idx[:, :, None] == n_iota)
    return jnp.sum(jnp.where(mask, values[None], jnp.zeros_like(values)[None]),
                   axis=2)


def block_heuristics(B, T, I, L, F, *, vmem_budget_bytes=12 * 1024 * 1024,
                     itemsize=4):
    """Pick (BB, BT) so the kernel working set fits the VMEM budget.

    Working set (f32 words):  x BB*F + trees 3*BT*I + onehot BT*I*F
    + s BB*BT*I + leaves BT*L + out BB*BT.   MXU alignment: BB multiple of 8
    (sublane), F/I contractions are already >=128 for depth-8 forests.
    """
    def words(bb, bt):
        return (bb * F + 3 * bt * I + bt * I * F + 2 * bb * bt * I
                + bt * L + bb * bt)

    bb, bt = min(128, B), min(8, T)
    while words(bb, bt) * itemsize > vmem_budget_bytes and bb > 8:
        bb //= 2
    while words(bb, bt) * itemsize > vmem_budget_bytes and bt > 1:
        bt //= 2
    return max(bb, 1), max(bt, 1)
