"""Shared in-kernel building blocks for the forest Pallas kernels.

TPU-native design notes (DESIGN.md Sec. 3): the MXU wants 128-aligned
matmuls and the VPU wants dense 8x128 vector ops; data-dependent gathers are
the enemy.  All three kernels therefore share one gather-free primitive:

  dense predicate evaluation
      s[b, t, i] = "sample b at internal node i of tree t goes LEFT"
  computed as a one-hot MXU contraction:
      onehot[t, i, f] = (feature[t, i] == f)       (built from iota compares)
      xv = x @ onehot^T                            (MXU matmul, [BB, BT*I])
      s  = where(isnan(xv'), default_left, xv < threshold)

NaN note: the matmul contraction would turn a NaN feature into NaN only when
the one-hot row selects it — but 0 * NaN = NaN would poison the row, so NaN
inputs are pre-masked to 0 and a parallel "is-nan" indicator column is
contracted with the same one-hot to recover per-node missingness exactly.

The per-level one-hot *select* (fetch a value at a computed node index
without a gather) is an iota compare + masked sum over the node axis — depth
x I VPU work per (b, t), still far below the MXU predicate cost.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dense_predicates", "onehot_select", "block_heuristics"]


def dense_predicates(x, feature, threshold, default_left, *, acc_dtype=jnp.float32):
    """In-kernel dense predicate tensor.

    x            [BB, F]   samples (may contain NaN)
    feature      [BT, I]   int32
    threshold    [BT, I]   f32
    default_left [BT, I]   bool
    returns s    [BB, BT, I] bool  (True = go left)
    """
    BB, F = x.shape
    BT, I = feature.shape
    # one-hot over features, built from a broadcasted iota compare (no gather)
    f_iota = jax.lax.broadcasted_iota(jnp.int32, (BT, I, F), 2)
    onehot = (feature[:, :, None] == f_iota).astype(acc_dtype)  # [BT, I, F]
    x_nan = jnp.isnan(x)
    x_safe = jnp.where(x_nan, jnp.zeros_like(x), x).astype(acc_dtype)
    # MXU: [BB, F] @ [F, BT*I]
    oh2 = onehot.reshape(BT * I, F).T
    xv = jnp.dot(x_safe, oh2, preferred_element_type=acc_dtype)
    xv = xv.reshape(BB, BT, I)
    nanv = jnp.dot(x_nan.astype(acc_dtype), oh2,
                   preferred_element_type=acc_dtype).reshape(BB, BT, I)
    is_missing = nanv > 0.5
    lt = xv < threshold[None].astype(acc_dtype)
    return jnp.where(is_missing, default_left[None], lt)


def onehot_select(values, idx):
    """values [BT, N], idx [BB, BT] int32 -> out [BB, BT] = values[t, idx].

    Gather-free: iota compare + masked sum over N (VPU).
    """
    BT, N = values.shape
    BB = idx.shape[0]
    n_iota = jax.lax.broadcasted_iota(jnp.int32, (BB, BT, N), 2)
    mask = (idx[:, :, None] == n_iota)
    return jnp.sum(jnp.where(mask, values[None], jnp.zeros_like(values)[None]),
                   axis=2)


def block_heuristics(B, T, I, L, F, *, vmem_budget_bytes=12 * 1024 * 1024,
                     itemsize=4, used_features=None, max_block_t=8):
    """Pick (BB, BT) so the kernel working set fits the VMEM budget.

    Working set (f32 words):  x BB*F + trees 3*BT*I + onehot BT*I*F_eff
    + s BB*BT*I + leaves BT*L + out BB*BT.   MXU alignment: BB multiple of 8
    (sublane), F/I contractions are already >=128 for depth-8 forests.

    The one-hot term models the feature-selection operand of the predicate
    GEMM.  Modeling it at the FULL feature width F starves wide-sparse
    inputs (criteo: F = 10k): a depth-d tree tests at most I = 2^d - 1
    distinct features, so the information content of the one-hot is
    bounded by I regardless of F, yet the naive bt*I*F estimate explodes
    ~40x and drives both blocks to 1.  ``F_eff = min(F, used_features or
    I)`` caps the modeled width at the per-tree used-feature count
    (callers may pass the true count; I is a universal upper bound).
    NOTE this models the compiler fusing the iota-compare into operand
    streaming; ``dense_predicates`` as written still reshapes the dense
    [BT*I, F] one-hot, so genuinely wide F should come in PRE-GATHERED:
    the sparse data plane (``core.forest.compact_forest`` +
    ``kernels/gather.py``) remaps the forest onto its used-feature union
    and hands the kernel a compact [BB, F_used] tile, making the modeled
    ``F_eff`` the kernel's REAL operand width.  Callers can pass the true
    per-tree count (``core.forest.used_feature_counts``) as
    ``used_features``.

    ``max_block_t`` is the tree-tile cap: 8 suits the unfused kernels
    (their [BB, BT] output tile pays bandwidth per extra tree), while the
    fused kernels pass a higher cap — their output tile is [BB, 1]
    regardless of BT, so more trees per pass is strictly better until the
    predicate working set hits the budget.
    """
    f_eff = min(F, used_features if used_features is not None else I)
    f_eff = max(f_eff, 1)

    def words(bb, bt):
        return (bb * F + 3 * bt * I + bt * I * f_eff + 2 * bb * bt * I
                + bt * L + bb * bt)

    bb, bt = min(128, B), min(max_block_t, T)
    while words(bb, bt) * itemsize > vmem_budget_bytes and bb > 8:
        bb //= 2
    while words(bb, bt) * itemsize > vmem_budget_bytes and bt > 1:
        bt //= 2
    return max(bb, 1), max(bt, 1)
