"""Pure-jnp oracles for the forest Pallas kernels.

The oracle chain: ``core.algorithms.naive_predict`` (per-sample while_loop —
the most literal transcription of tree traversal) is the root reference; the
three vectorized jnp algorithms are validated against it in
tests/test_algorithms.py, and each Pallas kernel is validated against its
matching jnp algorithm here (same math, no Pallas) in tests/test_kernels.py.

Every ref takes the SAME (forest, x) signature as the kernel wrapper and
returns raw per-tree scores [B, T] in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.algorithms import (
    hummingbird_predict,
    naive_predict,
    predicated_predict,
    quickscorer_predict,
)
from repro.core.forest import Forest

__all__ = [
    "ref_naive",
    "ref_predicated",
    "ref_hummingbird",
    "ref_quickscorer",
    "REFERENCES",
]


def ref_naive(forest: Forest, x: jax.Array) -> jax.Array:
    return naive_predict(forest, x).astype(jnp.float32)


def ref_predicated(forest: Forest, x: jax.Array) -> jax.Array:
    return predicated_predict(forest, x).astype(jnp.float32)


def ref_hummingbird(forest: Forest, x: jax.Array) -> jax.Array:
    return hummingbird_predict(forest, x).astype(jnp.float32)


def ref_quickscorer(forest: Forest, x: jax.Array) -> jax.Array:
    return quickscorer_predict(forest, x).astype(jnp.float32)


REFERENCES = {
    "predicated_pallas": ref_predicated,
    "hummingbird_pallas": ref_hummingbird,
    "quickscorer_pallas": ref_quickscorer,
}
