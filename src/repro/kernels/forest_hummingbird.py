"""Pallas TPU kernel: fused HummingBird (GEMM) forest inference.

Paper Fig. 1(b): tree traversal as tensor algebra.  HummingBird materializes
the predicate tensor S [B, T, I] and path tensor P [B, T, L] in device
memory between GEMMs; those intermediates are the largest tensors in the
whole computation (B*T*(I+L) words vs B*F + T*(I+L) for the inputs).

TPU adaptation (DESIGN.md Sec. 3): fuse all three stages into one kernel so S
and P live only in VMEM, per (sample-tile x tree-tile):

  1. S  = dense predicate eval                 (MXU, gather-free, common.py)
  2. P  = S @ C                                (MXU; C is the [I, L]
          structure-only path matrix shared by ALL trees of a depth - a
          consequence of the dense complete-tree layout, so it is loaded
          once, not per tree)
  3. out = sum_l (P == D[l]) * leaf_value[t,l] (VPU compare + MXU dot)

HBM traffic per tile drops from (read S + write S + read P + write P) to
zero — the roofline win measured in EXPERIMENTS.md §Perf.

FUSED variant (``hummingbird_fused_kernel_call``): the remaining [B, T]
score write is folded away too — the tree grid axis accumulates each tile's
per-sample partial sum into one revisited [BB, 1] output block (init at
j == 0), so phase 2 never touches HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import dense_predicates

__all__ = ["hummingbird_kernel_call", "hummingbird_fused_kernel_call"]


def _tile_scores(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref, c_ref, d_ref,
                 *, acc_dtype=jnp.float32):
    """One (sample tile x tree tile) of raw per-tree scores [BB, BT].

    Tree tiles (thresholds/leaves) may be staged bf16; compute accumulates
    at ``acc_dtype`` (f32) — leaves upcast on load, C/D stay f32 (they are
    structure-only, shared across trees, and the P == D leaf match needs
    exact small-integer counts).
    """
    x = x_ref[...]                        # [BB, F]
    feat = feat_ref[...]                  # [BT, I]
    thr = thr_ref[...]
    dl = dl_ref[...] != 0
    leaves = leaf_ref[...].astype(acc_dtype)   # [BT, L] upcast on load
    C = c_ref[...]                        # [I, L] shared structure matrix
    D = d_ref[...]                        # [1, L] left-turn counts per leaf
    BB = x.shape[0]
    BT, I = feat.shape
    L = C.shape[1]

    s = dense_predicates(x, feat, thr, dl,
                         acc_dtype=acc_dtype).astype(acc_dtype)  # [BB, BT, I]
    # stage 2: path GEMM against the shared C — one [BB*BT, I] @ [I, L]
    P = jnp.dot(s.reshape(BB * BT, I), C,
                preferred_element_type=acc_dtype)                # [BB*BT, L]
    # stage 3: exit-leaf one-hot (P == D) and leaf-value contraction
    onehot = (P == D).astype(acc_dtype).reshape(BB, BT, L)
    return jnp.sum(onehot * leaves[None], axis=2)


def _kernel(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref, c_ref, d_ref, out_ref,
            *, acc_dtype=jnp.float32):
    out_ref[...] = _tile_scores(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref,
                                c_ref, d_ref, acc_dtype=acc_dtype)


def _fused_kernel(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref, c_ref, d_ref,
                  out_ref, *, acc_dtype=jnp.float32):
    scores = _tile_scores(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref,
                          c_ref, d_ref, acc_dtype=acc_dtype)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.sum(scores, axis=1, keepdims=True)


def _in_specs(F, I, L, W_unused, block_b, block_t):
    return [
        pl.BlockSpec((block_b, F), lambda i, j: (i, 0)),
        pl.BlockSpec((block_t, I), lambda i, j: (j, 0)),
        pl.BlockSpec((block_t, I), lambda i, j: (j, 0)),
        pl.BlockSpec((block_t, I), lambda i, j: (j, 0)),
        pl.BlockSpec((block_t, L), lambda i, j: (j, 0)),
        pl.BlockSpec((I, L), lambda i, j: (0, 0)),
        pl.BlockSpec((1, L), lambda i, j: (0, 0)),
    ]


def hummingbird_kernel_call(x, feature, threshold, default_left, leaf_value,
                            C, D, *, block_b, block_t, interpret=False,
                            acc_dtype=jnp.float32):
    """Raw pallas_call; shapes must already be padded to block multiples.

    C [I, L] f32 and D [1, L] f32 are the structure-only tensors from
    ``core.forest.hb_path_matrix`` (shared across trees of one depth).
    """
    B, F = x.shape
    T, I = feature.shape
    L = leaf_value.shape[1]
    assert B % block_b == 0 and T % block_t == 0
    grid = (B // block_b, T // block_t)

    kernel = functools.partial(_kernel, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=_in_specs(F, I, L, None, block_b, block_t),
        out_specs=pl.BlockSpec((block_b, block_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, T), acc_dtype),
        interpret=interpret,
    )(x, feature, threshold, default_left.astype(jnp.int8), leaf_value, C, D)


def hummingbird_fused_kernel_call(x, feature, threshold, default_left,
                                  leaf_value, C, D, *, block_b, block_t,
                                  interpret=False, acc_dtype=jnp.float32):
    """Fused GEMM traversal + SUM aggregation: returns [B, 1] sums.

    Padding trees carry zero leaves, so they contribute exactly 0.0.
    bf16 tree tiles upcast in-kernel; sums accumulate at ``acc_dtype``."""
    B, F = x.shape
    T, I = feature.shape
    L = leaf_value.shape[1]
    assert B % block_b == 0 and T % block_t == 0
    grid = (B // block_b, T // block_t)

    kernel = functools.partial(_fused_kernel, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=_in_specs(F, I, L, None, block_b, block_t),
        out_specs=pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), acc_dtype),
        interpret=interpret,
    )(x, feature, threshold, default_left.astype(jnp.int8), leaf_value, C, D)
