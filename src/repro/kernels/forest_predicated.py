"""Pallas TPU kernel: predicated (branch-free) forest traversal.

Paper Fig. 2(c) / Nvidia FIL adapted to the TPU (DESIGN.md Sec. 3): a tile of
samples [BB, F] and a tile of trees (node arrays [BT, I]) are staged in VMEM;
all node predicates are evaluated densely on the MXU once (gather-free,
``common.dense_predicates``), then the fixed-depth descent

    idx_{d+1} = 2*idx_d + 1 + (1 - s[b, t, idx_d])

runs as ``depth`` unrolled VPU steps, where the data-dependent fetch
``s[b, t, idx]`` is an iota-compare masked sum (``common.onehot_select``) —
the FIL predication trick with the pointer arithmetic replaced by lane
arithmetic.  The exit-leaf fetch is one more one-hot select over L.

Grid: (ceil(B/BB), ceil(T/BT)); each program writes one [BB, BT] tile of raw
per-tree scores.  Tree tiles are independent => the tree axis can be sharded
across the mesh 'model' axis (relation-centric plan) with this same kernel.

FUSED variant (``predicated_fused_kernel_call``): phase-2 aggregation moves
INTO the kernel.  The tree grid axis j revisits one [BB, 1] output block per
sample tile (initialized at j == 0), accumulating each tree tile's partial
sum in VMEM — the [B, T] per-tree score matrix never exists in HBM, which is
the data-movement term the paper's stage-materialization analysis charges
the unfused path with (Sec. 3.3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import dense_predicates, onehot_select

__all__ = ["predicated_kernel_call", "predicated_fused_kernel_call"]


def _tile_scores(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref, *, depth,
                 acc_dtype=jnp.float32):
    """One (sample tile x tree tile) of raw per-tree scores [BB, BT].

    Tree tiles (thresholds/leaves) may be staged at a narrower dtype
    (bf16 halves their VMEM footprint and HBM bandwidth); all compute
    accumulates at ``acc_dtype`` (f32) — values upcast on load here.
    """
    x = x_ref[...]                       # [BB, F]
    feat = feat_ref[...]                 # [BT, I]
    thr = thr_ref[...]
    dl = dl_ref[...] != 0                # int8 -> bool
    leaves = leaf_ref[...].astype(acc_dtype)   # [BT, L] upcast on load
    BB = x.shape[0]
    BT, I = feat.shape

    s = dense_predicates(x, feat, thr, dl, acc_dtype=acc_dtype)
    s_val = s.astype(acc_dtype)                     # [BB, BT, I]

    idx = jnp.zeros((BB, BT), jnp.int32)
    for _ in range(depth):                          # unrolled descent
        # go_left = s[b, t, idx]  via per-(b,t) one-hot select over I
        n_iota = jax.lax.broadcasted_iota(jnp.int32, (BB, BT, I), 2)
        mask = idx[:, :, None] == n_iota
        go_left = jnp.sum(jnp.where(mask, s_val, 0.0), axis=2)
        idx = 2 * idx + 1 + (1 - go_left.astype(jnp.int32))

    leaf = idx - I                                  # [BB, BT] in [0, L)
    return onehot_select(leaves, leaf)


def _kernel(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref, out_ref, *, depth,
            acc_dtype=jnp.float32):
    out_ref[...] = _tile_scores(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref,
                                depth=depth, acc_dtype=acc_dtype)


def _fused_kernel(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref, out_ref,
                  *, depth, acc_dtype=jnp.float32):
    scores = _tile_scores(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref,
                          depth=depth, acc_dtype=acc_dtype)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.sum(scores, axis=1, keepdims=True)


def _forest_in_specs(F, I, L, block_b, block_t):
    return [
        pl.BlockSpec((block_b, F), lambda i, j: (i, 0)),
        pl.BlockSpec((block_t, I), lambda i, j: (j, 0)),
        pl.BlockSpec((block_t, I), lambda i, j: (j, 0)),
        pl.BlockSpec((block_t, I), lambda i, j: (j, 0)),
        pl.BlockSpec((block_t, L), lambda i, j: (j, 0)),
    ]


def predicated_kernel_call(x, feature, threshold, default_left, leaf_value,
                           *, depth, block_b, block_t, interpret=False,
                           acc_dtype=jnp.float32):
    """Raw pallas_call; shapes must already be padded to block multiples."""
    B, F = x.shape
    T, I = feature.shape
    L = leaf_value.shape[1]
    assert B % block_b == 0 and T % block_t == 0
    grid = (B // block_b, T // block_t)

    kernel = functools.partial(_kernel, depth=depth, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=_forest_in_specs(F, I, L, block_b, block_t),
        out_specs=pl.BlockSpec((block_b, block_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, T), acc_dtype),
        interpret=interpret,
    )(x, feature, threshold, default_left.astype(jnp.int8), leaf_value)


def predicated_fused_kernel_call(x, feature, threshold, default_left,
                                 leaf_value, *, depth, block_b, block_t,
                                 interpret=False, acc_dtype=jnp.float32):
    """Fused traversal + SUM aggregation: returns [B, 1] per-sample sums.

    The tree grid axis is the accumulation axis: its output block index map
    is constant in j, so the same [BB, 1] block is revisited for every tree
    tile and accumulated in place (init at j == 0).  Padding trees carry
    zero leaves, so they add exactly 0.0 to the sum.

    Tree tiles (threshold/leaf_value) may arrive bf16 (InTreeger-style
    shrink: half the tree-tile VMEM + HBM bandwidth); accumulation stays
    at ``acc_dtype`` (f32) — the output block and every partial sum hold
    full precision.
    """
    B, F = x.shape
    T, I = feature.shape
    L = leaf_value.shape[1]
    assert B % block_b == 0 and T % block_t == 0
    grid = (B // block_b, T // block_t)

    kernel = functools.partial(_fused_kernel, depth=depth,
                               acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=_forest_in_specs(F, I, L, block_b, block_t),
        out_specs=pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), acc_dtype),
        interpret=interpret,
    )(x, feature, threshold, default_left.astype(jnp.int8), leaf_value)
