"""Pallas TPU kernel: QuickScorer (bit-vector) forest inference, dense form.

Paper Fig. 1(c) / Lucchese et al. SIGIR'15: every internal node carries a
bit-vector with zeros on the leaves its FALSE outcome makes unreachable;
AND-ing the vectors of all FALSE nodes leaves the exit leaf as the lowest
surviving bit.  The CPU algorithm avoids evaluating every node via
per-feature sorted node lists + binary search — branchy, irregular,
unbalanced (the reason the paper rejects it for relation-centric netsDB,
Sec. 3.1, and the reason TFDF caps depth at 6 / 64-bit masks).

TPU adaptation (DESIGN.md Sec. 3/6.2): evaluate ALL predicates densely on the
MXU (the evaluation QuickScorer works to avoid is nearly free on a systolic
array), then per 32-bit word

    surviving[b, t, w] = AND_i ( s_false[b,t,i] ? bv[i, w] : 0xFFFFFFFF )

as a log-depth halving tree on the VPU.  The ≤64-leaf limit disappears:
depth-8 trees use W = 8 words.  Find-lowest-set-bit is a bit-expansion +
cumsum==1 mask (no argmax), fused with the leaf-value contraction.

The bit-vectors are STRUCTURE-ONLY for the dense complete layout (identical
for every tree of a depth) => loaded once per kernel, not per tree.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import dense_predicates

__all__ = ["quickscorer_kernel_call", "quickscorer_fused_kernel_call"]


def _and_reduce(masks):
    """[BB, BT, n] uint32 -> [BB, BT] AND over last axis (n a power of 2)."""
    while masks.shape[2] > 1:
        h = masks.shape[2] // 2
        masks = jnp.bitwise_and(masks[:, :, :h], masks[:, :, h:])
    return masks[:, :, 0]


def _tile_scores(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref, bv_ref,
                 *, num_words, acc_dtype=jnp.float32):
    """One (sample tile x tree tile) of raw per-tree scores [BB, BT].

    Tree tiles (thresholds/leaves) may be staged bf16; the bit-vector
    machinery is uint32 regardless, and the leaf contraction accumulates
    at ``acc_dtype`` (f32) after an on-load upcast.
    """
    x = x_ref[...]                        # [BB, F]
    feat = feat_ref[...]                  # [BT, I]
    thr = thr_ref[...]
    dl = dl_ref[...] != 0
    leaves = leaf_ref[...].astype(acc_dtype)   # [BT, L] upcast on load
    bv = bv_ref[...]                      # [I, W] uint32 (structure-only)
    BB = x.shape[0]
    BT, I = feat.shape
    L = leaves.shape[1]
    W = num_words

    s_false = ~dense_predicates(x, feat, thr, dl,
                                acc_dtype=acc_dtype)     # [BB, BT, I]

    # pad the node axis to a power of two with identity masks
    n = 1
    while n < I:
        n *= 2
    ones = jnp.uint32(0xFFFFFFFF)

    bit_planes = []
    for w in range(W):                                   # static unroll
        bv_w = bv[:, w]                                  # [I]
        m = jnp.where(s_false, bv_w[None, None, :], ones)  # [BB, BT, I]
        if n != I:
            m = jnp.concatenate(
                [m, jnp.full((BB, BT, n - I), ones, jnp.uint32)], axis=2)
        surv = _and_reduce(m)                            # [BB, BT] uint32
        # expand the word into 32 LSB-first bit lanes
        shifts = jax.lax.broadcasted_iota(jnp.uint32, (BB, BT, 32), 2)
        bits = jax.lax.shift_right_logical(surv[:, :, None], shifts)
        bit_planes.append(jnp.bitwise_and(bits, jnp.uint32(1)))
    bits = jnp.concatenate(bit_planes, axis=2).astype(jnp.float32)
    # lanes beyond L are phantom (never cleared, so always 1) — but the real
    # exit leaf (< L) always survives and is lower, so slicing is exact.
    bits = bits[:, :, :L]                                # [BB, BT, L]

    # lowest set bit: bit set AND cumulative count == 1 (no argmax needed)
    first = bits * (jnp.cumsum(bits, axis=2) == 1.0)
    return jnp.sum(first * leaves[None], axis=2)


def _kernel(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref, bv_ref, out_ref,
            *, num_words, acc_dtype=jnp.float32):
    out_ref[...] = _tile_scores(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref,
                                bv_ref, num_words=num_words,
                                acc_dtype=acc_dtype)


def _fused_kernel(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref, bv_ref,
                  out_ref, *, num_words, acc_dtype=jnp.float32):
    scores = _tile_scores(x_ref, feat_ref, thr_ref, dl_ref, leaf_ref,
                          bv_ref, num_words=num_words, acc_dtype=acc_dtype)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.sum(scores, axis=1, keepdims=True)


def _in_specs(F, I, L, W, block_b, block_t):
    return [
        pl.BlockSpec((block_b, F), lambda i, j: (i, 0)),
        pl.BlockSpec((block_t, I), lambda i, j: (j, 0)),
        pl.BlockSpec((block_t, I), lambda i, j: (j, 0)),
        pl.BlockSpec((block_t, I), lambda i, j: (j, 0)),
        pl.BlockSpec((block_t, L), lambda i, j: (j, 0)),
        pl.BlockSpec((I, W), lambda i, j: (0, 0)),
    ]


def quickscorer_kernel_call(x, feature, threshold, default_left, leaf_value,
                            bitvectors, *, block_b, block_t, interpret=False,
                            acc_dtype=jnp.float32):
    """Raw pallas_call; shapes must already be padded to block multiples.

    bitvectors [I, W] uint32 from ``core.forest.qs_bitvectors``.
    """
    B, F = x.shape
    T, I = feature.shape
    L = leaf_value.shape[1]
    W = bitvectors.shape[1]
    assert B % block_b == 0 and T % block_t == 0
    assert W * 32 >= L, f"bit width {W*32} < leaves {L}"
    grid = (B // block_b, T // block_t)

    kernel = functools.partial(_kernel, num_words=W, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=_in_specs(F, I, L, W, block_b, block_t),
        out_specs=pl.BlockSpec((block_b, block_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, T), acc_dtype),
        interpret=interpret,
    )(x, feature, threshold, default_left.astype(jnp.int8), leaf_value,
      bitvectors)


def quickscorer_fused_kernel_call(x, feature, threshold, default_left,
                                  leaf_value, bitvectors, *, block_b,
                                  block_t, interpret=False,
                                  acc_dtype=jnp.float32):
    """Fused bit-vector traversal + SUM aggregation: returns [B, 1] sums.

    The tree grid axis revisits one [BB, 1] output block per sample tile
    (init at j == 0); padding trees carry zero leaves so they add 0.0.
    bf16 tree tiles upcast in-kernel; sums accumulate at ``acc_dtype``."""
    B, F = x.shape
    T, I = feature.shape
    L = leaf_value.shape[1]
    W = bitvectors.shape[1]
    assert B % block_b == 0 and T % block_t == 0
    assert W * 32 >= L, f"bit width {W*32} < leaves {L}"
    grid = (B // block_b, T // block_t)

    kernel = functools.partial(_fused_kernel, num_words=W,
                               acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=_in_specs(F, I, L, W, block_b, block_t),
        out_specs=pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), acc_dtype),
        interpret=interpret,
    )(x, feature, threshold, default_left.astype(jnp.int8), leaf_value,
      bitvectors)
