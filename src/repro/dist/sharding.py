"""Sharding policy for the architecture pool (DESIGN.md §5).

One ``ShardingPlan`` per (config, mesh, phase): a frozen bundle of
PartitionSpecs that the layer code applies at its constraint points.  The
mesh axes are ``data`` (sample/batch parallelism, optionally preceded by a
cross-pod ``pod`` axis) and ``model`` (tensor/context parallelism).

Attention distribution picks between two modes:

  tp   head tensor-parallelism — q/k/v head axes sharded over ``model``.
       Only legal when BOTH num_heads and num_kv_heads divide the model
       axis (olmo 16/16, seamless 16/16, zamba2 32/32 on a 16-way axis).
  cp   context parallelism — the SEQUENCE axis is sharded over ``model``;
       K/V are replicated per layer (the per-layer all-gather).  Works for
       every head count (yi 56H/8KV, qwen2 28H/4KV, llama4 40H/8KV, ...).

Decode gets its own specs because the batch is often smaller than the mesh:
a [1, ...] decode stream replicates the batch axis and instead shards the
cache SEQUENCE axis over *all* axes (flash-decoding: the softmax over the
sharded axis lowers to partial reduce + all-reduce — the (m, l, o) merge).

Parameter specs are rule-based over the param pytree (``param_specs``):
  * leading stack dims of scan-over-blocks pytrees are never sharded;
  * MoE expert tensors pin the expert dim to ``model`` (expert parallelism);
  * large matrices shard their last dim over ``model`` and the dim before
    it over ``data`` (megatron TP x FSDP), but only when the dim divides
    the axis size — the dry-run's lowering rejects uneven shards;
  * small leaves (biases, norm scales, routers) are replicated.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingPlan",
    "ForestShardingPlan",
    "make_plan",
    "make_forest_plan",
    "param_specs",
    "batch_specs",
    "cache_specs",
    "tree_named",
]

# leaves below this many elements are replicated (biases, norms, routers);
# sharding them saves nothing and costs a collective per use
_MIN_SHARD_SIZE = 1 << 18

# top-level pytree keys whose leaves carry a leading scan-over-blocks stack
# dim (lax.scan iterates it) — that dim is never sharded
_STACKED_COLLECTIONS = ("blocks", "lora", "enc_blocks", "dec_blocks")


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Frozen sharding policy for one (config, mesh, phase)."""

    mesh: Any                        # Mesh | None (None = single device)
    attn_mode: str                   # "tp" | "cp"
    data_axes: tuple[str, ...]       # ("data",) or ("pod", "data")
    model_axis: str | None           # "model" when the mesh has one
    # --- activation specs ------------------------------------------------
    hidden: P                        # train/prefill hidden   [B, S, D]
    decode_hidden: P                 # decode hidden          [B, 1, D]
    qkv: P                           # projected queries      [B, S, H, dh]
    kv_ctx: P                        # full-context K/V       [B, Sk, KV, dh]
    decode_cache: P                  # decode-time K/V cache  [B, Sc, KV, dh]
    ssm_state: P                     # SSD recurrent state    [B, H, P, N]


def _data_entry(data_axes: tuple[str, ...]):
    if not data_axes:
        return None
    return data_axes[0] if len(data_axes) == 1 else data_axes


def make_plan(cfg, mesh, decode_batch: int | None = None) -> ShardingPlan:
    """Build the plan for ``cfg`` on ``mesh``.

    ``decode_batch``: global decode batch, used to decide whether the batch
    axis is worth sharding (a [1, ...] stream replicates the batch and
    shards the cache sequence axis over everything instead).  ``mesh`` may
    be any object with ``.shape``/``.axis_names`` (specs are pure data).
    """
    if mesh is None or not getattr(mesh, "axis_names", ()):
        empty = P()
        return ShardingPlan(
            mesh=None, attn_mode="cp", data_axes=(), model_axis=None,
            hidden=empty, decode_hidden=empty, qkv=empty, kv_ctx=empty,
            decode_cache=empty, ssm_state=empty)

    axis_names = tuple(mesh.axis_names)
    model = "model" if "model" in axis_names else None
    n_model = int(mesh.shape["model"]) if model else 1
    data_axes = tuple(a for a in axis_names if a != "model")
    da = _data_entry(data_axes)
    import numpy as np
    n_data = int(np.prod([mesh.shape[a] for a in data_axes] or [1]))

    H, KV = cfg.num_heads, cfg.num_kv_heads
    tp_ok = (model is not None and H > 0
             and H % n_model == 0 and KV % n_model == 0)
    attn_mode = "tp" if tp_ok else "cp"

    if attn_mode == "tp":
        hidden = P(da, None, model)
        qkv = P(da, None, model, None)
        kv_ctx = P(da, None, model, None)
    else:
        hidden = P(da, model, None)
        qkv = P(da, model, None, None)
        kv_ctx = P(da, None, None, None)      # replicated K/V: the CP gather

    # decode: batch sharding only pays when the batch covers the data axes
    small_batch = decode_batch is not None and decode_batch < n_data
    if small_batch:
        every = data_axes + ((model,) if model else ())
        decode_hidden = P(None, None, None)
        decode_cache = P(None, every if len(every) > 1 else every[0],
                         None, None)
    else:
        decode_hidden = P(da, None, None)
        if attn_mode == "tp":
            decode_cache = P(da, None, model, None)
        else:
            decode_cache = P(da, model, None, None)

    # SSD state [B, H, P, N]: shard heads over model when they divide
    try:
        ssm_heads = int(cfg.ssm_heads)
    except Exception:
        ssm_heads = 0
    h_entry = model if (model and ssm_heads and ssm_heads % n_model == 0) \
        else None
    ssm_state = P(None if small_batch else da, h_entry, None, None)

    return ShardingPlan(
        mesh=mesh, attn_mode=attn_mode, data_axes=data_axes,
        model_axis=model, hidden=hidden, decode_hidden=decode_hidden,
        qkv=qkv, kv_ctx=kv_ctx, decode_cache=decode_cache,
        ssm_state=ssm_state)


# ---------------------------------------------------------------------------
# forest-inference sharding (the db/query plans' mesh contract)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ForestShardingPlan:
    """Frozen axis mapping for multi-device forest inference.

    The paper's two parallelism modes, as mesh axes (DESIGN.md Sec. 3):

      ``data``   sample blocks — the tensor-block store shards dataset
                 pages (dense rows / CSR page blocks) over it, so every
                 device scans only its page range;
      ``model``  tree blocks — the relation-centric plan shards the
                 forest's tree dimension over it, so the cross-product's
                 per-partition partial sums are one LOCAL fused kernel
                 launch per device, combined by a single ``psum``.

    The plan is pure data: ``db/query`` wraps its kernel stages in
    ``shard_map`` using these specs, and falls back to the single-device
    template whenever the relevant axis is absent (``data_axis`` /
    ``model_axis`` is None).  Specs here are BROADCAST specs: a single
    PartitionSpec applied to every leaf of the corresponding pytree
    (rows/pages spec to a dense block or to all three CSR page arrays,
    tree spec to every Forest array — all carry the sharded dim first).
    """

    mesh: Any                        # Mesh | None (None = single device)
    data_axis: str | None            # samples/pages axis name, if any
    model_axis: str | None           # trees axis name, if any
    n_data: int                      # mesh size along data_axis (1 if none)
    n_model: int                     # mesh size along model_axis (1 if none)

    @property
    def x_spec(self) -> P:
        """Sample blocks [B, F] / CSR page arrays [P, *]: rows over data."""
        return P(self.data_axis, None)

    @property
    def tree_spec(self) -> P:
        """Forest arrays [T, ...]: tree dim over model (broadcast spec)."""
        return P(self.model_axis)

    @property
    def replicated_spec(self) -> P:
        """Side tensors (gather inverse map, udf-plan forests)."""
        return P()

    @property
    def out_spec(self) -> P:
        """Per-sample outputs [B]: rows over data, replicated over model
        (the rel plan's in-body psum makes them so)."""
        return P(self.data_axis)

    @property
    def partial_spec(self) -> P:
        """[n_parts, B] partial-sum layout, for callers that materialize
        partials instead of psum-ing in the kernel stage."""
        return P(self.model_axis, self.data_axis)

    def forest_shardings(self, forest):
        """NamedSharding tree for a Forest pytree (partition-stage layout);
        None without a mesh.  Reuses ``tree_named`` on the broadcast spec."""
        if self.mesh is None or self.model_axis is None:
            return None
        import jax as _jax
        spec_tree = _jax.tree_util.tree_map(lambda _: self.tree_spec, forest)
        return tree_named(self.mesh, spec_tree)

    def shard_forest(self, forest):
        """Place a Forest's tree blocks over the ``model`` axis (identity
        when the mesh has no model axis).  The in-database trainer lands
        its freshly grown forest through this before pinning it in the
        model catalog, so a catalog model is already laid out the way the
        relation-centric plans shard it."""
        sh = self.forest_shardings(forest)
        if sh is None:
            return forest
        import jax as _jax
        return _jax.device_put(forest, sh)


def make_forest_plan(mesh) -> ForestShardingPlan:
    """Build the forest-inference axis mapping for ``mesh``.

    Any object with ``.shape``/``.axis_names`` works (specs are pure
    data); executing under ``shard_map`` additionally needs a real Mesh.
    """
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return ForestShardingPlan(mesh=None, data_axis=None, model_axis=None,
                                  n_data=1, n_model=1)
    names = tuple(mesh.axis_names)
    data = "data" if "data" in names else None
    model = "model" if "model" in names else None
    return ForestShardingPlan(
        mesh=mesh,
        data_axis=data,
        model_axis=model,
        n_data=int(mesh.shape["data"]) if data else 1,
        n_model=int(mesh.shape["model"]) if model else 1,
    )


# ---------------------------------------------------------------------------
# parameter specs (rule-based over the pytree)
# ---------------------------------------------------------------------------


def _path_keys(path) -> list[str]:
    keys = []
    for p in path:
        k = getattr(p, "key", None)
        if k is None:
            k = getattr(p, "idx", None)
        keys.append(str(k))
    return keys


def param_specs(tree, mesh):
    """PartitionSpec tree for a param (or optimizer-state) pytree."""
    axis_names = tuple(getattr(mesh, "axis_names", ()))
    n_model = int(mesh.shape["model"]) if "model" in axis_names else 0
    n_data = int(mesh.shape["data"]) if "data" in axis_names else 0

    def one(path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        ndim = len(shape)
        size = 1
        for s in shape:
            size *= s
        if ndim <= 1 or size < _MIN_SHARD_SIZE:
            return P()
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        first = 1 if (keys and keys[0] in _STACKED_COLLECTIONS) else 0
        spec: list = [None] * ndim

        # MoE expert tensors: pin the expert dim to 'model' (EP), give the
        # d_model dim to 'data'
        if (ndim - first >= 3 and len(keys) >= 2 and keys[-2] == "moe"
                and name in ("wi", "wg", "wo")):
            e_dim = ndim - 3
            if n_model and shape[e_dim] % n_model == 0 and e_dim >= first:
                spec[e_dim] = "model"
            if n_data and shape[e_dim + 1] % n_data == 0:
                spec[e_dim + 1] = "data"
            return P(*spec)

        if n_model and shape[-1] % n_model == 0 and ndim - 1 >= first:
            spec[-1] = "model"
        if (ndim >= 2 and n_data and shape[-2] % n_data == 0
                and ndim - 2 >= first):
            spec[-2] = "data"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(
        one, tree,
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "dtype"))


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(plan: ShardingPlan) -> dict[str, P]:
    """Input-batch PartitionSpecs (tokens/labels [B, S], frames [B, S, D])."""
    if plan.mesh is None:
        return {"tokens": P(), "labels": P(), "frames": P()}
    da = _data_entry(plan.data_axes)
    return {"tokens": P(da, None), "labels": P(da, None),
            "frames": P(da, None, None)}


def cache_specs(caches, plan: ShardingPlan):
    """PartitionSpec tree for a decode-cache pytree.

    Handles stacked [nB, B, ...] and unstacked [B, ...] layouts by matching
    the spec to the TRAILING dims; leading stack dims stay unsharded.
    """
    def one(path, leaf):
        ndim = int(getattr(leaf, "ndim", 0) or len(getattr(leaf, "shape", ())))
        name = _path_keys(path)[-1] if path else ""
        if name == "index" or ndim < 2 or plan.mesh is None:
            return P()
        if name in ("k", "v") and ndim >= 4:
            return P(*((None,) * (ndim - 4) + tuple(plan.decode_cache)))
        if name == "state" and ndim >= 4:
            return P(*((None,) * (ndim - 4) + tuple(plan.ssm_state)))
        if name == "conv" and ndim >= 3:
            return P(*((None,) * (ndim - 3)
                       + (plan.decode_hidden[0], None, None)))
        if name == "memory" and ndim >= 3:
            return P(*((None,) * (ndim - 3)
                       + (plan.decode_hidden[0], None, None)))
        return P()

    return jax.tree_util.tree_map_with_path(
        one, caches,
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "dtype"))


def tree_named(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree (jit in/out_shardings)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
