"""Distribution policy: sharding plans, parameter specs, gradient compression.

``repro.dist.sharding`` is the single place PartitionSpecs are decided; the
model / trainer / serving code only places ``with_sharding_constraint``
points and consults the plan, so distribution policy changes never touch
layer code (DESIGN.md §5).
"""

from repro.dist.sharding import (  # noqa: F401
    ForestShardingPlan,
    ShardingPlan,
    batch_specs,
    cache_specs,
    make_forest_plan,
    make_plan,
    param_specs,
    tree_named,
)
