"""Gradient compression for the cross-pod (DCN) all-reduce (DESIGN.md §8).

int8 symmetric quantization moves 4x fewer bytes over the slow inter-pod
links; error feedback (Seide et al., 2014 / Karimireddy et al., 2019) keeps
the *accumulated* update unbiased: the quantization residual of step k is
added back into the gradient of step k+1, so the compressed stream's running
mean converges to the true gradient mean (test_error_feedback_mean_preserving).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "init_error_feedback",
    "compress_with_error_feedback",
    "compress_grads_crosspod",
]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric round-to-nearest int8: returns (q int8, scale f32 scalar).

    max |x - dequantize(q, s)| <= s / 2 by construction.
    """
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _roundtrip(x: jax.Array) -> jax.Array:
    q, s = quantize_int8(x)
    return dequantize_int8(q, s).astype(x.dtype)


def init_error_feedback(grads):
    """Zero residual accumulator, matching the grad pytree (f32)."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_with_error_feedback(grads, ef):
    """(grads, residuals) -> (quantize-dequantized grads, new residuals).

    The transmitted value is Q(g + e); the residual e' = (g + e) - Q(g + e)
    is carried to the next step, so sum_k Q(g + e_k) -> sum_k g.
    """
    def one(g, e):
        c = g.astype(jnp.float32) + e
        sent = _roundtrip(c)
        return sent.astype(g.dtype), c - sent

    flat = jax.tree_util.tree_map(one, grads, ef)
    sent = jax.tree_util.tree_map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree_util.tree_map(lambda t: t[1], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return sent, new_ef


def compress_grads_crosspod(grads, mesh):
    """Stateless int8 round-trip applied before the cross-pod all-reduce.

    Inside jit the quantize/dequantize pair makes XLA's DCN all-reduce
    operate on values representable in 8 bits (the wire saving); stateful
    error feedback lives in the trainer when a residual slot is threaded.
    """
    del mesh  # policy hook: per-axis treatment if pods ever differ
    return jax.tree_util.tree_map(
        lambda g: _roundtrip(g) if jnp.issubdtype(g.dtype, jnp.floating)
        else g, grads)
