"""Decoder-only LM assembly for the architecture pool.

Covers five families through one scan-over-blocks backbone:

  dense / vlm   attn + MLP every layer (yi, olmo, qwen2, minitron, chameleon)
  moe           llama4 scout/maverick: iRoPE (3 chunked-local RoPE layers +
                1 global NoPE per period-4 block), MoE every / alternating
                layers with top-1 routing + shared expert
  ssm           mamba2: every layer an SSD block, no attention, no MLP
  hybrid        zamba2: 6 Mamba2 layers per block + ONE SHARED attention
                block (on concat(hidden, embed0), per-block LoRA deltas)

The layer pattern within one period is a static list of ``LayerPlan``s; the
backbone is ``lax.scan`` over ``num_blocks`` stacked param pytrees (compact
HLO — one block body regardless of depth — which is what keeps the 40-cell
dry-run compile tractable).  ``cfg.remat`` wraps the block body in
``jax.checkpoint`` for training.

Three entry points (the dry-run lowers exactly these):
  lm_loss     train forward + chunked-vocab cross-entropy (never
              materializes [B, S, V] logits)
  lm_prefill  builds the stacked KV/SSD caches, returns last-token logits
  lm_decode   one-token step against the caches (flash-decoding sharding
              comes from the ShardingPlan's decode specs)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from repro.models import scanctl

from repro.configs.base import ModelConfig
from repro.dist.sharding import ShardingPlan, make_plan
from repro.models import layers as L
from repro.models import ssd as S

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# layer pattern
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    kind: str                 # "attn" | "ssm"
    use_moe: bool = False
    attn: L.AttnSpec | None = None


def make_layer_plans(cfg: ModelConfig) -> list[LayerPlan]:
    """Static per-period-position wiring."""
    period = cfg.block_period
    plans = []
    for i in range(period):
        if cfg.ssm_layers:
            plans.append(LayerPlan(kind="ssm"))
            continue
        is_global = cfg.global_every > 0 and (i + 1) % cfg.global_every == 0
        window = 0 if is_global else cfg.attn_window
        use_rope = cfg.pos_type != "nope" and not (
            cfg.pos_type == "irope" and is_global)
        use_moe = (cfg.num_experts > 0
                   and (i % cfg.moe_every) == (cfg.moe_every - 1))
        plans.append(LayerPlan(
            kind="attn", use_moe=use_moe,
            attn=L.AttnSpec(use_rope=use_rope, window=window,
                            causal=cfg.causal)))
    return plans


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stack_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def _init_position(cfg: ModelConfig, plan: LayerPlan, key, dtype) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": L.init_norm(cfg, D, dtype)}
    if plan.kind == "ssm":
        p["ssm"] = S.init_ssd(cfg, ks[0], dtype)
        return p
    p["attn"] = L.init_attention(cfg, ks[0], D, dtype)
    p["norm2"] = L.init_norm(cfg, D, dtype)
    if plan.use_moe:
        p["moe"] = L.init_moe(cfg, ks[1], D, F, dtype)
    elif F > 0:
        p["mlp"] = L.init_mlp(cfg, ks[1], D, F, dtype)
    return p


def _init_shared_attn(cfg: ModelConfig, key, dtype) -> Params:
    """Zamba2's shared block over the concat(h, embed0) 2·D stream."""
    D2 = 2 * cfg.d_model
    ks = jax.random.split(key, 4)
    gelu_cfg = dataclasses.replace(cfg, mlp_type="gelu")
    return {
        "norm1": L.init_norm(cfg, D2, dtype),
        "attn": L.init_attention(cfg, ks[0], D2, dtype, d_out=cfg.d_model),
        "norm2": L.init_norm(cfg, D2, dtype),
        "mlp": {"wi": (jax.random.normal(ks[1], (D2, cfg.d_ff), jnp.float32)
                       / np.sqrt(D2)).astype(dtype),
                "wo": (jax.random.normal(ks[2], (cfg.d_ff, cfg.d_model),
                                         jnp.float32)
                       / np.sqrt(cfg.d_ff)).astype(dtype)},
        "_gelu": None,  # marker; apply uses gelu_cfg
    }


def _init_lora(cfg: ModelConfig, key, dtype) -> Params:
    D2, r = 2 * cfg.d_model, cfg.shared_attn_lora_rank
    H, dh = cfg.num_heads, cfg.head_dim
    ka, kb = jax.random.split(key)
    return {
        "a": (jax.random.normal(ka, (D2, r), jnp.float32) / np.sqrt(D2)
              ).astype(dtype),
        "b": jnp.zeros((r, H * dh), dtype),
    }


def init_lm(cfg: ModelConfig, key, *, dtype=jnp.bfloat16) -> Params:
    plans = make_layer_plans(cfg)
    nB = cfg.num_blocks
    keys = jax.random.split(key, len(plans) + 4)
    params: Params = {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab_padded, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "final_norm": L.init_norm(cfg, cfg.d_model, dtype),
        "blocks": {},
    }
    for i, plan in enumerate(plans):
        params["blocks"][f"p{i}"] = _stack_init(
            partial(_init_position, cfg, plan, dtype=dtype), keys[i], nB)
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            keys[-2], (cfg.d_model, cfg.vocab_padded), jnp.float32)
            / np.sqrt(cfg.d_model)).astype(dtype)
    if cfg.shared_attn_every:
        params["shared_attn"] = _init_shared_attn(cfg, keys[-3], dtype)
        params["shared_attn"].pop("_gelu")
        params["lora"] = _stack_init(
            partial(_init_lora, cfg, dtype=dtype), keys[-4], nB)
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_shared_attn(cfg: ModelConfig, shared: Params, lora: Params,
                       h: jax.Array, e0: jax.Array, splan: ShardingPlan,
                       positions, *, decode_cache=None, collect=False,
                       ctx=None):
    cat = jnp.concatenate([h, e0], axis=-1)
    n1 = L.apply_norm(cfg, shared["norm1"], cat)
    attn_p = dict(shared["attn"])
    attn_p["wq"] = attn_p["wq"] + (lora["a"] @ lora["b"]).astype(
        attn_p["wq"].dtype)
    spec = L.AttnSpec(use_rope=True, causal=True)
    if decode_cache is not None:
        a, new_cache = L.attention_decode(cfg, attn_p, n1, decode_cache,
                                          spec, splan=splan)
    elif collect:
        a, new_cache = L.attention_forward_with_cache(
            cfg, attn_p, n1, spec, splan=splan, positions=positions,
            ctx=ctx)
    else:
        a, new_cache = L.attention_forward(
            cfg, attn_p, n1, spec, splan=splan, positions=positions), None
    n2 = L.apply_norm(cfg, shared["norm2"], cat)
    gelu_cfg = dataclasses.replace(cfg, mlp_type="gelu")
    m = L.apply_mlp(gelu_cfg, shared["mlp"], n2)
    return h + a + m, new_cache


def _apply_position(cfg: ModelConfig, plan: LayerPlan, p: Params,
                    h: jax.Array, splan: ShardingPlan, positions,
                    *, cache=None, decode=False, ctx=None):
    """One layer (train/prefill: cache=None or prefill-collect; decode:
    cache is this layer's cache).  Returns (h, new_cache_or_None)."""
    mesh = splan.mesh
    new_cache = None
    if plan.kind == "ssm":
        n1 = L.apply_norm(cfg, p["norm1"], h)
        n1 = L.shard(n1, splan.hidden, mesh)
        if decode:
            y, new_cache = S.ssd_decode(cfg, p["ssm"], n1, cache)
        elif cache == "collect":
            y, new_cache = S.ssd_forward_with_cache(cfg, p["ssm"], n1,
                                                    splan=splan)
        else:
            y = S.ssd_forward(cfg, p["ssm"], n1, splan=splan)
        h = h + y
        return L.shard(h, splan.hidden if not decode else splan.decode_hidden,
                       mesh), new_cache

    n1 = L.apply_norm(cfg, p["norm1"], h)
    if decode:
        a, new_cache = L.attention_decode(cfg, p["attn"], n1, cache,
                                          plan.attn, splan=splan)
    elif cache == "collect":
        a, new_cache = L.attention_forward_with_cache(
            cfg, p["attn"], n1, plan.attn, splan=splan, positions=positions,
            ctx=ctx)
    else:
        a = L.attention_forward(cfg, p["attn"], n1, plan.attn, splan=splan,
                                positions=positions)
    h = h + a
    hs = splan.decode_hidden if decode else splan.hidden
    h = L.shard(h, hs, mesh)
    n2 = L.apply_norm(cfg, p["norm2"], h)
    if plan.use_moe:
        m = (L.moe_decode(cfg, p["moe"], n2, splan=splan) if decode
             else L.apply_moe(cfg, p["moe"], n2, splan=splan))
    elif cfg.d_ff > 0:
        m = L.apply_mlp(cfg, p["mlp"], n2)
    else:
        m = 0.0
    h = L.shard(h + m, hs, mesh)
    return h, new_cache


def _remat(cfg: ModelConfig, fn):
    """Activation-checkpoint policy (hillclimb knob, §Perf):
    full  recompute everything in backward (min memory, +1 fwd of FLOPs)
    dots  save matmul outputs, recompute elementwise (the usual sweet
          spot: removes most recompute FLOPs at modest memory)
    none  store everything (max memory, no recompute)
    """
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _backbone(cfg: ModelConfig, params: Params, h: jax.Array,
              splan: ShardingPlan, positions, *, mode: str,
              caches: Params | None = None, ctx: int | None = None):
    """mode: train | prefill | decode.  Returns (h, new_caches | None)."""
    plans = make_layer_plans(cfg)
    e0 = h if cfg.shared_attn_every else None
    collect = mode == "prefill"
    decode = mode == "decode"
    index = caches["index"] if decode else None   # scalar, closure-captured

    def block(carry, xs):
        hh = carry
        p_block = xs["params"]
        c_block = xs.get("caches")
        new_caches = {}
        if cfg.shared_attn_every:
            dc = ({**c_block["shared"], "index": index} if decode else None)
            hh, nc = _apply_shared_attn(cfg, params["shared_attn"],
                                        xs["lora"], hh, e0, splan, positions,
                                        decode_cache=dc, collect=collect,
                                        ctx=ctx)
            if nc is not None:
                new_caches["shared"] = {"k": nc["k"], "v": nc["v"]}
        for i, plan in enumerate(plans):
            if decode:
                c = c_block[f"p{i}"]
                if plan.kind == "attn":
                    c = {**c, "index": index}
            else:
                c = "collect" if collect else None
            hh, nc = _apply_position(cfg, plan, p_block[f"p{i}"], hh, splan,
                                     positions, cache=c, decode=decode,
                                     ctx=ctx)
            if nc is not None:
                new_caches[f"p{i}"] = ({"k": nc["k"], "v": nc["v"]}
                                       if plan.kind == "attn" else nc)
        return hh, (new_caches if (decode or collect) else None)

    body = block
    if cfg.remat and mode == "train":
        body = _remat(cfg, block)

    xs: dict[str, Any] = {"params": params["blocks"]}
    if cfg.shared_attn_every:
        xs["lora"] = params["lora"]
    if decode:
        xs["caches"] = {k: v for k, v in caches.items() if k != "index"}

    h, ys = scanctl.scan(body, h, xs)
    return h, ys


# ---------------------------------------------------------------------------
# heads + losses
# ---------------------------------------------------------------------------


def _lm_head_weight(cfg: ModelConfig, params: Params) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def chunked_xent(h: jax.Array, w: jax.Array, labels: jax.Array,
                 *, vocab_chunk: int = 16_384) -> jax.Array:
    """Cross-entropy without materializing [B, S, V] logits.

    h [B, S, D]; w [D, V]; labels [B, S] int32 (-1 = pad).  Scans over V
    chunks with a running (max, sumexp, target-logit) triple; the body is
    rematerialized so backward recomputes each chunk's logits.
    """
    B, Sq, D = h.shape
    V = w.shape[1]
    nc = -(-V // vocab_chunk)
    pad = nc * vocab_chunk - V
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
    wc = w.reshape(D, nc, vocab_chunk).transpose(1, 0, 2)   # [nc, D, vc]
    labels_safe = jnp.maximum(labels, 0)

    @jax.checkpoint
    def body(carry, xs):
        m, s, tgt = carry
        w_chunk, c = xs
        logits = jnp.einsum("bsd,dv->bsv", h, w_chunk,
                            preferred_element_type=jnp.float32)
        if pad:  # mask the padded vocab tail in the LAST chunk
            vmask = (c * vocab_chunk + jnp.arange(vocab_chunk)) < V
            logits = jnp.where(vmask[None, None], logits, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        s = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[..., None]), axis=-1)
        idx = labels_safe - c * vocab_chunk
        inb = (idx >= 0) & (idx < vocab_chunk)
        picked = jnp.take_along_axis(
            logits, jnp.clip(idx, 0, vocab_chunk - 1)[..., None], -1)[..., 0]
        tgt = tgt + jnp.where(inb, picked, 0.0)
        return (m_new, s, tgt), None

    m0 = jnp.full((B, Sq), -jnp.inf, jnp.float32)
    s0 = jnp.zeros((B, Sq), jnp.float32)
    t0 = jnp.zeros((B, Sq), jnp.float32)
    (m, s, tgt), _ = scanctl.scan(
        body, (m0, s0, t0), (wc, jnp.arange(nc)))
    nll = (m + jnp.log(jnp.maximum(s, 1e-30))) - tgt
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def full_logits(cfg: ModelConfig, params: Params, h: jax.Array) -> jax.Array:
    """[B, S, D] -> [B, S, Vp] — only for small S (last-token / smoke)."""
    w = _lm_head_weight(cfg, params)
    return jnp.einsum("bsd,dv->bsv", h, w,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def lm_hidden(cfg: ModelConfig, params: Params, tokens: jax.Array,
              *, splan: ShardingPlan | None = None) -> jax.Array:
    """Train-mode backbone: tokens [B, S] -> normed hidden [B, S, D]."""
    splan = splan or make_plan(cfg, None)
    B, Sq = tokens.shape
    h = params["embed"][tokens]
    h = L.shard(h, splan.hidden, splan.mesh)
    positions = jnp.arange(Sq, dtype=jnp.int32)
    h, _ = _backbone(cfg, params, h, splan, positions, mode="train")
    return L.apply_norm(cfg, params["final_norm"], h)


def lm_loss(cfg: ModelConfig, params: Params, tokens: jax.Array,
            labels: jax.Array, *, splan: ShardingPlan | None = None,
            vocab_chunk: int = 16_384) -> jax.Array:
    h = lm_hidden(cfg, params, tokens, splan=splan)
    return chunked_xent(h, _lm_head_weight(cfg, params), labels,
                        vocab_chunk=vocab_chunk)


def lm_prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
               *, splan: ShardingPlan | None = None,
               ctx: int | None = None):
    """tokens [B, S] -> (last-token logits [B, Vp], caches).
    ``ctx``: total cache positions (> S for decode appends; serving)."""
    splan = splan or make_plan(cfg, None)
    B, Sq = tokens.shape
    h = params["embed"][tokens]
    h = L.shard(h, splan.hidden, splan.mesh)
    positions = jnp.arange(Sq, dtype=jnp.int32)
    h, caches = _backbone(cfg, params, h, splan, positions, mode="prefill",
                          ctx=ctx)
    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = full_logits(cfg, params, h[:, -1:])[:, 0]
    caches = dict(caches)
    caches["index"] = jnp.int32(Sq)
    return logits, caches


def lm_decode(cfg: ModelConfig, params: Params, caches: Params,
              token: jax.Array, *, splan: ShardingPlan | None = None):
    """token [B, 1] -> (logits [B, Vp], new caches)."""
    splan = splan or make_plan(cfg, None)
    h = params["embed"][token]
    h = L.shard(h, splan.decode_hidden, splan.mesh)
    h, new_caches = _backbone(cfg, params, h, splan, None, mode="decode",
                              caches=caches)
    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = full_logits(cfg, params, h)[:, 0]
    out = dict(new_caches)
    out["index"] = caches["index"] + 1
    return logits, out


# ---------------------------------------------------------------------------
# cache construction (decode dry-run input specs use the SHAPES of these)
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, ctx: int,
                *, dtype=jnp.bfloat16) -> Params:
    """Zero caches for a [batch] decode stream with ``ctx`` total positions.

    Attention layers: [nB, B, Sc, KV, dh] stacked K/V (windowed layers get
    the full ctx too — window masking happens at attend time; the memory
    saving of ring caches is a recorded hillclimb option).
    SSM layers: O(1) conv + state caches (the family's 'KV cache').
    """
    plans = make_layer_plans(cfg)
    nB = cfg.num_blocks
    KV, dh = cfg.num_kv_heads, cfg.head_dim

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (nB,) + x.shape), tree)

    caches: Params = {}
    for i, plan in enumerate(plans):
        if plan.kind == "ssm":
            caches[f"p{i}"] = stack(S.init_ssd_cache(cfg, batch, dtype))
        else:
            caches[f"p{i}"] = stack({
                "k": jnp.zeros((batch, ctx, KV, dh), dtype),
                "v": jnp.zeros((batch, ctx, KV, dh), dtype),
            })
    if cfg.shared_attn_every:
        caches["shared"] = stack({
            "k": jnp.zeros((batch, ctx, KV, dh), dtype),
            "v": jnp.zeros((batch, ctx, KV, dh), dtype),
        })
    caches["index"] = jnp.int32(0)
    return caches
