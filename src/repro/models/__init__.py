"""Model zoo for the assigned architecture pool (DESIGN.md §4)."""

from repro.models.registry import (  # noqa: F401
    ModelBundle,
    get_bundle,
    input_specs,
)
