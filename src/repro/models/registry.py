"""Registry: one ``ModelBundle`` of entry points per architecture family.

The bundle's functions are what the trainer, the serving engine, and the
dry-run lower; ``input_specs`` builds the ShapeDtypeStruct stand-ins for
every (arch × shape) cell — weak-type-correct, shardable, no allocation.

Modality frontends are STUBS per the assignment: ``seamless`` takes
precomputed frame embeddings, ``chameleon`` takes already-VQ-quantized
token ids from the unified vocab.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import ShardingPlan, make_plan
from repro.models import encdec as ED
from repro.models import lm as LM

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    """Family-dispatched entry points, all (cfg, params, ..., splan)."""
    init: Callable[..., Params]
    loss: Callable[..., jax.Array]            # loss(cfg, params, batch, splan)
    prefill: Callable[..., tuple]             # (cfg, params, batch, splan)
    decode: Callable[..., tuple]              # (cfg, params, caches, tok, splan)
    init_caches: Callable[..., Params]


def _lm_loss(cfg, params, batch, splan):
    return LM.lm_loss(cfg, params, batch["tokens"], batch["labels"],
                      splan=splan)


def _lm_prefill(cfg, params, batch, splan):
    return LM.lm_prefill(cfg, params, batch["tokens"], splan=splan)


def _lm_decode(cfg, params, caches, token, splan):
    return LM.lm_decode(cfg, params, caches, token, splan=splan)


def _ed_loss(cfg, params, batch, splan):
    return ED.encdec_loss(cfg, params, batch["frames"], batch["tokens"],
                          batch["labels"], splan=splan)


def _ed_prefill(cfg, params, batch, splan):
    return ED.encdec_prefill(cfg, params, batch["frames"], batch["tokens"],
                             splan=splan)


def _ed_decode(cfg, params, caches, token, splan):
    return ED.encdec_decode(cfg, params, caches, token, splan=splan)


_LM_BUNDLE = ModelBundle(init=LM.init_lm, loss=_lm_loss, prefill=_lm_prefill,
                         decode=_lm_decode, init_caches=LM.init_caches)
_ED_BUNDLE = ModelBundle(init=ED.init_encdec, loss=_ed_loss,
                         prefill=_ed_prefill, decode=_ed_decode,
                         init_caches=ED.init_encdec_caches)


def get_bundle(cfg: ModelConfig) -> ModelBundle:
    return _ED_BUNDLE if cfg.encoder_layers else _LM_BUNDLE


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                *, dtype=jnp.bfloat16) -> dict[str, Any]:
    """Inputs for the step the cell lowers (train/prefill: the batch;
    decode: {token, caches-with-ctx=seq_len})."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if cfg.encoder_layers:  # enc-dec: frames + decoder tokens (S_dec = S/r)
        Sd = max(S // cfg.dec_len_ratio, 1)
        if shape.kind == "train":
            return {"frames": sds((B, S, cfg.d_model), dtype),
                    "tokens": sds((B, Sd), i32),
                    "labels": sds((B, Sd), i32)}
        if shape.kind == "prefill":
            return {"frames": sds((B, S, cfg.d_model), dtype),
                    "tokens": sds((B, Sd), i32)}
        # decode: self cache of S positions + fixed 4096-frame memory
        caches = jax.eval_shape(
            lambda: ED.init_encdec_caches(cfg, B, S, dtype=dtype))
        return {"token": sds((B, 1), i32), "caches": caches}

    if shape.kind == "train":
        return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
    if shape.kind == "prefill":
        return {"tokens": sds((B, S), i32)}
    caches = jax.eval_shape(lambda: LM.init_caches(cfg, B, S, dtype=dtype))
    return {"token": sds((B, 1), i32), "caches": caches}
