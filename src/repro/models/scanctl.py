"""Scan control for the dry-run's cost accounting.

XLA's ``cost_analysis`` counts a while-loop body ONCE, not × trip count,
so a scan-over-layers model under-reports FLOPs/bytes/collectives by
~num_layers.  The dry-run therefore lowers with ``UNROLL=True`` (every
``lax.scan`` fully unrolled: exact HLO costs, larger compile) for the
§Roofline table, and with the default rolled scan for the fits-in-HBM
memory analysis (the production configuration).  Production code never
sets this.
"""

from __future__ import annotations

import jax

UNROLL = False


def scan(body, init, xs, **kw):
    return jax.lax.scan(body, init, xs,
                        unroll=True if UNROLL else 1, **kw)
