"""Encoder-decoder model (seamless-m4t-large-v2).

The speech frontend is a STUB per the assignment: the encoder consumes
PRECOMPUTED frame embeddings [B, S_enc, D] (``input_specs()`` supplies
ShapeDtypeStructs for them).  The decoder is a standard causal transformer
with cross-attention into the encoder memory; decoder length = encoder
length / cfg.dec_len_ratio (speech→text, DESIGN.md §4).

Decode cells carry {self-attn KV cache of S_ctx} + a FIXED 4096-frame
encoder memory (the paper-pool shape definition for enc-dec decode).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from repro.models import scanctl

from repro.configs.base import ModelConfig
from repro.dist.sharding import ShardingPlan, make_plan
from repro.models import layers as L
from repro.models.lm import (_stack_init, chunked_xent, full_logits)

Params = dict[str, Any]

DECODE_MEMORY_FRAMES = 4096  # fixed cross-attention memory at decode time


def _init_enc_layer(cfg: ModelConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "norm1": L.init_norm(cfg, cfg.d_model, dtype),
        "attn": L.init_attention(cfg, ks[0], cfg.d_model, dtype),
        "norm2": L.init_norm(cfg, cfg.d_model, dtype),
        "mlp": L.init_mlp(cfg, ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(cfg: ModelConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "norm1": L.init_norm(cfg, cfg.d_model, dtype),
        "attn": L.init_attention(cfg, ks[0], cfg.d_model, dtype),
        "norm_x": L.init_norm(cfg, cfg.d_model, dtype),
        "xattn": L.init_attention(cfg, ks[1], cfg.d_model, dtype),
        "norm2": L.init_norm(cfg, cfg.d_model, dtype),
        "mlp": L.init_mlp(cfg, ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def init_encdec(cfg: ModelConfig, key, *, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 5)
    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_padded, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "enc_blocks": _stack_init(
            partial(_init_enc_layer, cfg, dtype=dtype), ks[1],
            cfg.encoder_layers),
        "dec_blocks": _stack_init(
            partial(_init_dec_layer, cfg, dtype=dtype), ks[2],
            cfg.num_layers),
        "enc_norm": L.init_norm(cfg, cfg.d_model, dtype),
        "final_norm": L.init_norm(cfg, cfg.d_model, dtype),
        "lm_head": (jax.random.normal(ks[3], (cfg.d_model, cfg.vocab_padded),
                                      jnp.float32)
                    / np.sqrt(cfg.d_model)).astype(dtype),
    }


def encode(cfg: ModelConfig, params: Params, frames: jax.Array,
           *, splan: ShardingPlan) -> jax.Array:
    """frames [B, S_enc, D] (stub embeddings) -> memory [B, S_enc, D]."""
    h = L.shard(frames.astype(params["embed"].dtype), splan.hidden,
                splan.mesh)
    S_enc = h.shape[1]
    positions = jnp.arange(S_enc, dtype=jnp.int32)
    spec = L.AttnSpec(use_rope=True, causal=False)

    def body(hh, p):
        n1 = L.apply_norm(cfg, p["norm1"], hh)
        hh = hh + L.attention_forward(cfg, p["attn"], n1, spec, splan=splan,
                                      positions=positions)
        n2 = L.apply_norm(cfg, p["norm2"], hh)
        hh = L.shard(hh + L.apply_mlp(cfg, p["mlp"], n2), splan.hidden,
                     splan.mesh)
        return hh, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = scanctl.scan(body_fn, h, params["enc_blocks"])
    return L.apply_norm(cfg, params["enc_norm"], h)


def _decoder(cfg: ModelConfig, params: Params, h: jax.Array,
             memory: jax.Array, splan: ShardingPlan, *, mode: str,
             caches=None):
    S_dec = h.shape[1]
    positions = jnp.arange(S_dec, dtype=jnp.int32)
    self_spec = L.AttnSpec(use_rope=True, causal=True)
    cross_spec = L.AttnSpec(use_rope=False, causal=False, cross=True)
    mem_positions = jnp.arange(memory.shape[1], dtype=jnp.int32)
    decode = mode == "decode"
    collect = mode == "prefill"
    index = caches["index"] if decode else None

    def body(carry, xs):
        hh = carry
        p = xs["params"]
        new_cache = {}
        n1 = L.apply_norm(cfg, p["norm1"], hh)
        if decode:
            a, nc = L.attention_decode(
                cfg, p["attn"], n1, {**xs["caches"], "index": index},
                self_spec, splan=splan)
            new_cache = {"k": nc["k"], "v": nc["v"]}
        elif collect:
            a, nc = L.attention_forward_with_cache(
                cfg, p["attn"], n1, self_spec, splan=splan,
                positions=positions)
            new_cache = nc
        else:
            a = L.attention_forward(cfg, p["attn"], n1, self_spec,
                                    splan=splan, positions=positions)
        hh = hh + a
        nx = L.apply_norm(cfg, p["norm_x"], hh)
        x = L.attention_forward(cfg, p["xattn"], nx, cross_spec, splan=splan,
                                positions=positions, kv_x=memory,
                                kv_positions=mem_positions)
        hh = hh + x
        n2 = L.apply_norm(cfg, p["norm2"], hh)
        hh = hh + L.apply_mlp(cfg, p["mlp"], n2)
        hs = splan.decode_hidden if decode else splan.hidden
        hh = L.shard(hh, hs, splan.mesh)
        return hh, (new_cache if (decode or collect) else None)

    body_fn = jax.checkpoint(body) if (cfg.remat and mode == "train") \
        else body
    xs: dict[str, Any] = {"params": params["dec_blocks"]}
    if decode:
        xs["caches"] = caches["self"]
    h, ys = scanctl.scan(body_fn, h, xs)
    return h, ys


def encdec_loss(cfg: ModelConfig, params: Params, frames: jax.Array,
                dec_tokens: jax.Array, labels: jax.Array,
                *, splan: ShardingPlan | None = None,
                vocab_chunk: int = 16_384) -> jax.Array:
    splan = splan or make_plan(cfg, None)
    memory = encode(cfg, params, frames, splan=splan)
    h = params["embed"][dec_tokens]
    h = L.shard(h, splan.hidden, splan.mesh)
    h, _ = _decoder(cfg, params, h, memory, splan, mode="train")
    h = L.apply_norm(cfg, params["final_norm"], h)
    return chunked_xent(h, params["lm_head"], labels,
                        vocab_chunk=vocab_chunk)


def encdec_prefill(cfg: ModelConfig, params: Params, frames: jax.Array,
                   dec_tokens: jax.Array,
                   *, splan: ShardingPlan | None = None):
    """Returns (last-token logits, caches {self, memory, index})."""
    splan = splan or make_plan(cfg, None)
    memory = encode(cfg, params, frames, splan=splan)
    h = params["embed"][dec_tokens]
    h = L.shard(h, splan.hidden, splan.mesh)
    h, self_caches = _decoder(cfg, params, h, memory, splan, mode="prefill")
    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = full_logits(cfg, params, h[:, -1:])[:, 0]
    return logits, {"self": self_caches, "memory": memory,
                    "index": jnp.int32(dec_tokens.shape[1])}


def encdec_decode(cfg: ModelConfig, params: Params, caches: Params,
                  token: jax.Array, *, splan: ShardingPlan | None = None):
    splan = splan or make_plan(cfg, None)
    h = params["embed"][token]
    h = L.shard(h, splan.decode_hidden, splan.mesh)
    h, new_self = _decoder(cfg, params, h, caches["memory"], splan,
                           mode="decode", caches=caches)
    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = full_logits(cfg, params, h)[:, 0]
    return logits, {"self": new_self, "memory": caches["memory"],
                    "index": caches["index"] + 1}


def init_encdec_caches(cfg: ModelConfig, batch: int, ctx: int,
                       *, mem_frames: int = DECODE_MEMORY_FRAMES,
                       dtype=jnp.bfloat16) -> Params:
    KV, dh = cfg.num_kv_heads, cfg.head_dim
    nL = cfg.num_layers
    return {
        "self": {
            "k": jnp.zeros((nL, batch, ctx, KV, dh), dtype),
            "v": jnp.zeros((nL, batch, ctx, KV, dh), dtype),
        },
        "memory": jnp.zeros((batch, mem_frames, cfg.d_model), dtype),
        "index": jnp.int32(0),
    }
