"""Shared transformer building blocks for the assigned architecture pool.

Everything is a pure function over explicit param pytrees (nested dicts of
jnp arrays) so the same code paths run under ``jax.eval_shape`` (the dry-run
lowers against ShapeDtypeStructs — no allocation) and under jit on device.

Sharding is expressed through ``shard(x, spec, mesh)`` constraint points; the
actual PartitionSpecs come from ``repro.dist.sharding`` so the layer code is
policy-free.  Two attention distribution modes are supported (DESIGN.md §5):

  head-TP   q/k/v head axes sharded over ``model`` — only legal when BOTH
            num_heads and num_kv_heads divide the model-axis size
            (olmo/seamless/zamba2 on a 16-way axis);
  context   sequence axis sharded over ``model`` (context parallelism): K/V
            are all-gathered per layer, each device attends for its S-slice.
            Divisibility-proof (yi 56H, qwen2 28H, llama4 40H, ...).

Decode attends one query token against a cache whose SEQUENCE axis may be
sharded (flash-decoding): the softmax reductions over the sharded axis lower
to partial reduce + all-reduce — exactly the (m, l, o) merge — so the code
is written as plain jnp and XLA SPMD emits the merge collectives.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from repro.models import scanctl
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# sharding constraint helper
# ---------------------------------------------------------------------------


def shard(x: jax.Array, spec: P | None, mesh: Mesh | None) -> jax.Array:
    """Constraint point; no-op when mesh or spec is absent (smoke tests)."""
    if mesh is None or spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# initializers (all take an rng key; shapes only — dry-run eval_shapes these)
# ---------------------------------------------------------------------------


def _dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) > 1 else 1
    s = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def init_norm(cfg: ModelConfig, d: int, dtype) -> Params:
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if cfg.norm_type == "ln":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if cfg.norm_type == "nonparam_ln":
        return {}
    raise ValueError(cfg.norm_type)


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    # (nonparam_)ln
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    if cfg.norm_type == "ln":
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def _rms_head(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Per-head-dim RMS norm (chameleon / llama4 QK-norm)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, dh]; positions [..., S] (broadcastable)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs (paper pool: swiglu / squared-relu / gelu)
# ---------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key, d: int, f: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {"wi": _dense_init(ks[0], (d, f), dtype),
         "wo": _dense_init(ks[1], (f, d), dtype)}
    if cfg.mlp_type == "swiglu":
        p["wg"] = _dense_init(ks[2], (d, f), dtype)
    return p


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    h = x @ p["wi"]
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * h
    elif cfg.mlp_type == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    elif cfg.mlp_type == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(cfg.mlp_type)
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Static attention wiring for one layer position."""
    use_rope: bool = True
    window: int = 0          # >0: chunked-local (block-diagonal causal)
    causal: bool = True
    cross: bool = False      # cross-attention (enc-dec memory)


def init_attention(cfg: ModelConfig, key, d_in: int, dtype,
                   *, d_out: int | None = None) -> Params:
    H, KV, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    d_out = d_out if d_out is not None else d_in
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d_in, H * dh), dtype),
        "wk": _dense_init(ks[1], (d_in, KV * dh), dtype),
        "wv": _dense_init(ks[2], (d_in, KV * dh), dtype),
        "wo": _dense_init(ks[3], (H * dh, d_out), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), dtype)
        p["bk"] = jnp.zeros((KV * dh,), dtype)
        p["bv"] = jnp.zeros((KV * dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def _project_qkv(cfg: ModelConfig, p: Params, x: jax.Array,
                 kv_x: jax.Array | None = None):
    """x [B, S, Din] -> q [B, S, H, dh], k/v [B, Skv, KV, dh]."""
    H, KV, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kv_x = x if kv_x is None else kv_x
    q = x @ p["wq"]
    k = kv_x @ p["wk"]
    v = kv_x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(*x.shape[:-1], H, dh)
    k = k.reshape(*kv_x.shape[:-1], KV, dh)
    v = v.reshape(*kv_x.shape[:-1], KV, dh)
    if cfg.qk_norm:
        q = _rms_head(q, p["q_norm"])
        k = _rms_head(k, p["k_norm"])
    return q, k, v


def _sdpa(q, k, v, mask, *, kv_groups: int) -> jax.Array:
    """Grouped scaled-dot-product attention.

    q [B, Sq, H, dh] with H = KV * kv_groups; k/v [B, Sk, KV, dh];
    mask [Sq, Sk] bool (True = attend) or None.  f32 softmax.
    """
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, Sq, KV, kv_groups, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits / np.sqrt(dh)
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


def _chunked_sdpa(q, k, v, *, kv_groups: int, q_positions, kv_positions,
                  spec: AttnSpec, chunk: int) -> jax.Array:
    """Flash-style blockwise attention: scan over KV chunks with running
    (m, l, acc).  Never materializes the [Sq, Sk] score matrix — the memory
    bound that makes prefill_32k / train_4k lowerable.

    q [B, Sq, H, dh]; k/v [B, Sk, KV, dh]; positions give causal/window
    masks under context parallelism (q_positions are the GLOBAL indices of
    this shard's queries).
    """
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad),
                               constant_values=jnp.iinfo(jnp.int32).max)
    kc = k.reshape(B, n_chunks, chunk, KV, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, dh).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(n_chunks, chunk)

    qg = q.reshape(B, Sq, KV, kv_groups, dh)
    scale = 1.0 / np.sqrt(dh)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, pj = xs
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kj,
                            preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((Sq, chunk), bool)
        if spec.causal:
            mask &= q_positions[:, None] >= pj[None, :]
        if spec.window > 0:  # chunked-local (llama4 iRoPE)
            mask &= (q_positions[:, None] // spec.window) == \
                    (pj[None, :] // spec.window)
        mask &= pj[None, :] < jnp.iinfo(jnp.int32).max  # padding
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, kv_groups, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, kv_groups, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, kv_groups, Sq, dh), jnp.float32)
    (m, l, acc), _ = scanctl.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, dh)
    return out.astype(q.dtype)


def attention_forward(cfg: ModelConfig, p: Params, x: jax.Array,
                      spec: AttnSpec, *, splan=None,
                      positions: jax.Array | None = None,
                      kv_x: jax.Array | None = None,
                      kv_positions: jax.Array | None = None,
                      attn_chunk: int | None = None) -> jax.Array:
    """Full-sequence attention (train / prefill).  x [B, S, D].

    ``splan`` (repro.dist.sharding.ShardingPlan) steers the distribution:
    head-TP constrains the head axis to 'model'; context parallelism keeps
    queries S-sharded and constrains K/V replicated on 'model' (the
    per-layer KV all-gather).
    """
    B, S = x.shape[:2]
    H, KV = cfg.num_heads, cfg.num_kv_heads
    kv_groups = H // KV
    q, k, v = _project_qkv(cfg, p, x, kv_x)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if kv_positions is None:
        kv_positions = (positions if kv_x is None
                        else jnp.arange(k.shape[1], dtype=jnp.int32))
    if spec.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        if not spec.cross:
            k = apply_rope(k, kv_positions, cfg.rope_theta)
    if splan is not None and splan.mesh is not None:
        q = shard(q, splan.qkv, splan.mesh)
        k = shard(k, splan.kv_ctx, splan.mesh)
        v = shard(v, splan.kv_ctx, splan.mesh)
    out = _chunked_sdpa(q, k, v, kv_groups=kv_groups, q_positions=positions,
                        kv_positions=kv_positions, spec=spec,
                        chunk=min(attn_chunk or cfg.attn_kv_chunk,
                                  k.shape[1]))
    return out.reshape(B, S, H * cfg.head_dim) @ p["wo"]


def attention_forward_with_cache(cfg: ModelConfig, p: Params, x: jax.Array,
                                 spec: AttnSpec, *, splan=None,
                                 positions: jax.Array | None = None,
                                 ctx: int | None = None,
                                 attn_chunk: int | None = None):
    """Prefill: like attention_forward but also emits the {k, v} cache
    (post-RoPE), zero-padded to ``ctx`` positions for later decode appends."""
    B, S = x.shape[:2]
    H, KV = cfg.num_heads, cfg.num_kv_heads
    kv_groups = H // KV
    q, k, v = _project_qkv(cfg, p, x)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if spec.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if splan is not None and splan.mesh is not None:
        q = shard(q, splan.qkv, splan.mesh)
        k = shard(k, splan.kv_ctx, splan.mesh)
        v = shard(v, splan.kv_ctx, splan.mesh)
    out = _chunked_sdpa(q, k, v, kv_groups=kv_groups, q_positions=positions,
                        kv_positions=positions, spec=spec,
                        chunk=min(attn_chunk or cfg.attn_kv_chunk,
                                  k.shape[1]))
    out = out.reshape(B, S, H * cfg.head_dim) @ p["wo"]
    ctx = ctx or S
    if ctx > S:
        k = jnp.pad(k, ((0, 0), (0, ctx - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, ctx - S), (0, 0), (0, 0)))
    if splan is not None and splan.mesh is not None:
        k = shard(k, splan.decode_cache, splan.mesh)
        v = shard(v, splan.decode_cache, splan.mesh)
    return out, {"k": k, "v": v}


def attention_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                     cache: dict[str, jax.Array], spec: AttnSpec,
                     *, splan=None,
                     update_cache: bool = True) -> tuple[jax.Array, dict]:
    """One-token decode. x [B, 1, D]; cache {k,v: [B, Sc, KV, dh], index: []}.

    The cache S axis may be sharded (flash-decoding) — the softmax over it
    lowers to partial reduce + all-reduce (the (m,l,o) merge), so this is
    plain jnp.  Local (windowed) layers keep a ring cache of size window.
    """
    B = x.shape[0]
    H, KV, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kv_groups = H // KV
    Sc = cache["k"].shape[1]
    # index: [] (lockstep batch) or [B] (continuous batching, per-slot)
    index = jnp.broadcast_to(jnp.atleast_1d(cache["index"]), (B,))
    q, k_new, v_new = _project_qkv(cfg, p, x)
    pos = index[:, None]
    if spec.use_rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        if not spec.cross:
            k_new = apply_rope(k_new, pos, cfg.rope_theta)
    if spec.cross:
        k, v = cache["k"], cache["v"]
        valid = jnp.ones((B, Sc), bool)
        new_cache = cache
    else:
        slot = jnp.mod(index, Sc)
        bix = jnp.arange(B)
        k = cache["k"].at[bix, slot].set(k_new[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[bix, slot].set(v_new[:, 0].astype(cache["v"].dtype))
        slots = jnp.arange(Sc)
        valid = slots[None, :] <= index[:, None]
        if spec.window > 0:  # chunked-local (iRoPE): same window block only
            valid &= (slots[None, :] // spec.window) == \
                (index[:, None] // spec.window)
        if splan is not None and splan.mesh is not None:
            k = shard(k, splan.decode_cache, splan.mesh)
            v = shard(v, splan.decode_cache, splan.mesh)
        new_cache = ({"k": k, "v": v, "index": cache["index"] + 1}
                     if update_cache else cache)

    logits = jnp.einsum("bqkgd,bskd->bkgqs",
                        q.reshape(B, 1, KV, kv_groups, dh), k,
                        preferred_element_type=jnp.float32) / np.sqrt(dh)
    logits = jnp.where(valid[:, None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, H * dh).astype(x.dtype)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# Mixture of Experts (llama4): top-1 routing + shared expert, EP all-to-all
# ---------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key, d: int, f: int, dtype) -> Params:
    E = cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, E), jnp.float32),
        "wi": _dense_init(ks[1], (E, d, f), dtype),
        "wg": _dense_init(ks[2], (E, d, f), dtype),
        "wo": _dense_init(ks[3], (E, f, d), dtype),
    }
    if cfg.shared_expert:
        p["shared"] = init_mlp(
            dataclasses.replace(cfg, mlp_type="swiglu"), ks[4], d, f, dtype)
    return p


def _moe_dispatch_compute(p: Params, tokens: jax.Array, capacity: int,
                          *, ep_axis: str | None) -> jax.Array:
    """tokens [T, D] -> routed expert output [T, D] (top-1, capacity drop).

    Local math: scatter tokens into an [E, C, D] buffer keyed by
    (expert, position-in-expert); batched expert GEMMs; gather back.
    With ``ep_axis`` (inside shard_map) the buffer's E axis is exchanged via
    all_to_all so each device computes ONLY its local experts — the paper's
    model parallelism (trees ↔ experts; DESIGN.md §4) at the MoE layer.
    """
    T, D = tokens.shape
    E = p["router"].shape[1]
    logits = tokens.astype(jnp.float32) @ p["router"]          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate = jnp.max(probs, axis=-1)                             # [T]
    eidx = jnp.argmax(probs, axis=-1).astype(jnp.int32)        # [T]

    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.int32)          # [T, E]
    pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - 1,
                              eidx[:, None], 1)[:, 0]          # [T]
    keep = pos < capacity
    slot = jnp.where(keep, eidx * capacity + pos, E * capacity)

    buf = jnp.zeros((E * capacity + 1, D), tokens.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], tokens, 0))
    buf = buf[:-1].reshape(E, capacity, D)

    wi, wg, wo = p["wi"], p["wg"], p["wo"]
    if ep_axis is not None:
        # self-transposing all_to_all (split==concat axis) so the VJP maps
        # back onto the same primitive with matching axis order
        n = jax.lax.axis_size(ep_axis)
        buf = jax.lax.all_to_all(buf.reshape(n, E // n, capacity, D),
                                 ep_axis, split_axis=0, concat_axis=0,
                                 tiled=False)          # [n_src, E/n, C, D]
        buf = jnp.moveaxis(buf, 0, 1).reshape(E // n, n * capacity, D)
    h = jnp.einsum("ecd,edf->ecf", buf, wi,
                   preferred_element_type=jnp.float32).astype(tokens.dtype)
    g = jnp.einsum("ecd,edf->ecf", buf, wg,
                   preferred_element_type=jnp.float32).astype(tokens.dtype)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo,
                   preferred_element_type=jnp.float32).astype(tokens.dtype)
    if ep_axis is not None:
        n = jax.lax.axis_size(ep_axis)
        y = jnp.moveaxis(y.reshape(E // n, n, capacity, D), 1, 0)
        y = jax.lax.all_to_all(y, ep_axis, split_axis=0, concat_axis=0,
                               tiled=False)            # [n_dst, E/n, C, D]
        y = y.reshape(E, capacity, D)
    y = jnp.concatenate([y.reshape(E * capacity, D),
                         jnp.zeros((1, D), y.dtype)], 0)
    out = y[slot] * (gate * keep)[:, None].astype(y.dtype)
    return out


def apply_moe(cfg: ModelConfig, p: Params, x: jax.Array, *,
              splan=None) -> jax.Array:
    """x [B, S, D] -> [B, S, D]; EP over the mesh 'model' axis when present."""
    B, S, D = x.shape
    mesh = splan.mesh if splan is not None else None
    use_ep = (mesh is not None and "model" in mesh.axis_names
              and cfg.num_experts % mesh.shape["model"] == 0)
    cf = cfg.capacity_factor

    if not use_ep:
        cap = max(1, int(B * S * cf / cfg.num_experts))
        out = _moe_dispatch_compute(p, x.reshape(B * S, D), cap, ep_axis=None)
        out = out.reshape(B, S, D)
    else:
        from jax.experimental.shard_map import shard_map
        n_model = mesh.shape["model"]
        data_axes = splan.data_axes
        # local tokens per (data..., model) block (activations are CP-sharded
        # for the MoE archs: [B -> data..., S -> model, D])
        t_local = (B // int(np.prod([mesh.shape[a] for a in data_axes]))) * \
                  (S // n_model)
        # capacity per SOURCE device per expert (before all_to_all concat)
        cap_src = max(1, int(t_local * cf / cfg.num_experts))

        def local(xb, router, wi, wg, wo):
            b, s, d = xb.shape
            pp = {"router": router, "wi": wi, "wg": wg, "wo": wo}
            y = _moe_dispatch_compute(pp, xb.reshape(b * s, d), cap_src,
                                      ep_axis="model")
            return y.reshape(b, s, d)

        da = data_axes if len(data_axes) > 1 else data_axes[0]
        in_specs = (P(da, "model", None),                 # x
                    P(),                                  # router replicated
                    P("model", None, None),               # wi (E sharded)
                    P("model", None, None),               # wg
                    P("model", None, None))               # wo
        out = shard_map(local, mesh=mesh, in_specs=in_specs,
                        out_specs=P(da, "model", None),
                        check_rep=False)(
            x, p["router"], p["wi"], p["wg"], p["wo"])

    if cfg.shared_expert:
        shared_cfg = dataclasses.replace(cfg, mlp_type="swiglu")
        out = out + apply_mlp(shared_cfg, p["shared"], x)
    return out


def moe_decode(cfg: ModelConfig, p: Params, x: jax.Array,
               *, splan=None) -> jax.Array:
    """Decode-path MoE.

    Default: per-token expert-weight gather — simple, but against
    E-sharded expert weights XLA materializes cross-device weight
    gathers (the collective bottleneck §Perf found on decode cells).

    ``cfg.moe_decode_ep``: EP-local compute + psum — tokens are tiny at
    decode, so replicate them over 'model', let each device run ONLY its
    local experts (zero-masking tokens routed elsewhere) and psum the
    [B, 1, D] outputs: moves activations (KB), never weights (GB).
    """
    B, S, D = x.shape
    mesh = splan.mesh if splan is not None else None
    use_ep = (cfg.moe_decode_ep and mesh is not None
              and "model" in mesh.axis_names
              and cfg.num_experts % mesh.shape["model"] == 0)
    if use_ep:
        from jax.experimental.shard_map import shard_map
        n = mesh.shape["model"]
        E = cfg.num_experts
        E_l = E // n
        da = (splan.data_axes if len(splan.data_axes) > 1
              else (splan.data_axes[0] if splan.data_axes else None))
        b_spec = splan.decode_hidden[0]

        def local(xb, router, wi, wg, wo):
            # xb [b, s, d] (replicated over model); wi/wg/wo local experts.
            # Masked EINSUM over all E_l local experts: token counts are
            # tiny at decode, so E_l× extra FLOPs are free while a
            # per-token weight gather would materialize [T, D, F] copies
            # (the memory term iteration 2 removed, EXPERIMENTS §Perf).
            my = jax.lax.axis_index("model")
            b, s, d = xb.shape
            t = xb.reshape(b * s, d)
            logits = t.astype(jnp.float32) @ router
            gate = jnp.max(jax.nn.softmax(logits, -1), -1)
            eidx = jnp.argmax(logits, -1).astype(jnp.int32)
            local_e = eidx - my * E_l                    # [T]
            onehot = jax.nn.one_hot(local_e, E_l, dtype=t.dtype)  # [T, E_l]
            h = jnp.einsum("td,edf->tef", t, wi)         # [T, E_l, F]
            g = jnp.einsum("td,edf->tef", t, wg)
            y = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * h, wo)
            y = jnp.einsum("ted,te->td", y, onehot)
            y = y * gate[:, None].astype(y.dtype)
            return jax.lax.psum(y.reshape(b, s, d), "model")

        out = shard_map(
            local, mesh=mesh,
            in_specs=(P(b_spec, None, None), P(),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=P(b_spec, None, None), check_rep=False)(
            x, p["router"], p["wi"], p["wg"], p["wo"])
    else:
        tokens = x.reshape(B * S, D)
        logits = tokens.astype(jnp.float32) @ p["router"]
        gate = jnp.max(jax.nn.softmax(logits, -1), -1)
        eidx = jnp.argmax(logits, -1)
        wi = p["wi"][eidx]                               # [T, D, F] gather
        wg = p["wg"][eidx]
        wo = p["wo"][eidx]
        h = jnp.einsum("td,tdf->tf", tokens, wi)
        g = jnp.einsum("td,tdf->tf", tokens, wg)
        y = jnp.einsum("tf,tfd->td", jax.nn.silu(g) * h, wo)
        out = (y * gate[:, None].astype(y.dtype)).reshape(B, S, D)
    if cfg.shared_expert:
        shared_cfg = dataclasses.replace(cfg, mlp_type="swiglu")
        out = out + apply_mlp(shared_cfg, p["shared"], x)
    return out
