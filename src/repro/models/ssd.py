"""Mamba2 SSD (state-space duality) block — chunked scan + O(1) decode.

Implements the SSD algorithm of Dao & Gu (arXiv:2405.21060): within a chunk
of length Q the recurrence is computed as a masked quadratic form (MXU
matmuls); across chunks a tiny recurrent state [H, P, N] is carried by a
``lax.scan``.  This is the TPU-friendly middle point between the pure
recurrence (serial, VPU-bound) and the pure quadratic form (O(S²)).

Sharding (DESIGN.md §5): SSD heads are independent, so the head axis H is
the natural TPU 'model'-axis shard (80 = 2·2560/64 divides 16 for mamba2;
d_inner/ssm channels shard with it).  The sequence axis stays UNSHARDED for
SSM layers — the chunk scan is along S — which is why hybrid archs reshard
activations between attention (context-parallel) and SSM (head-parallel)
layers only when both exist.

Decode carries {conv_state [B, W-1, C_conv], ssd_state [B, H, P, N]} — the
"KV cache" of this family is O(1) in sequence length (noted in §Roofline).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from repro.models import scanctl

from repro.configs.base import ModelConfig

Params = dict[str, Any]


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) > 1 else 1
    s = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def init_ssd(cfg: ModelConfig, key, dtype) -> Params:
    D = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    W = cfg.conv_width
    conv_ch = di + 2 * N
    ks = jax.random.split(key, 4)
    return {
        # fused input projection -> [z(di), xBC(di + 2N), dt(H)]
        "in_proj": _dense_init(ks[0], (D, 2 * di + 2 * N + H), dtype),
        "conv_w": _dense_init(ks[1], (W, conv_ch), dtype, scale=1.0 / W),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": _dense_init(ks[2], (di, D), dtype),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di:di + di + 2 * N]
    dt = proj[..., di + di + 2 * N:]
    assert dt.shape[-1] == H
    return z, xBC, dt


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array) -> jax.Array:
    """Mamba2's RMSNorm(y * silu(z)) output gate."""
    g = (y * jax.nn.silu(z)).astype(jnp.float32)
    g = g * jax.lax.rsqrt(jnp.mean(g * g, -1, keepdims=True) + 1e-6)
    return (g * scale.astype(jnp.float32)).astype(y.dtype)


def _segsum(x: jax.Array) -> jax.Array:
    """x [..., Q] -> [..., Q, Q] with out[i, j] = sum_{j < k <= i} x[k],
    -inf above the diagonal (the 1-SS mask of the SSD paper)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_forward(cfg: ModelConfig, p: Params, x: jax.Array,
                *, chunk: int | None = None, return_cache: bool = False,
                splan=None):
    """Full-sequence SSD. x [B, S, D] -> [B, S, D]. S % chunk == 0 required
    (callers pad); the chunk scan carries the [B, H, P, N] state.
    ``return_cache`` additionally emits the decode cache (prefill).

    ``splan`` pins the HEAD axis to the mesh 'model' axis through the whole
    chunk computation — without the constraints XLA replicates H and every
    chip pays 16× the L-matrix traffic (§Perf mamba2 iteration 3)."""
    B, S_true, D = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P_ = cfg.ssm_headdim
    Q = min(chunk or cfg.ssm_chunk, S_true)
    S = -(-S_true // Q) * Q                       # pad S up to a Q multiple
    if S != S_true:
        x = jnp.pad(x, ((0, 0), (0, S - S_true), (0, 0)))
    nC = S // Q

    proj = x @ p["in_proj"]
    z, xBC_raw, dt = _split_proj(cfg, proj)
    if S != S_true:  # pad positions: dt=0 => no state update, no output
        smask = (jnp.arange(S) < S_true)[None, :, None]
        dt = jnp.where(smask, dt, -1e9)           # softplus(-1e9) == 0

    # causal depthwise conv over S (width W), SiLU
    W = cfg.conv_width
    pad = jnp.pad(xBC_raw, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S] * p["conv_w"][i] for i in range(W))
    xBC = jax.nn.silu(conv + p["conv_b"])

    xs = xBC[..., :di].reshape(B, S, H, P_)
    B_ = xBC[..., di:di + N]                               # [B, S, N] (1 group)
    C_ = xBC[..., di + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B, S, H]
    A = -jnp.exp(p["A_log"])                               # [H]
    dA = dt * A                                            # [B, S, H]

    if splan is not None and splan.mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as Pspec
        da = (splan.data_axes if len(splan.data_axes) > 1
              else (splan.data_axes[0] if splan.data_axes else None))
        model = splan.model_axis
        wsc = jax.lax.with_sharding_constraint
        mesh = splan.mesh
        xs = wsc(xs, NamedSharding(mesh, Pspec(da, None, model, None)))
        z = wsc(z, NamedSharding(mesh, Pspec(da, None, model)))
        B_ = wsc(B_, NamedSharding(mesh, Pspec(da, None, None)))
        C_ = wsc(C_, NamedSharding(mesh, Pspec(da, None, None)))
        dA = wsc(dA, NamedSharding(mesh, Pspec(da, None, model)))

    # chunked layout [nC, B, Q, ...] for the scan
    def chunked(t, tail):
        return t.reshape((B, nC, Q) + tail).transpose((1, 0, 2) +
                                                      tuple(range(3, 3 + len(tail))))
    xs_c = chunked(xs * dt[..., None].astype(xs.dtype), (H, P_))
    x_raw_c = chunked(xs, (H, P_))
    B_c = chunked(B_, (N,))
    C_c = chunked(C_, (N,))
    dA_c = chunked(dA, (H,))

    def body(state, inp):
        xdt, xraw, Bj, Cj, dAj = inp                       # per-chunk
        # within-chunk quadratic term
        L = jnp.exp(_segsum(dAj.transpose(0, 2, 1)))       # [B, H, Q, Q]
        scores = jnp.einsum("bqn,bsn->bqs", Cj, Bj,
                            preferred_element_type=jnp.float32)
        M = scores[:, None] * L                            # [B, H, Q, Q]
        y_diag = jnp.einsum("bhqs,bshp->bqhp", M.astype(xdt.dtype), xdt,
                            preferred_element_type=jnp.float32)
        # contribution of the carried state
        cum = jnp.cumsum(dAj, axis=1)                      # [B, Q, H]
        y_off = jnp.einsum("bqn,bhpn,bqh->bqhp", Cj, state,
                           jnp.exp(cum).astype(Cj.dtype),
                           preferred_element_type=jnp.float32)
        # new chunk state
        decay = jnp.exp(cum[:, -1:, :] - cum)              # [B, Q, H]
        new_state = jnp.einsum("bsn,bsh,bshp->bhpn", Bj,
                               decay.astype(Bj.dtype), xdt,
                               preferred_element_type=jnp.float32)
        state = state * jnp.exp(cum[:, -1])[:, :, None, None] + new_state
        y = (y_diag + y_off).astype(xraw.dtype) + \
            xraw * p["D"][None, None, :, None].astype(xraw.dtype)
        return state, y

    state0 = jnp.zeros((B, H, P_, N), jnp.float32)
    final_state, ys = scanctl.scan(body, state0,
                                   (xs_c, x_raw_c, B_c, C_c, dA_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, di)
    out = _gated_rmsnorm(y, z, p["norm"]) @ p["out_proj"]
    out = out[:, :S_true]
    if not return_cache:
        return out
    conv_cache = xBC_raw[:, S_true - (W - 1):S_true, :] if W > 1 else \
        xBC_raw[:, :0, :]
    return out, {"conv": conv_cache, "state": final_state}


def ssd_forward_with_cache(cfg: ModelConfig, p: Params, x: jax.Array,
                           *, chunk: int | None = None, splan=None):
    return ssd_forward(cfg, p, x, chunk=chunk, return_cache=True,
                       splan=splan)


def init_ssd_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim,
                            cfg.ssm_state), jnp.float32),
    }


def ssd_decode(cfg: ModelConfig, p: Params, x: jax.Array,
               cache: dict) -> tuple[jax.Array, dict]:
    """One-token recurrent step. x [B, 1, D]."""
    B = x.shape[0]
    di, N, H, P_ = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    proj = x[:, 0] @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, proj)

    hist = jnp.concatenate([cache["conv"], xBC[:, None]], axis=1)  # [B, W, C]
    conv = jnp.einsum("bwc,wc->bc", hist, p["conv_w"]) + p["conv_b"]
    xBC_a = jax.nn.silu(conv)
    new_conv = hist[:, 1:]

    xt = xBC_a[:, :di].reshape(B, H, P_)
    Bt = xBC_a[:, di:di + N]
    Ct = xBC_a[:, di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # [B, H]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                           # [B, H]

    state = cache["state"] * dA[:, :, None, None] + \
        jnp.einsum("bhp,bn,bh->bhpn", xt.astype(jnp.float32), Bt,
                   dt)
    y = jnp.einsum("bhpn,bn->bhp", state, Ct).astype(x.dtype)
    y = y + xt * p["D"][None, :, None].astype(xt.dtype)
    y = y.reshape(B, di)
    out = _gated_rmsnorm(y, z, p["norm"]) @ p["out_proj"]
    return out[:, None], {"conv": new_conv, "state": state}
