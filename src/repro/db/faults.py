"""Reliability layer for the scan/serve data plane: fault injection,
bounded retries, deadlines, and structured scan faults.

The paper's motivating deployments (fraud gating, ranking, admission)
put the forest on the REQUEST path, where a stalled DMA or a dead drain
worker is an outage, not a slow benchmark.  The training side already
has a fault discipline (``train/fault.py``: step-level injection,
restart invariants); this module ports it to inference, where the unit
of failure is not a training step but a call at one of the data plane's
NAMED INJECTION SITES:

  ``page_dma_in``     host/disk page block -> device transfer
                      (``StreamingScanExecutor`` acquire, loader
                      transfer paths)
  ``drain_copy_out``  device predictions -> host result buffer
                      (the drain worker's per-batch write)
  ``disk_page_read``  reading disk-tier mmap pages (executor
                      ``page_slice`` on the disk tier, ``store.move``
                      off the disk tier)
  ``kernel_launch``   running a batch's compiled kernel stages
  ``drain_worker``    the dedicated ``scan-drain`` worker thread itself
                      (models THREAD DEATH, not a recoverable write
                      error — the ladder's answer is mid-scan fallback
                      to the synchronous drain, not a retry)

``FaultInjector`` arms sites deterministically (fire at the Nth call)
or probabilistically (seeded, reproducible); ``RetryPolicy`` bounds the
recovery attempts around every site with exponential backoff and
DETERMINISTIC jitter (hash-derived, replay-stable — no wall-clock or
process-salt randomness, mirroring the determinism rules of
``train/data.py``).  ``Deadline`` is the cooperative per-scan budget:
checked between batches and before every backoff sleep, never
preempting a jitted call mid-flight (an honest contract on XLA — the
same reason stage timing is measured at stage boundaries).

Recovery that cannot succeed surfaces as a structured ``ScanFault``
carrying the site, attempt count, and rows completed — never a silent
wrong answer, never a hang.  See ``docs/reliability.md`` for the
degradation ladders built on top of these primitives in
``db/executor.py`` / ``db/query.py``.

Everything here runs in PYTHON DRIVER CODE between jitted calls: no
injection point, retry wrapper, or deadline check is ever traced into a
stage, so the zero-fault hot path stays the compiled path
(``BENCH_faults.json`` records the measured overhead; the acceptance
bound is 5%).
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any, Callable

import numpy as np

from repro.obs import TRACER

__all__ = ["FAULT_SITES", "InjectedFault", "ScanFault", "DeadlineExceeded",
           "FaultInjector", "RetryPolicy", "Deadline", "DegradedReport"]

#: the named injection points of the scan/serve data plane
FAULT_SITES = ("page_dma_in", "drain_copy_out", "disk_page_read",
               "kernel_launch", "drain_worker")


class InjectedFault(RuntimeError):
    """Raised by ``FaultInjector.fire`` at an armed site — the synthetic
    stand-in for a transfer error / failed read / kernel launch failure.
    Retry policies treat it as retryable by default."""

    def __init__(self, site: str, call: int):
        super().__init__(f"injected fault at site {site!r} (call {call})")
        self.site = site
        self.call = call


class ScanFault(RuntimeError):
    """A scan-path failure that exhausted its recovery ladder.

    Structured: carries the fault ``site``, how many ``attempts`` the
    retry policy made at that site, how many ``rows_completed`` had
    already landed in the result buffer, and the underlying ``cause``.
    This is the data plane's ONLY terminal error shape — callers never
    have to parse message strings to find out what died where.
    """

    def __init__(self, site: str, *, attempts: int, rows_completed: int,
                 cause: BaseException | None = None,
                 detail: str = ""):
        msg = (f"scan fault at site {site!r} after {attempts} attempt(s), "
               f"{rows_completed} rows completed")
        if detail:
            msg += f": {detail}"
        if cause is not None:
            msg += f" (cause: {cause!r})"
        super().__init__(msg)
        self.site = site
        self.attempts = attempts
        self.rows_completed = rows_completed
        self.cause = cause


class DeadlineExceeded(Exception):
    """Internal control-flow signal: a deadline expired inside a retry
    loop.  The executor converts it into a graceful partial result
    (``deadline_hit``), so it should never escape to callers."""

    def __init__(self, site: str, cause: BaseException | None = None):
        super().__init__(f"deadline exceeded during retries at {site!r}")
        self.site = site
        self.cause = cause


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _SiteRule:
    """Arming state for one site."""

    fail_at: int | None = None       # fire at the Nth call (1-based)
    probability: float = 0.0         # else fire with this probability
    times: int = 1                   # how many fires before disarming
    fired: int = 0                   # fires so far
    rng: Any = None                  # seeded per-site generator


class FaultInjector:
    """Site-based fault injection for the scan/serve data plane.

    Modeled on ``train/fault.py``'s ``FailureInjector``, generalized
    from "raise at step N" to named sites with two deterministic modes:

      * ``inject(site, fail_at=N)`` — fire at exactly the Nth call of
        that site (1-based), ``times`` consecutive calls starting there;
      * ``inject(site, probability=p)`` — fire each call with
        probability ``p`` from a generator seeded by (seed, site), so a
        given (seed, call sequence) always fires at the same calls.

    ``fire(site)`` is placed at each injection point by the production
    code; it counts the call and raises ``InjectedFault`` when armed.
    A disarmed injector (or ``injector=None`` at the call sites) costs
    one attribute check per site call — nothing is traced.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.calls: dict[str, int] = {s: 0 for s in FAULT_SITES}
        self._rules: dict[str, _SiteRule] = {}

    def inject(self, site: str, *, fail_at: int | None = None,
               probability: float | None = None,
               times: int = 1) -> "FaultInjector":
        """Arm ``site``.  Exactly one of ``fail_at`` / ``probability``.
        Returns self so arming chains."""
        if site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {site!r}; "
                             f"expected one of {FAULT_SITES}")
        if (fail_at is None) == (probability is None):
            raise ValueError("arm with exactly one of fail_at=/probability=")
        rule = _SiteRule(fail_at=fail_at, times=times)
        if probability is not None:
            rule.probability = float(probability)
            sd = int.from_bytes(hashlib.blake2s(
                f"{self.seed}:{site}".encode(), digest_size=8).digest(),
                "big")
            rule.rng = np.random.default_rng(sd)
        self._rules[site] = rule
        return self

    def fire(self, site: str) -> None:
        """Count one call at ``site``; raise ``InjectedFault`` if armed."""
        self.calls[site] = call = self.calls.get(site, 0) + 1
        rule = self._rules.get(site)
        if rule is None or rule.fired >= rule.times:
            return
        if rule.fail_at is not None:
            hit = rule.fail_at <= call < rule.fail_at + rule.times
        else:
            hit = bool(rule.rng.random() < rule.probability)
        if hit:
            rule.fired += 1
            # observability: an armed site firing is a span event on the
            # enclosing span (`fault.injected`, docs/observability.md) —
            # traces show exactly which call of which site faulted
            TRACER.event("fault.injected", site=site, call=call)
            raise InjectedFault(site, call)

    @property
    def total_fired(self) -> int:
        """Faults fired so far, across every site (the executor
        snapshots this around a scan to fill ``ScanStats
        .faults_injected``)."""
        return sum(r.fired for r in self._rules.values())


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


class Deadline:
    """A cooperative wall-clock budget for one scan / query.

    Checked at batch boundaries and before backoff sleeps — never
    preempting a jitted call (XLA offers no safe mid-kernel cancel, so
    pretending otherwise would be dishonest accounting).  ``None``
    budget means no deadline (``expired`` is always False).
    """

    def __init__(self, budget_s: float | None,
                 start: float | None = None):
        self.budget_s = budget_s
        self.start = time.perf_counter() if start is None else start

    @property
    def expired(self) -> bool:
        return (self.budget_s is not None
                and time.perf_counter() - self.start >= self.budget_s)

    def remaining(self) -> float:
        if self.budget_s is None:
            return float("inf")
        return max(0.0, self.budget_s - (time.perf_counter() - self.start))


# ---------------------------------------------------------------------------
# retries
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with exponential backoff and deterministic jitter.

    ``run(fn, site=...)`` calls ``fn`` up to ``max_attempts`` times,
    sleeping ``backoff_base_s * backoff_factor**k`` (capped at
    ``max_backoff_s``) plus a hash-derived jitter between attempts.
    The jitter is a pure function of (site, attempt) — replay-stable,
    no process-salted ``hash()`` and no wall-clock entropy — so two
    runs of the same failing scan back off identically.

    Budgets: ``per_call_budget_s`` bounds the TOTAL time one logical
    call may spend across its attempts (a stuck site stops retrying
    even with attempts left); a ``deadline`` passed to ``run`` bounds
    the whole scan — an expired deadline stops the retry loop with
    ``DeadlineExceeded`` so the caller can degrade to a partial result
    instead of erroring.

    Only ``retryable`` exception types are retried; anything else
    propagates immediately (a shape error is a bug, not a fault).
    The first attempt is a plain call — a policy wrapped around a
    healthy site adds one function call and one try frame, nothing
    else, which is what keeps the zero-fault overhead inside the 5%
    acceptance bound.
    """

    max_attempts: int = 3
    backoff_base_s: float = 0.001
    backoff_factor: float = 2.0
    max_backoff_s: float = 0.05
    jitter_frac: float = 0.25
    per_call_budget_s: float | None = None
    retryable: tuple = (InjectedFault, OSError)

    def backoff_s(self, site: str, attempt: int) -> float:
        """Deterministic backoff before retry ``attempt`` (1-based)."""
        base = min(self.backoff_base_s * self.backoff_factor
                   ** (attempt - 1), self.max_backoff_s)
        h = int.from_bytes(hashlib.blake2s(
            f"{site}:{attempt}".encode(), digest_size=4).digest(), "big")
        return base * (1.0 + self.jitter_frac * (h / 0xFFFFFFFF))

    def run(self, fn: Callable[[], Any], *, site: str,
            injector: FaultInjector | None = None,
            on_retry: Callable[[], None] | None = None,
            deadline: Deadline | None = None) -> Any:
        """Run ``fn`` under this policy at ``site``.

        ``injector.fire(site)`` is invoked before each attempt (the
        injection point IS the guarded call).  ``on_retry`` is called
        once per re-attempt (the executor counts ``ScanStats.retries``
        there).  Exhausted attempts re-raise the last cause — callers
        wrap it into a ``ScanFault`` with their own context (rows
        completed, ladder position).
        """
        t0 = time.perf_counter()
        attempt = 0
        while True:
            attempt += 1
            try:
                if injector is not None:
                    injector.fire(site)
                return fn()
            except self.retryable as e:
                if attempt >= self.max_attempts:
                    raise
                if (self.per_call_budget_s is not None
                        and time.perf_counter() - t0
                        >= self.per_call_budget_s):
                    raise
                if deadline is not None and deadline.expired:
                    raise DeadlineExceeded(site, cause=e)
                if on_retry is not None:
                    on_retry()
                # every re-attempt is a `retry` span event, so exported
                # traces carry the exact per-site retry counts
                TRACER.event("retry", site=site, attempt=attempt)
                pause = self.backoff_s(site, attempt)
                if deadline is not None:
                    pause = min(pause, deadline.remaining())
                if pause > 0:
                    time.sleep(pause)


# ---------------------------------------------------------------------------
# graceful degradation reporting
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DegradedReport:
    """What a PARTIAL query result is missing and why.

    Attached to ``QueryResult.degraded`` when ``infer(deadline_s=...)``
    ran out of budget mid-scan: the rows that WERE scored are exact
    (bit-identical to an unbounded run — the scan's page↔batch mapping
    is deterministic, so a completed batch is a completed batch), the
    rows that were not carry NaN in ``predictions``, and ``row_mask``
    says which is which.
    """

    rows_scored: int
    rows_missing: int
    cause: str                        # "deadline" (the only ladder that
    #                                   returns partials today)
    deadline_s: float | None = None
    row_mask: np.ndarray | None = None   # [num_rows] bool, True = scored

    def __bool__(self) -> bool:
        return self.rows_missing > 0
