"""CSR tensor-block pages: the sparse data plane's storage format.

The paper's wide-sparse workloads (Bosch F=968 @ 81% missing, Criteo
F=1M LIBSVM) are exactly where the external load/convert cost dominates
end-to-end latency — and where densifying on ingest (the dense store's
``[N, F]`` layout) multiplies both the host working set and the
host->device transfer by ``1 / density``.  This module keeps the data
compressed end to end: the store holds CSR pages on device and the
feature-gather prepass (``kernels/gather.py``) expands each page block
straight into the *compact* per-forest feature space, never into ``F``.

Layout: a sparse dataset is THREE device arrays with a fixed per-page
entry capacity

    indptr   [P, R+1] int32   row offsets WITHIN the page (indptr[p,0]==0)
    indices  [P, C]   int32   column ids; padding entries hold n_features
    values   [P, C]   f32     stored values (explicit zeros are kept)

where R = ``page_rows`` and C = the max per-page nnz rounded up to a lane
multiple.  Fixing C across pages costs at most one lane of padding per
page but buys the property the whole query engine is built on: every page
block has the SAME shape, so the dense store's page<->batch determinism
(batch k always covers the same pages, paper F3 / DESIGN.md Sec. 8) and
the compiled-plan cache's one-signature-per-batching guarantee carry over
to the sparse plane unchanged.

Missing features are NOT stored.  The gather prepass re-materializes them
as NaN, so the forest's ``default_left`` missing-value semantics are
bit-identical to the dense plane's (NaN page padding included).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CSRPages",
    "csr_from_dense",
    "paginate_csr",
    "densify_csr",
    "csr_pages_from_dense",
]

#: padded capacity granularity — one f32 VPU lane
LANE = 128


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSRPages:
    """CSR page block (a whole dataset or a batch slice), device or host.

    A registered pytree: jitted stage functions take it as an input like
    any dense block, and a contiguous page range is a ``dynamic_slice``
    (device tier) or numpy view (host tier) along axis 0 of all three
    arrays (same page granularity as ``StoredDataset.page_slice``).
    Because it is a pytree, one ``jax.device_put`` stages a host-tier
    block onto the mesh — the streaming executor's sparse DMA path.
    """

    indptr: jax.Array                 # [P, R+1] int32, page-local offsets
    indices: jax.Array                # [P, C] int32, pad entries = n_features
    values: jax.Array                 # [P, C] f32
    n_features: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def tier(self) -> str:
        """Where the page arrays live: disk-tier pages are ``np.memmap``
        views of page-aligned spill files, host-tier pages plain numpy
        (the out-of-core store keeps both page-aligned off-device and the
        streaming executor DMAs batch ranges to device)."""
        if isinstance(self.indptr, np.memmap):
            return "disk"
        return "host" if isinstance(self.indptr, np.ndarray) else "device"

    @property
    def num_pages(self) -> int:
        return self.indptr.shape[0]

    @property
    def page_rows(self) -> int:
        return self.indptr.shape[1] - 1

    @property
    def capacity(self) -> int:
        return self.indices.shape[1]

    @property
    def num_rows_padded(self) -> int:
        return self.num_pages * self.page_rows

    @property
    def nbytes(self) -> int:
        return sum(a.size * a.dtype.itemsize
                   for a in (self.indptr, self.indices, self.values))

    def page_slice(self, first_page: int, num_pages: int) -> "CSRPages":
        """Contiguous page range (a view in the pages' own tier — a
        disk-tier slice is three lazy memmap views), same contract as the
        dense store's page_slice: page p of batch k is always the same
        rows AND the same block shape."""
        if self.tier != "device":
            sl = lambda a: a[first_page:first_page + num_pages]
        else:
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, first_page,
                                                        num_pages, axis=0)
        return dataclasses.replace(self, indptr=sl(self.indptr),
                                   indices=sl(self.indices),
                                   values=sl(self.values))


# ---------------------------------------------------------------------------
# host-side construction
# ---------------------------------------------------------------------------


def csr_from_dense(x: np.ndarray, *, drop_zeros: bool = False
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """[N, F] dense-with-NaN -> host CSR (indptr [N+1], indices, values).

    NaN means missing (bosch semantics).  Explicit zeros are KEPT by
    default so a CSR ingest of a dense dataset is lossless — LIBSVM files
    drop zeros at *write* time (``loader.write_libsvm``), which is that
    format's convention, not this store's.
    """
    present = ~np.isnan(x)
    if drop_zeros:
        present &= x != 0.0
    counts = present.sum(axis=1)
    indptr = np.zeros(x.shape[0] + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    rows, cols = np.nonzero(present)
    return indptr, cols.astype(np.int32), x[rows, cols].astype(np.float32)


def paginate_csr(
    indptr: np.ndarray,
    indices: np.ndarray,
    values: np.ndarray,
    *,
    num_rows: int,
    page_rows: int,
    n_features: int,
    pages_multiple: int = 1,
    lane: int = LANE,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host CSR -> fixed-capacity page blocks (still host numpy).

    Rows are padded to whole pages (empty rows — the sparse analogue of
    the dense store's NaN padding rows: every feature missing) and the
    page count to ``pages_multiple`` (mesh data-axis divisibility).
    Capacity C = max page nnz rounded up to ``lane``; padding entries
    carry column id ``n_features`` (one past the end), which the gather
    prepass routes to a dump slot.
    """
    assert indptr.shape[0] == num_rows + 1
    num_pages = -(-num_rows // page_rows)
    num_pages += (-num_pages) % pages_multiple
    num_pages = max(num_pages, pages_multiple)
    padded_rows = num_pages * page_rows
    # extend indptr over padding rows (they hold zero entries)
    full_indptr = np.concatenate(
        [indptr, np.full(padded_rows - num_rows, indptr[-1], indptr.dtype)])
    starts = full_indptr[0:padded_rows + 1:page_rows]      # [P+1]
    page_nnz = np.diff(starts)
    cap = int(page_nnz.max(initial=0))
    cap = max(lane, -(-cap // lane) * lane)

    out_indptr = np.zeros((num_pages, page_rows + 1), np.int32)
    out_indices = np.full((num_pages, cap), n_features, np.int32)
    out_values = np.zeros((num_pages, cap), np.float32)
    for p in range(num_pages):
        lo, hi = int(starts[p]), int(starts[p + 1])
        n = hi - lo
        out_indptr[p] = (full_indptr[p * page_rows:(p + 1) * page_rows + 1]
                         - lo).astype(np.int32)
        out_indices[p, :n] = indices[lo:hi]
        out_values[p, :n] = values[lo:hi]
    return out_indptr, out_indices, out_values


def csr_pages_from_dense(x: np.ndarray, *, page_rows: int,
                         pages_multiple: int = 1, lane: int = LANE,
                         drop_zeros: bool = False) -> CSRPages:
    """Convenience: dense-with-NaN host array -> device CSRPages."""
    n, f = x.shape
    indptr, indices, values = csr_from_dense(x, drop_zeros=drop_zeros)
    ip, ix, vl = paginate_csr(indptr, indices, values, num_rows=n,
                              page_rows=page_rows, n_features=f,
                              pages_multiple=pages_multiple, lane=lane)
    return CSRPages(indptr=jnp.asarray(ip), indices=jnp.asarray(ix),
                    values=jnp.asarray(vl), n_features=f)


def densify_csr(pages_indptr: np.ndarray, pages_indices: np.ndarray,
                pages_values: np.ndarray, n_features: int,
                *, fill: float = np.nan) -> np.ndarray:
    """Reference host densify of page blocks (tests/parity only — the
    production path never builds [N, F]; that is the point)."""
    P, Rp1 = pages_indptr.shape
    R = Rp1 - 1
    out = np.full((P * R, n_features), fill, np.float32)
    for p in range(P):
        for r in range(R):
            lo, hi = int(pages_indptr[p, r]), int(pages_indptr[p, r + 1])
            cols = pages_indices[p, lo:hi]
            out[p * R + r, cols] = pages_values[p, lo:hi]
    return out
