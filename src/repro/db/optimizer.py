"""Self-driving cost-based query optimizer (the paper's punchline, closed).

The paper's central finding is that the best inference configuration —
UDF-centric vs relation-centric plan, algorithm, and placement — FLIPS
with model scale × data scale.  Every call site used to hand-pick
``plan=`` / ``algorithm=`` / ``n_parts=`` / ``batch_pages=``;
``CostBasedOptimizer`` is the one decision point that replaces those
scattered heuristics: ``ForestQueryEngine.infer(plan="auto",
algorithm="auto")`` and the serve plane's ``register_model`` both route
through it.

Three phases, only the first two ever run more than once per key:

  lookup     decisions persist in the store's decision catalog keyed by
             (model fingerprint, dataset name, dataset signature, mesh
             signature) — the steady state is a dictionary lookup
             feeding the existing compiled-plan cache.  Swept exactly
             like compiled plans: ``engine.invalidate(model_id)``,
             ``store.drop`` / re-``put`` of the dataset.
  score      every feasible (algorithm × plan × tier placement) cell
             gets an ANALYTIC roofline cost: closed-form FLOP / byte
             counts per algorithm (the conventions of
             ``launch/hlo_cost.py`` — dot FLOPs are ``2·result·K``,
             bytes are top-level operand+result traffic, trip counts
             multiply) pushed through ``launch/roofline.roofline_terms``
             with BACKEND-CALIBRATED peaks (``launch/roofline.
             resolve_peaks``), not the hardcoded TPU-v5e table — cost
             ranking is meaningful on the CI backend.  Gather traffic
             gets its own calibrated bandwidth (it differs from
             streaming bandwidth in either direction per backend).
  autotune   cells whose analytic cost lands within ``uncertainty_band``
             of the best are refined by a bounded measure-and-cache
             pass: each uncertain cell is probed with a real warm query
             (min of ``probe_iters``), then the winner's ``n_parts`` /
             ``batch_pages`` are hillclimbed (half / double neighbors,
             ``launch/hillclimb.py``-style) while the wall budget lasts.
             Budgeted (``measure_budget_s``, ``max_measurements``) and
             OFF the hot path: it runs at most once per decision key —
             the regret bench and CI gate assert zero autotune re-runs
             on repeat queries via the ``optimizer.autotune_runs``
             counter.

Tier placement is scored (a host/disk dataset that fits the device
budget is costed at zero steady-state transfer) and the winning rung is
recorded on ``Decision.tier`` as ADVICE; execution stays on the
dataset's current tier unless the caller opts in (``infer(...,
auto_move=True)``), because silently migrating a dataset is a store
mutation no query should hide.  See ``docs/optimizer.md`` for the cost
model terms, the calibration table, and the decision-cache contract.
"""

from __future__ import annotations

import dataclasses
import time
import weakref
from typing import Any, Sequence

from repro.launch.roofline import resolve_peaks, roofline_terms
from repro.obs import METRICS, TRACER

__all__ = ["Decision", "CostBasedOptimizer", "dataset_signature",
           "DEFAULT_ALGORITHMS", "DEFAULT_PLANS"]

#: candidate algorithms — the three jnp backends the paper compares.
#: (Pallas kernels run ``interpret=True`` off-TPU, so auto-selection on
#: the CI backend would never pick them; callers targeting TPU can pass
#: ``algorithms=(..., "predicated_pallas_fused", ...)`` explicitly.)
DEFAULT_ALGORITHMS = ("predicated", "hummingbird", "quickscorer")

#: candidate plans — ``rel`` (bare) is the paper's deliberately
#: UNCACHED baseline: it re-partitions the model every query, so it can
#: never win steady state and is excluded from auto-selection.
DEFAULT_PLANS = ("udf", "rel+reuse")

#: sentinel dataset slot for row-batch (serving-plane) decisions —
#: mirrors ``db.query.ROW_PLAN_DATASET`` ("#" never names a real
#: catalog entry, so dataset sweeps cannot touch row decisions).
ROW_DECISION_DATASET = "#rows"


def dataset_signature(ds) -> tuple:
    """The dataset facts a decision is conditioned on.  Any change —
    row count, width, storage format, TIER, page layout — yields a new
    key, so a stale decision can never be served for reshaped data
    (re-``put`` additionally sweeps the old key eagerly)."""
    return (int(ds.num_rows), int(ds.num_features),
            getattr(ds, "storage_format", "dense"),
            getattr(ds, "tier", "device"),
            int(ds.page_rows), int(ds.num_pages))


@dataclasses.dataclass(frozen=True)
class Decision:
    """One persisted optimizer verdict: the winning execution cell."""

    algorithm: str
    plan: str                     # "udf" | "rel+reuse"
    tier: str                     # recommended scan tier (advice — the
    #                               engine only applies it under
    #                               ``infer(..., auto_move=True)``)
    n_parts: int | None           # rel tree-partition count (None: engine
    #                               default), winner of the hillclimb
    batch_pages: int | None       # scan batch size (None: engine default)
    predicted_s: float            # analytic roofline estimate of the cell
    measured_s: float | None      # autotune probe wall (None: model-trusted)
    source: str                   # "measured" | "model"
    cells_scored: int = 0         # analytic candidates enumerated
    cells_measured: int = 0       # probes the autotune pass paid

    def overrides(self) -> dict[str, Any]:
        """kwargs for ``engine._infer`` executing this decision."""
        return dict(algorithm=self.algorithm, plan=self.plan,
                    n_parts=self.n_parts, batch_pages=self.batch_pages)


@dataclasses.dataclass
class _Cell:
    """A feasible configuration under scoring."""
    algorithm: str
    plan: str
    tier: str
    n_parts: int | None = None
    batch_pages: int | None = None
    predicted_s: float = float("inf")
    measured_s: float | None = None


def _forest_flop_bytes(algorithm: str, *, rows: int, trees: int,
                       depth: int, f_used: int) -> tuple[float, float, float]:
    """Closed-form (flops, stream_bytes, gather_bytes) of one algorithm
    over ``rows`` samples — the analytic mirror of what
    ``launch/hlo_cost.analyze`` reads off the compiled HLO.

    Conventions follow ``hlo_cost``: dot FLOPs are ``2·result·K``,
    elementwise ops are one FLOP per output element, bytes are
    per-boundary operand+result traffic (f32), and loop trip counts
    multiply (predicated's ``fori_loop`` over depth is a while body
    executed ``depth`` times).  Gather traffic (data-dependent row
    lookups — tree traversal's access pattern) is returned separately
    because its effective bandwidth differs from streaming bandwidth —
    in either direction, per backend (see ``roofline.calibrate_peaks``).
    """
    B, T, d = float(rows), float(trees), float(depth)
    I = float(2 ** depth - 1)         # internal nodes (complete tree)
    L = float(2 ** depth)             # leaves
    W = float(-(-int(L) // 32))       # quickscorer uint32 mask words
    if algorithm.startswith("predicated") or algorithm.startswith("compiled"):
        # per level: 3 node-table gathers + take_along_axis on x [B,T],
        # compare + index update (~6 elementwise ops on [B,T])
        flops = d * B * T * 6.0
        gather_bytes = d * B * T * 4.0 * 4.0          # f/thr/dl/xv lookups
        stream_bytes = d * B * T * 4.0 * 2.0          # idx read+write
        flops += B * T * 2.0                          # leaf gather + sum
        gather_bytes += B * T * 4.0
    elif algorithm.startswith("hummingbird"):
        # S = predicates [B,T,I]; S @ C -> [B,T,L] (2·B·T·L·I flops);
        # one-hot count-match [B,T,L]; onehot ⊙ leaf -> [B] (2·B·T·L)
        flops = 2.0 * B * T * L * I + B * T * I * 4.0 + B * T * L * 3.0
        gather_bytes = B * T * I * 4.0                # xv feature gather
        stream_bytes = B * T * (I * 3.0 + L * 4.0) * 4.0
    elif algorithm.startswith("quickscorer"):
        # all-node predicates [B,T,I], mask AND-reduce over [B,T,I,W]
        # words, lowest-surviving-bit leaf pick [B,T,W]
        flops = B * T * I * (4.0 + 2.0 * W) + B * T * W * 3.0
        gather_bytes = B * T * I * 4.0
        stream_bytes = (B * T * I * W + B * T * (I + W) * 2.0) * 4.0
    elif algorithm.startswith("naive"):
        # while_loop per (sample, tree): ~depth iterations, serial gathers
        flops = d * B * T * 8.0
        gather_bytes = d * B * T * 4.0 * 5.0
        stream_bytes = d * B * T * 4.0
    else:                             # unknown / kernel variant: model as
        flops = d * B * T * 6.0       # predicated-shaped work
        gather_bytes = d * B * T * 16.0
        stream_bytes = d * B * T * 8.0
    return flops, stream_bytes, gather_bytes


class CostBasedOptimizer:
    """Scores, measures, and caches (algorithm × plan × tier × blocks)
    decisions for a ``ForestQueryEngine`` (see module docstring)."""

    def __init__(self, engine, *,
                 algorithms: Sequence[str] = DEFAULT_ALGORITHMS,
                 plans: Sequence[str] = DEFAULT_PLANS,
                 measure_budget_s: float = 4.0,
                 max_measurements: int = 12,
                 uncertainty_band: float = 16.0,
                 probe_iters: int = 3,
                 hillclimb: bool = True):
        # weak: the engine owns its optimizer — a strong back-reference
        # would cycle and keep dead engines' store invalidation hooks
        # alive until a gc pass
        self._engine = weakref.ref(engine)
        self.algorithms = tuple(algorithms)
        self.plans = tuple(plans)
        self.measure_budget_s = measure_budget_s
        self.max_measurements = max_measurements
        self.uncertainty_band = uncertainty_band
        self.probe_iters = probe_iters
        self.hillclimb = hillclimb

    @property
    def engine(self):
        eng = self._engine()
        if eng is None:
            raise ReferenceError("optimizer outlived its query engine")
        return eng

    # ------------------------------------------------------------------
    # analytic roofline scoring
    # ------------------------------------------------------------------
    def score_cell(self, cell: _Cell, *, rows: int, trees: int, depth: int,
                   f_used: int, data_nbytes: int, num_pages: int,
                   page_rows: int, peaks: dict) -> float:
        """Analytic seconds for one cell over the whole dataset scan."""
        flops, stream_b, gather_b = _forest_flop_bytes(
            cell.algorithm, rows=rows, trees=trees, depth=depth,
            f_used=f_used)
        # gather traffic at its own (calibrated) effective bandwidth,
        # folded into roofline_terms' single memory term as equivalent
        # streaming bytes
        gather_bw = peaks.get("gather_bandwidth", peaks["hbm_bandwidth"])
        eq_bytes = stream_b + gather_b * (peaks["hbm_bandwidth"] / gather_bw)
        # rel plans materialize [n_parts, B] partials at a stage
        # boundary and fold them; udf keeps everything in one stage
        n_parts = cell.n_parts or 1
        if cell.plan.startswith("rel"):
            eq_bytes += 3.0 * 4.0 * n_parts * rows    # write+read+fold
        coll = 0.0
        fplan = getattr(self.engine, "fplan", None)
        if fplan is not None and getattr(fplan, "model_axis", None) \
                is not None and cell.plan.startswith("rel"):
            coll = 4.0 * rows                          # psum over model
        terms = roofline_terms(flops_per_chip=flops,
                               bytes_per_chip=eq_bytes,
                               coll_bytes_per_chip=coll, peak=peaks)
        cost = terms["step_s_lower_bound"]
        # tier transfer: scanning an off-device dataset streams every
        # byte through host→device DMA once per query (disk additionally
        # pays the file read, modeled at half the DMA rate)
        h2d = peaks.get("h2d_bandwidth", peaks["hbm_bandwidth"])
        if cell.tier == "host":
            cost += data_nbytes / h2d
        elif cell.tier == "disk":
            cost += data_nbytes / h2d + data_nbytes / (h2d / 2.0)
        # dispatch overhead: one per stage per batch (udf: 1 fused
        # stage; rel: cross-product + aggregate + postprocess)
        dispatch = peaks.get("dispatch_s", 5e-6)
        bp = cell.batch_pages or num_pages
        n_batches = max(1, -(-num_pages // max(bp, 1)))
        stages = 1 if cell.plan == "udf" else 3
        cost += dispatch * n_batches * stages
        return cost

    # ------------------------------------------------------------------
    # candidate enumeration
    # ------------------------------------------------------------------
    def _enumerate(self, *, tier: str, fits_device: bool,
                   algorithms: Sequence[str], plans: Sequence[str],
                   ) -> list[_Cell]:
        tiers = [tier]
        if tier != "device" and fits_device:
            tiers.append("device")    # promotion candidate (advice)
        return [_Cell(algorithm=a, plan=p, tier=t)
                for t in tiers for a in algorithms for p in plans]

    # ------------------------------------------------------------------
    # measurement probes
    # ------------------------------------------------------------------
    def _probe(self, run, budget_left: float) -> float | None:
        """Warm once (compile), then min-of-``probe_iters`` timed runs.
        Returns None when the budget is already spent."""
        if budget_left <= 0:
            return None
        run()                          # warm: compile + cache the plan
        best = float("inf")
        for _ in range(max(1, self.probe_iters)):
            t0 = time.perf_counter()
            run()
            best = min(best, time.perf_counter() - t0)
        METRICS.counter("optimizer.measurements").inc()
        return best

    def _autotune(self, cells: list[_Cell], make_runner,
                  neighbors) -> tuple[_Cell, int]:
        """Measure-and-refine pass over the uncertain ``cells`` (already
        sorted best-analytic-first).  ``make_runner(cell)`` returns a
        zero-arg callable executing the cell; ``neighbors(cell)`` yields
        hillclimb variants of the winner.  Returns (winner, probes)."""
        METRICS.counter("optimizer.autotune_runs").inc()
        t0 = time.perf_counter()
        measured = 0
        with TRACER.span("optimizer.autotune", candidates=len(cells)):
            live: list[tuple[_Cell, Any]] = []
            for cell in cells:
                if measured >= self.max_measurements:
                    break
                left = self.measure_budget_s - (time.perf_counter() - t0)
                # always measure at least the top-2 candidates — a
                # budget too small to compare anything would silently
                # degrade to pure-model ranking
                if measured >= 2 and left <= 0:
                    break
                run = make_runner(cell)
                run()                  # warm: compile + cache the plan
                live.append((cell, run))
                measured += 1
                METRICS.counter("optimizer.measurements").inc()
            # timed runs INTERLEAVED across cells round-robin (the
            # bench_obs protocol): a transient load spike lands on every
            # candidate equally instead of sinking whichever cell was
            # being probed sequentially when it hit — close calls stay
            # fair.  At least one full round even past the budget.
            for round_ in range(max(1, self.probe_iters)):
                if round_ > 0 and time.perf_counter() - t0 \
                        >= self.measure_budget_s:
                    break
                for cell, run in live:
                    t1 = time.perf_counter()
                    run()
                    dt = time.perf_counter() - t1
                    if cell.measured_s is None or dt < cell.measured_s:
                        cell.measured_s = dt
            done = [c for c in cells if c.measured_s is not None]
            best = min(done, key=lambda c: c.measured_s) if done \
                else cells[0]
            # hillclimb the winner's block sizes while budget remains
            if self.hillclimb and done:
                improved = True
                while improved:
                    improved = False
                    for cand in neighbors(best):
                        left = self.measure_budget_s - \
                            (time.perf_counter() - t0)
                        if left <= 0 or measured >= self.max_measurements:
                            break
                        got = self._probe(make_runner(cand), left)
                        if got is None:
                            break
                        cand.measured_s = got
                        measured += 1
                        if got < best.measured_s:
                            best, improved = cand, True
        return best, measured

    # ------------------------------------------------------------------
    # dataset-scan decisions (ForestQueryEngine.infer)
    # ------------------------------------------------------------------
    def decide(self, dataset: str, forest, *, model_id: str | None = None,
               algorithms: Sequence[str] | None = None,
               plans: Sequence[str] | None = None) -> Decision:
        """Decision for a full dataset scan — cached in the store's
        decision catalog; first call per key pays the score + autotune
        passes, every later call is a dictionary lookup."""
        from repro.core.reuse import mesh_signature
        eng = self.engine
        store = eng.store
        ds = store.get(dataset)
        sig = dataset_signature(ds)
        mid = eng._model_key(forest, model_id)
        # the candidate sets are part of the key: a decision made under a
        # pinned axis (algorithm="hummingbird", plan="auto") must never be
        # served for — or clobbered by — the unconstrained auto query
        algorithms = tuple(algorithms or self.algorithms)
        plans = tuple(plans or self.plans)
        key = (mid, dataset, sig, mesh_signature(eng.mesh),
               algorithms, plans)
        hit = store.get_decision(key)
        if hit is not None:
            METRICS.counter("optimizer.decision_cache_hits").inc()
            return hit
        METRICS.counter("optimizer.decision_cache_misses").inc()
        with TRACER.span("optimizer.decide", dataset=dataset,
                         model=mid[:12]) as sp:
            peaks = resolve_peaks()
            budget = store.device_budget_bytes
            fits = budget is None or \
                store.device_nbytes + ds.nbytes <= budget
            cells = self._enumerate(
                tier=sig[3], fits_device=fits,
                algorithms=algorithms, plans=plans)
            kw = dict(rows=int(ds.num_pages) * int(ds.page_rows),
                      trees=int(forest.num_trees),
                      depth=int(forest.depth),
                      f_used=int(forest.n_features),
                      data_nbytes=int(ds.nbytes),
                      num_pages=int(ds.num_pages),
                      page_rows=int(ds.page_rows), peaks=peaks)
            for c in cells:
                if c.plan.startswith("rel"):
                    c.n_parts = eng._resolve_n_parts(forest, c.algorithm,
                                                     None)
                c.predicted_s = self.score_cell(c, **kw)
            cells.sort(key=lambda c: c.predicted_s)
            # the executable winner must run on the CURRENT tier; other
            # rungs are scored for the tier recommendation only
            here = [c for c in cells if c.tier == sig[3]]
            uncertain = [c for c in here if c.predicted_s
                         <= here[0].predicted_s * self.uncertainty_band]

            def make_runner(cell: _Cell):
                return lambda: eng._infer(
                    dataset, forest, model_id=model_id,
                    algorithm=cell.algorithm, plan=cell.plan,
                    n_parts=cell.n_parts, batch_pages=cell.batch_pages)

            def neighbors(cell: _Cell):
                out = []
                if cell.plan.startswith("rel") and cell.n_parts:
                    for np_ in (max(1, cell.n_parts // 2),
                                min(int(forest.num_trees),
                                    cell.n_parts * 2)):
                        if np_ != cell.n_parts:
                            out.append(dataclasses.replace(
                                cell, n_parts=np_, measured_s=None))
                if sig[3] != "device":
                    bp = cell.batch_pages or self._default_batch_pages(ds)
                    for bp_ in (max(1, bp // 2),
                                min(int(ds.num_pages), bp * 2)):
                        if bp_ != bp:
                            out.append(dataclasses.replace(
                                cell, batch_pages=bp_, measured_s=None))
                return out

            measured = 0
            if len(uncertain) > 1:
                best, measured = self._autotune(uncertain, make_runner,
                                                neighbors)
            else:
                best = here[0]
            decision = Decision(
                algorithm=best.algorithm, plan=best.plan,
                tier=cells[0].tier,           # best overall rung = advice
                n_parts=best.n_parts, batch_pages=best.batch_pages,
                predicted_s=best.predicted_s,
                measured_s=best.measured_s,
                source="measured" if best.measured_s is not None
                else "model",
                cells_scored=len(cells), cells_measured=measured)
            sp.set(algorithm=decision.algorithm, plan=decision.plan,
                   source=decision.source, measured=measured)
        store.put_decision(key, decision)
        METRICS.counter("optimizer.decisions").inc()
        TRACER.event("optimizer.decision", dataset=dataset,
                     algorithm=decision.algorithm, plan=decision.plan,
                     tier=decision.tier, source=decision.source)
        return decision

    def _default_batch_pages(self, ds) -> int:
        """Mirror of the engine's off-device default (half the device
        budget in pages) used as the hillclimb starting point."""
        budget = self.engine.store.device_budget_bytes
        from repro.db.executor import DEFAULT_STREAM_BATCH_BYTES
        target = budget // 2 if budget else DEFAULT_STREAM_BATCH_BYTES
        return min(int(ds.num_pages),
                   max(1, target // max(int(ds.page_nbytes), 1)))

    # ------------------------------------------------------------------
    # row-batch decisions (serving plane: register_model)
    # ------------------------------------------------------------------
    def decide_rows(self, forest, batch_rows: int, *,
                    model_id: str | None = None,
                    algorithms: Sequence[str] | None = None,
                    plans: Sequence[str] | None = None) -> Decision:
        """Decision for the serving plane's padded row batches: same
        score → autotune → persist pipeline, probed through
        ``engine.infer_rows`` at the largest bucket signature.  Keyed
        under the ``#rows`` sentinel so dataset sweeps never touch it;
        ``engine.invalidate(model_id)`` sweeps it like any plan."""
        import numpy as np
        from repro.core.reuse import mesh_signature
        eng = self.engine
        store = eng.store
        mid = eng._model_key(forest, model_id)
        B, F = int(batch_rows), int(forest.n_features)
        algorithms = tuple(algorithms or self.algorithms)
        plans = tuple(p for p in (plans or self.plans)
                      if p in ("udf", "rel+reuse"))
        key = (mid, ROW_DECISION_DATASET, (B, F), mesh_signature(eng.mesh),
               algorithms, plans)
        hit = store.get_decision(key)
        if hit is not None:
            METRICS.counter("optimizer.decision_cache_hits").inc()
            return hit
        METRICS.counter("optimizer.decision_cache_misses").inc()
        with TRACER.span("optimizer.decide", dataset=ROW_DECISION_DATASET,
                         model=mid[:12]) as sp:
            peaks = resolve_peaks()
            cells = [_Cell(algorithm=a, plan=p, tier="device")
                     for a in algorithms for p in plans]
            for c in cells:
                if c.plan.startswith("rel"):
                    c.n_parts = eng._resolve_n_parts(forest, c.algorithm,
                                                     None)
                c.predicted_s = self.score_cell(
                    c, rows=B, trees=int(forest.num_trees),
                    depth=int(forest.depth), f_used=F,
                    data_nbytes=B * F * 4, num_pages=1, page_rows=B,
                    peaks=peaks)
            cells.sort(key=lambda c: c.predicted_s)
            uncertain = [c for c in cells if c.predicted_s
                         <= cells[0].predicted_s * self.uncertainty_band]
            x = np.zeros((B, F), np.float32)

            def make_runner(cell: _Cell):
                return lambda: eng.infer_rows(
                    forest, x, algorithm=cell.algorithm, plan=cell.plan,
                    model_id=mid, n_parts=cell.n_parts)

            measured = 0
            if len(uncertain) > 1:
                best, measured = self._autotune(uncertain, make_runner,
                                                lambda c: [])
            else:
                best = cells[0]
            decision = Decision(
                algorithm=best.algorithm, plan=best.plan, tier="device",
                n_parts=best.n_parts, batch_pages=None,
                predicted_s=best.predicted_s, measured_s=best.measured_s,
                source="measured" if best.measured_s is not None
                else "model",
                cells_scored=len(cells), cells_measured=measured)
            sp.set(algorithm=decision.algorithm, plan=decision.plan,
                   source=decision.source, measured=measured)
        store.put_decision(key, decision)
        METRICS.counter("optimizer.decisions").inc()
        TRACER.event("optimizer.decision", dataset=ROW_DECISION_DATASET,
                     algorithm=decision.algorithm, plan=decision.plan,
                     tier=decision.tier, source=decision.source)
        return decision
