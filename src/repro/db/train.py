"""In-database streamed training: the other half of the lifecycle.

The paper trains its models OUTSIDE the database (scikit-learn / XGBoost /
LightGBM, Sec. 4) and only benchmarks inference; JoinBoost's thesis
(PAPERS.md) is that the in-database payoff comes from growing the trees
where the data lives.  This module closes that gap for our system: the
SAME ``StreamingScanExecutor`` + tiered ``TensorBlockStore`` machinery
that pages inference batches through device memory now drives
``core.train.grow_forest_scanned``'s per-level histogram scans, so
training consumes host/disk-tier dense and CSR pages exactly like
inference — and the trained ``Forest`` lands straight in the store's
model catalog, where the serving plane and the optimizer pick it up.

Three streaming passes, all through the executor (bounded at two live
device page buffers, double-buffered DMA, the scan spans/metrics of
``docs/observability.md``):

  1. SKETCH (``train.sketch``, skipped when the caller supplies edges):
     a deterministic global-stride row sample is drawn batch-by-batch
     (CSR pages densified per batch to the full feature space, missing
     stays NaN) and finalized into quantile bin edges by
     ``core.train.edges_from_sample``.  The retained sample is capped at
     ``sketch_rows`` rows — never the full matrix.
  2. BIN INGEST (``train.bin_ingest``): each batch is binned on device
     (``core.train.bin_features``: NaN -> the dedicated MISSING slot) and
     appended through ``store.stream_writer`` into a NEW in-store
     relation ``<dataset>::bins`` (uint8, same page geometry, same tier
     by default — on the disk tier each batch is written straight into
     the page-aligned mmap, so the full binned matrix never exists in
     host RAM either).
  3. LEVEL SCANS (``train.level``, ``(max_depth + 1)`` per tree): every
     scan streams the bins relation; a routing stage updates the
     node-of frontier on device (``core.train.route_level`` — exact
     integer kernel) with the previous level's split parameters fed per
     batch through the executor's ``extras`` hook, the updated frontier
     drains back through the executor's double-buffered drain worker
     (``result_key="node_of"``), and the ``on_batch`` hook accumulates
     the level's gradient/hessian histograms host-side in global row
     order (``core.train.hist_update``).

BIT-IDENTITY CONTRACT: given identical bin edges, the streamed trainer
produces a forest bit-identical to the resident ``core.train.
train_forest`` — for any tier, storage format, page/batch geometry, or
mesh.  Routing is exact integer arithmetic; histograms accumulate via
``np.add.at`` whose sequential element-order update makes consecutive
row slices bitwise equal to one whole-array call; store padding rows
carry g = h = 0 and contribute only +0.0, which never changes a float64
accumulator bit.  ``tests/test_train_streaming.py`` enforces the matrix.

The per-level histograms themselves are HOST state, not an in-store
relation: they are model-sized (``2^level x F x (num_bins + 1)``
float64), bounded by the model, not the data — spilling them through the
store would add tier churn without touching the out-of-core story (the
data-sized state, bins + node-of frontier, IS in-store / streamed).
``docs/training.md`` records this and the other deviations.

Order caveat (documented on ``StreamingScanExecutor.execute``): the
histogram reduction is order-sensitive, so the level scans run with the
reliability ladders OFF — the injector-free plan is never reordered or
split, and each batch is seen exactly once in global row order.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import Forest
from repro.core.reuse import fingerprint_forest
from repro.core.train import (TrainConfig, bin_features, edges_from_sample,
                              grow_forest_scanned, hist_update, route_level)
from repro.db.executor import (DEFAULT_STREAM_BATCH_BYTES, ScanStats,
                               StreamingScanExecutor)
from repro.db.operators import Operator, split_into_stages
from repro.kernels.gather import csr_block_to_dense, gather_inverse_map
from repro.obs import METRICS, TRACER

__all__ = ["TrainResult", "train_streaming"]

#: cap on rows the quantile sketch retains (the sketch's host footprint
#: is ``min(num_rows, sketch_rows) * F`` floats, never the full matrix)
DEFAULT_SKETCH_ROWS = 65536


@dataclasses.dataclass
class TrainResult:
    """What ``ForestQueryEngine.train`` returns.

    ``scan_stats`` holds one ``ScanStats`` per executor pass in
    execution order — sketch (if run), bin ingest, then every per-level
    scan — so tests and benchmarks can assert the training scans really
    streamed (batches, bytes_streamed, max_in_flight <= 2) with the same
    telemetry contract inference has.
    """

    forest: Forest
    model_name: str
    fingerprint: str
    edges: np.ndarray                 # [F, num_bins - 1] bin boundaries
    bins_dataset: str                 # the in-store binned relation
    cfg: TrainConfig
    scan_stats: list[ScanStats]
    tier: str                         # source dataset's tier
    storage_format: str               # "dense" | "csr"
    num_scans: int = 0                # executor passes (incl. sketch/bins)
    sketch_rows_used: int = 0         # rows the sketch retained (0: edges
    #                                   were supplied by the caller)
    wall_s: float = 0.0
    #: the streamed path's no-full-X invariant: the trainer only ever
    #: touches per-batch blocks + the capped sketch sample; nothing in
    #: this module materializes the [N, F] matrix (asserted structurally
    #: by tests via jaxpr/ScanStats, recorded here for the bench gate)
    materialized_full_x: bool = False


def _auto_batch_pages(engine, ds) -> int:
    """Mirror ``ForestQueryEngine._infer``'s out-of-core batch sizing:
    half the device budget per in-flight buffer (or the fixed default),
    in data-axis units, rounded down; device tier scans whole."""
    if getattr(ds, "tier", "device") == "device":
        return ds.num_pages
    budget = engine.store.device_budget_bytes
    target = budget // 2 if budget else DEFAULT_STREAM_BATCH_BYTES
    unit = max(1, engine.fplan.n_data)
    fit = target // max(ds.page_nbytes, 1)
    return min(ds.num_pages, max(unit, fit // unit * unit))


def _mesh_round(engine, ds, batch_pages: int) -> int:
    """shard_map-divisible page batches (same rule as ``_infer``)."""
    nd = engine.fplan.n_data
    if nd > 1:
        batch_pages = min(-(-batch_pages // nd) * nd, ds.num_pages)
    return batch_pages


def _source_ops(ds) -> list[Operator]:
    """Stage prefix that turns a source block into dense [rows, F] float:
    identity for the dense plane; for CSR pages a per-batch densify to
    the FULL feature space with NaN fill (missing stays missing, so it
    bins to the MISSING slot — the same contract the dense plane's NaN
    padding rows follow)."""
    if getattr(ds, "storage_format", "dense") != "csr":
        return []
    F = ds.num_features
    inv_full = jnp.asarray(gather_inverse_map(np.arange(F), F))

    def densify(state):
        state = dict(state)
        state["x"] = csr_block_to_dense(state["x"], inv_full, F)
        return state

    return [Operator("train:densify-csr", densify)]


def train_streaming(engine, dataset: str, cfg: TrainConfig, *,
                    model_name: str | None = None,
                    edges: np.ndarray | None = None,
                    batch_pages: int | None = None,
                    prefetch_depth: int = 2,
                    bins_tier: str | None = None,
                    sketch_rows: int = DEFAULT_SKETCH_ROWS) -> TrainResult:
    """Train ``cfg``'s forest ON a stored dataset, streaming every pass.

    ``engine`` is the ``ForestQueryEngine`` (this is the implementation
    behind ``engine.train``).  ``edges`` short-circuits the sketch pass
    (the parity tests pass the SAME edges to the resident reference —
    the bit-identity contract is conditioned on identical edges);
    ``bins_tier`` overrides where the binned relation lands (default:
    the source's own tier); ``batch_pages`` / ``prefetch_depth`` control
    the executor exactly as in ``engine.infer``.

    The trained forest is sharded over the mesh ``model`` axis
    (``ForestShardingPlan.shard_forest``) and pinned in the store's
    model catalog under ``model_name`` (default ``f"{dataset}:model"``)
    — re-pinning an existing name sweeps the replaced fingerprint's
    compiled plans and optimizer decisions (``store.put_model``), so a
    re-trained model can never serve the old forest's verdicts.
    """
    store = engine.store
    ds = store.get(dataset)
    fmt = getattr(ds, "storage_format", "dense")
    tier = getattr(ds, "tier", "device")
    N, F = ds.num_rows, ds.num_features
    if ds.labels is None:
        raise ValueError(f"dataset {dataset!r} has no labels to train on")
    if cfg.num_bins > 255:
        raise ValueError(
            f"num_bins must fit the uint8 bins relation (<= 255 with the "
            f"MISSING slot), got {cfg.num_bins}")
    y = np.asarray(ds.labels, np.float32)[:N]
    name = model_name or f"{dataset}:model"
    bins_name = f"{dataset}::bins"
    sharding = store.data_sharding()
    min_bp = max(1, engine.fplan.n_data)
    R = ds.page_rows
    scan_stats: list[ScanStats] = []
    t0 = time.perf_counter()
    METRICS.counter("train.runs").inc()

    with TRACER.span("train.forest", dataset=dataset, model=name,
                     model_type=cfg.model_type, num_trees=cfg.num_trees,
                     tier=tier, storage_format=fmt) as root:
        src_bp = _mesh_round(engine, ds, batch_pages if batch_pages
                             is not None else _auto_batch_pages(engine, ds))

        # -- pass 1: quantile sketch -> bin edges --------------------------
        sketch_used = 0
        if edges is None:
            stride = max(1, -(-N // max(1, int(sketch_rows))))
            sample_parts: list[np.ndarray] = []

            def sketch_batch(first: int, n: int, state) -> None:
                lo = first * R
                idx = np.arange(lo, min(lo + n * R, N))
                sel = idx[(idx % stride) == 0] - lo
                if sel.size:
                    sample_parts.append(np.asarray(state["x"])[sel])

            stages = split_into_stages(_source_ops(ds),
                                       prefix="train-stage")
            ex = StreamingScanExecutor(stages, sharding=sharding,
                                       prefetch_depth=prefetch_depth,
                                       result_key=None,
                                       min_batch_pages=min_bp)
            with TRACER.span("train.sketch", dataset=dataset,
                             stride=stride):
                _, _, st = ex.execute(ds, src_bp, on_batch=sketch_batch)
            scan_stats.append(st)
            sample = (np.concatenate(sample_parts) if sample_parts
                      else np.zeros((0, F), np.float32))
            sketch_used = int(sample.shape[0])
            edges = edges_from_sample(sample, cfg.num_bins)
        edges = np.asarray(edges, np.float32)
        edges_j = jnp.asarray(edges)

        # -- pass 2: streamed binning into the <dataset>::bins relation ----
        writer = store.stream_writer(
            bins_name, num_rows=N, num_features=F, dtype=np.uint8,
            page_rows=R, tier=bins_tier if bins_tier is not None else tier,
            fill=cfg.num_bins)

        def bin_op(state):
            state = dict(state)
            state["bins"] = bin_features(state["x"],
                                         edges_j).astype(jnp.uint8)
            return state

        def ingest_batch(first: int, n: int, state) -> None:
            lo = first * R
            real = min(lo + n * R, N) - lo
            if real > 0:
                writer.write(np.asarray(state["bins"])[:real])

        stages = split_into_stages(
            _source_ops(ds) + [Operator("train:bin-features", bin_op)],
            prefix="train-stage")
        ex = StreamingScanExecutor(stages, sharding=sharding,
                                   prefetch_depth=prefetch_depth,
                                   result_key=None, min_batch_pages=min_bp)
        try:
            with TRACER.span("train.bin_ingest", dataset=dataset,
                             bins=bins_name):
                _, _, st = ex.execute(ds, src_bp, on_batch=ingest_batch)
        except BaseException:
            writer.abort()
            raise
        scan_stats.append(st)
        bins_ds = writer.close()
        total = bins_ds.num_pages * bins_ds.page_rows
        bins_bp = _mesh_round(engine, bins_ds,
                              batch_pages if batch_pages is not None
                              else _auto_batch_pages(engine, bins_ds))

        # -- pass 3..: per-level scans over the bins relation ---------------
        def run_scan(node_of, *, route=None, hist=None):
            ops: list[Operator] = []
            if route is not None:
                level_r, feat, sbin, dleft, term = route
                feat_j, sbin_j = jnp.asarray(feat), jnp.asarray(sbin)
                dleft_j, term_j = jnp.asarray(dleft), jnp.asarray(term)

                def route_op(state):
                    state = dict(state)
                    state["node_of"] = route_level(
                        state["x"].astype(jnp.int32), state["node_of"],
                        feat_j, sbin_j, dleft_j, term_j,
                        level=level_r, num_bins=cfg.num_bins)
                    return state

                ops.append(Operator("train:route-level", route_op))
            # route_level is itself jitted (static level); stage-level jit
            # would retrace per run_scan call since the closure is new
            stages = split_into_stages(ops, prefix="train-stage",
                                       jit=False)

            hg = hh = None
            if hist is not None:
                g, h, level_h = hist
                hg = np.zeros(((1 << level_h), F, cfg.num_bins + 1),
                              np.float64)
                hh = np.zeros_like(hg)

            def extras(first: int, n: int) -> dict:
                lo = first * R
                return {"node_of": jnp.asarray(node_of[lo: lo + n * R])}

            def on_batch(first: int, n: int, state) -> None:
                lo = first * R
                nb = (np.asarray(state["node_of"]) if route is not None
                      else node_of[lo: lo + n * R])
                hist_update(hg, hh, np.asarray(state["x"]), nb,
                            g[lo: lo + n * R], h[lo: lo + n * R])

            ex = StreamingScanExecutor(
                stages, sharding=sharding, prefetch_depth=prefetch_depth,
                result_key="node_of" if route is not None else None,
                min_batch_pages=min_bp)
            with TRACER.span("train.level",
                             level=route[0] + 1 if route else 0,
                             hist=hist is not None):
                out_np, _, st = ex.execute(
                    bins_ds, bins_bp,
                    extras=extras if route is not None else None,
                    on_batch=on_batch if hist is not None else None)
            scan_stats.append(st)
            METRICS.counter("train.level_scans").inc()
            if route is None:
                return node_of, (hg, hh) if hist is not None else None
            new_node = np.zeros_like(node_of)
            new_node[:N] = out_np          # padding rows stay inert (g=h=0)
            return new_node, (hg, hh) if hist is not None else None

        forest = grow_forest_scanned(run_scan, y=y, num_rows=N,
                                     num_features=F, total_rows=total,
                                     edges=edges, cfg=cfg)
        METRICS.counter("train.trees_grown").inc(cfg.num_trees)

        # -- land it: model-axis sharding + the store's model catalog -------
        forest = engine.fplan.shard_forest(forest)
        fp = fingerprint_forest(forest)
        store.put_model(name, forest, fingerprint=fp, trained_on=dataset,
                        bins_dataset=bins_name, num_bins=cfg.num_bins,
                        streamed=True)
        root.set(fingerprint=fp, scans=len(scan_stats))

    return TrainResult(
        forest=forest, model_name=name, fingerprint=fp, edges=edges,
        bins_dataset=bins_name, cfg=cfg, scan_stats=scan_stats,
        tier=tier, storage_format=fmt, num_scans=len(scan_stats),
        sketch_rows_used=sketch_used,
        wall_s=time.perf_counter() - t0)
