"""The tensor-block store: netsDB's native storage, TPU-resident.

Paper Sec. 3.1: "the input samples are stored as a collection of tensor
blocks, called sample blocks. Each block is a 2D tensor that represents a
vector of feature vectors."  Our mapping (DESIGN.md Sec. 3): a stored dataset
is ONE device-resident array [N, F] laid out as ``page_rows``-row pages,
sharded over the mesh ``data`` axis (and replicated over ``model``), plus a
catalog entry.  "In-database inference" = the query plan consumes these
device buffers directly; the external path (db/loader.py) must parse +
convert + transfer through the host first — exactly the boundary whose cost
the paper measures.

Pages are the batching unit (paper F3): a batch is a contiguous page range,
and the page↔step mapping is deterministic (page p of batch k is always the
same rows), which is what makes failure replay exact (DESIGN.md Sec. 8).

Storage formats: the catalog tags every dataset with a ``storage_format``.
``dense`` is the original [N, F] layout; ``csr`` is the sparse data plane
(``db/sparse.CSRPages``: fixed-capacity CSR page blocks, same page↔batch
determinism, consumed through the feature-gather prepass instead of being
densified at full F).  Query plans key their compiled-plan cache on the
format, so a dense and a CSR plan over the same model never collide.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.db.sparse import CSRPages, csr_from_dense, paginate_csr

__all__ = ["StoredDataset", "SparseStoredDataset", "TensorBlockStore"]


@dataclasses.dataclass
class StoredDataset:
    name: str
    data: jax.Array               # [N_padded, F] device-resident, row-sharded
    num_rows: int                 # true N (pre-padding)
    page_rows: int
    labels: jax.Array | None = None
    task: str = "classification"
    created_at: float = dataclasses.field(default_factory=time.time)
    storage_format: str = "dense"

    @property
    def num_features(self) -> int:
        return self.data.shape[1]

    @property
    def num_pages(self) -> int:
        return self.data.shape[0] // self.page_rows

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize

    def page_slice(self, first_page: int, num_pages: int) -> jax.Array:
        """[num_pages * page_rows, F] contiguous page range (device view)."""
        lo = first_page * self.page_rows
        return jax.lax.dynamic_slice_in_dim(
            self.data, lo, num_pages * self.page_rows, axis=0)

    def batches(self, pages_per_batch: int) -> Iterator[tuple[int, jax.Array]]:
        """Deterministic (batch_index, block) iteration — the F3 batching
        loop AND the replay unit: batch k always covers the same pages."""
        for k, first in enumerate(range(0, self.num_pages, pages_per_batch)):
            n = min(pages_per_batch, self.num_pages - first)
            yield k, self.page_slice(first, n)


@dataclasses.dataclass
class SparseStoredDataset:
    """A CSR-paged dataset: the sparse plane's analogue of StoredDataset.

    Same page↔batch determinism (a batch is a contiguous page range and
    every page block has one fixed shape), but rows live compressed —
    pages beyond ``num_rows`` are EMPTY rows (every feature missing),
    mirroring the dense store's NaN padding rows.
    """

    name: str
    pages: CSRPages                # device-resident CSR page blocks
    num_rows: int                  # true N (pre-padding)
    labels: jax.Array | None = None
    task: str = "classification"
    created_at: float = dataclasses.field(default_factory=time.time)
    storage_format: str = "csr"

    @property
    def num_features(self) -> int:
        return self.pages.n_features

    @property
    def page_rows(self) -> int:
        return self.pages.page_rows

    @property
    def num_pages(self) -> int:
        return self.pages.num_pages

    @property
    def nbytes(self) -> int:
        return self.pages.nbytes

    @property
    def nnz(self) -> int:
        """True stored-entry count (excludes capacity padding)."""
        return int(jnp.sum(self.pages.indptr[:, -1]))

    def page_slice(self, first_page: int, num_pages: int) -> CSRPages:
        return self.pages.page_slice(first_page, num_pages)

    def batches(self, pages_per_batch: int) -> Iterator[tuple[int, CSRPages]]:
        """Deterministic (batch_index, CSR block) iteration — identical
        page→batch mapping to the dense plane's ``batches``."""
        for k, first in enumerate(range(0, self.num_pages, pages_per_batch)):
            n = min(pages_per_batch, self.num_pages - first)
            yield k, self.page_slice(first, n)


class TensorBlockStore:
    """Catalog of device-resident datasets (one store per pod; DESIGN §8)."""

    def __init__(self, mesh: Mesh | None = None, *, default_page_rows: int = 1024):
        self.mesh = mesh
        self.default_page_rows = default_page_rows
        self._datasets: dict[str, StoredDataset] = {}

    # -- mesh contract ------------------------------------------------------
    @property
    def data_axis_size(self) -> int:
        """Mesh ``data``-axis size (1 off-mesh).  Every ingest pads its
        page count to a multiple of this, so any whole-dataset batch
        divides evenly for the query plans' shard_map."""
        if self.mesh is not None and "data" in self.mesh.axis_names:
            return int(self.mesh.shape["data"])
        return 1

    def data_sharding(self) -> NamedSharding | None:
        """Row/page sharding for stored blocks: dim 0 over ``data``,
        replicated over ``model`` (None off-mesh).  One definition for
        dense pages, CSR page arrays, and result writes."""
        if self.mesh is not None and "data" in self.mesh.axis_names:
            return NamedSharding(self.mesh, P("data", None))
        return None

    # -- ingestion ----------------------------------------------------------
    def put(
        self,
        name: str,
        data: np.ndarray | jax.Array,
        *,
        labels: np.ndarray | None = None,
        page_rows: int | None = None,
        task: str = "classification",
        dtype=jnp.float32,
    ) -> StoredDataset:
        """Ingest [N, F] rows: pad to whole pages (NaN rows — never counted
        in results), shard rows over the mesh ``data`` axis, register."""
        page_rows = page_rows or self.default_page_rows
        arr = np.asarray(jax.device_get(data))
        n = arr.shape[0]
        # page padding AND divisibility by the data axis
        row_multiple = self.data_axis_size * page_rows
        pad = (-n) % row_multiple
        if pad:
            arr = np.concatenate(
                [arr, np.full((pad, arr.shape[1]), np.nan, arr.dtype)])
        dev = jnp.asarray(arr, dtype)
        sharding = self.data_sharding()
        if sharding is not None:
            dev = jax.device_put(dev, sharding)
        lab = None
        if labels is not None:
            lab = jnp.asarray(np.asarray(labels), jnp.float32)
        ds = StoredDataset(name=name, data=dev, num_rows=n,
                           page_rows=page_rows, labels=lab, task=task)
        self._datasets[name] = ds
        return ds

    def put_sparse(
        self,
        name: str,
        data: np.ndarray | None = None,
        *,
        csr: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None,
        num_rows: int | None = None,
        num_features: int | None = None,
        pages: CSRPages | None = None,
        labels: np.ndarray | None = None,
        page_rows: int | None = None,
        task: str = "classification",
        drop_zeros: bool = False,
    ) -> SparseStoredDataset:
        """Ingest a CSR dataset (the sparse data plane).

        Three entry points, most-compressed first:
          * ``pages`` — already-paginated device CSRPages (the LIBSVM→CSR
            loader hands these over; zero extra host work, the in-database
            boundary the paper measures against);
          * ``csr`` — host (indptr [N+1], indices, values) triple;
          * ``data`` — dense-with-NaN host rows (NaN = missing; explicit
            zeros kept unless ``drop_zeros``), converted here.

        Page padding mirrors ``put``: rows pad to whole pages as EMPTY
        rows, and the page count pads to the mesh ``data`` axis.
        """
        page_rows = page_rows or self.default_page_rows
        pages_multiple = self.data_axis_size

        if pages is not None:
            if num_rows is None:
                raise ValueError("num_rows is required with pages=")
        else:
            if csr is None:
                if data is None:
                    raise ValueError("need one of data=, csr=, pages=")
                arr = np.asarray(jax.device_get(data))
                num_rows = arr.shape[0]
                num_features = arr.shape[1]
                csr = csr_from_dense(arr, drop_zeros=drop_zeros)
            if num_rows is None or num_features is None:
                raise ValueError("num_rows/num_features required with csr=")
            indptr, indices, values = csr
            ip, ix, vl = paginate_csr(indptr, indices, values,
                                      num_rows=num_rows, page_rows=page_rows,
                                      n_features=num_features,
                                      pages_multiple=pages_multiple)
            pages = CSRPages(indptr=jnp.asarray(ip), indices=jnp.asarray(ix),
                             values=jnp.asarray(vl),
                             n_features=int(num_features))
        sharding = self.data_sharding()
        if sharding is not None:
            pages = dataclasses.replace(
                pages,
                indptr=jax.device_put(pages.indptr, sharding),
                indices=jax.device_put(pages.indices, sharding),
                values=jax.device_put(pages.values, sharding))
        lab = None
        if labels is not None:
            lab = jnp.asarray(np.asarray(labels), jnp.float32)
        ds = SparseStoredDataset(name=name, pages=pages, num_rows=int(num_rows),
                                 labels=lab, task=task)
        self._datasets[name] = ds
        return ds

    def put_result(self, name: str, result: jax.Array, num_rows: int) -> StoredDataset:
        """The WRITE operator's sink: register an output dataset."""
        ds = StoredDataset(name=name, data=result[:, None] if result.ndim == 1
                           else result,
                           num_rows=num_rows, page_rows=self.default_page_rows)
        self._datasets[name] = ds
        return ds

    # -- catalog --------------------------------------------------------------
    def get(self, name: str) -> StoredDataset:
        try:
            return self._datasets[name]
        except KeyError:
            raise KeyError(f"dataset {name!r} not in store; "
                           f"have {sorted(self._datasets)}")

    def drop(self, name: str) -> None:
        self._datasets.pop(name, None)

    def __contains__(self, name: str) -> bool:
        return name in self._datasets

    def catalog(self) -> dict[str, dict[str, Any]]:
        out = {}
        for n, d in self._datasets.items():
            entry = dict(rows=d.num_rows, features=d.num_features,
                         pages=d.num_pages, page_rows=d.page_rows,
                         bytes=d.nbytes, task=d.task,
                         format=getattr(d, "storage_format", "dense"))
            if entry["format"] == "csr":
                entry["nnz"] = d.nnz
            out[n] = entry
        return out
