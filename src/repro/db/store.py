"""The tensor-block store: netsDB's native storage, tiered.

Paper Sec. 3.1: "the input samples are stored as a collection of tensor
blocks, called sample blocks. Each block is a 2D tensor that represents a
vector of feature vectors."  Our mapping (DESIGN.md Sec. 3): a stored dataset
is ONE array [N, F] laid out as ``page_rows``-row pages, sharded over the
mesh ``data`` axis (and replicated over ``model``), plus a catalog entry.
"In-database inference" = the query plan consumes these buffers directly;
the external path (db/loader.py) must parse + convert + transfer through
the host first — exactly the boundary whose cost the paper measures.

Pages are the batching unit (paper F3): a batch is a contiguous page range,
and the page↔step mapping is deterministic (page p of batch k is always the
same rows), which is what makes failure replay exact (DESIGN.md Sec. 8).

Storage formats: the catalog tags every dataset with a ``storage_format``.
``dense`` is the original [N, F] layout; ``csr`` is the sparse data plane
(``db/sparse.CSRPages``: fixed-capacity CSR page blocks, same page↔batch
determinism, consumed through the feature-gather prepass instead of being
densified at full F).  Query plans key their compiled-plan cache on the
format, so a dense and a CSR plan over the same model never collide.

Memory tiers: every dataset also lives on exactly one rung of the TIER
LADDER (see ``docs/architecture.md`` for the full design):

  ``device``  the original layout — device-resident jax arrays, consumed
              by kernels with zero staging (dataset size capped by HBM);
  ``host``    page-aligned host numpy blocks — the in-RAM out-of-core
              tier.  The streaming scan executor (``db/executor.py``)
              pages a host dataset through device memory batch by batch,
              double buffered, so datasets far larger than device memory
              execute;
  ``disk``    page-aligned memory-mapped files under the store's
              ``spill_dir`` — the bottom rung.  Dense rows are one mmap
              file; a CSR dataset is three (indptr / indices / values
              page arrays).  A disk dataset's ``page_slice`` is an
              ``np.memmap`` VIEW: only the pages a batch actually
              touches are ever faulted in, so the SCAN's steady-state
              host residency is bounded by the batch, not the dataset.
              (Ingest itself still materializes the array once in host
              RAM while writing the file — the tier bounds scan-time
              residency, not ingest residency.)

``put(..., tier=...)`` / ``put_sparse(..., tier=...)`` accept an explicit
tier or ``"auto"``: the auto cascade walks the ladder top-down — an
ingest that would push the device-resident total past
``device_budget_bytes`` spills to host, and one that would also push the
host-resident total past ``host_budget_bytes`` spills to disk.  Catalog
entries carry the tier, and the store accounts ``nbytes`` PER TIER
(``device_nbytes`` / ``host_nbytes`` / ``disk_nbytes``).  ``store.move``
migrates a dataset between any two tiers preserving the page layout
exactly; ``store.drop`` deletes the spill files the store created.  Both
dataset classes implement the executor's ``ScanSource`` protocol
(``page_slice`` in their own tier + ``to_device`` staging), so no caller
ever branches on where pages live.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import re
import tempfile
import time
import weakref
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.db.faults import (FaultInjector, InjectedFault, RetryPolicy,
                             ScanFault)
from repro.db.sparse import CSRPages, csr_from_dense, paginate_csr
from repro.obs import METRICS, TRACER

__all__ = ["StoredDataset", "SparseStoredDataset", "TensorBlockStore",
           "DenseStreamWriter", "mmap_array", "TIERS"]

#: the tier ladder, fastest first — the ``auto`` cascade walks it top-down
TIERS = ("device", "host", "disk")


def _check_tier(tier: str) -> str:
    if tier not in TIERS:
        raise ValueError(f"unknown tier {tier!r}; expected one of {TIERS}")
    return tier


def _host_copy(a) -> np.ndarray:
    """Materialize ANY tier's array as a plain host ndarray copy (mmap
    views must be read fully off the file before the file can go away)."""
    out = jax.device_get(a)
    return np.array(out) if isinstance(out, np.memmap) \
        else np.ascontiguousarray(out)


def mmap_array(path: str, arr: np.ndarray) -> np.memmap:
    """Write ``arr`` to ``path`` as a raw page-aligned memory-mapped file
    and return the live map.

    Raw (headerless) layout at offset 0, C-contiguous: logical store page
    ``p`` occupies exactly bytes ``[p * page_nbytes, (p+1) * page_nbytes)``
    of the file, so a ``page_slice`` view faults in only the OS pages that
    batch touches.  An existing file is unlinked first (never truncated in
    place — truncating a mapped file SIGBUSes readers of the old map; the
    unlinked inode stays alive for them).
    """
    if os.path.exists(path):
        os.unlink(path)
    mm = np.memmap(path, dtype=arr.dtype, mode="w+", shape=arr.shape)
    mm[...] = arr
    mm.flush()
    return mm


@dataclasses.dataclass
class StoredDataset:
    name: str
    data: Any                     # [N_padded, F]: jax.Array (device tier,
    #                               row-sharded), np.ndarray (host tier,
    #                               page-aligned pages), or np.memmap
    #                               (disk tier, page-aligned mmap file)
    num_rows: int                 # true N (pre-padding)
    page_rows: int
    labels: jax.Array | None = None
    task: str = "classification"
    created_at: float = dataclasses.field(default_factory=time.time)
    storage_format: str = "dense"
    tier: str = "device"

    @property
    def num_features(self) -> int:
        return self.data.shape[1]

    @property
    def num_pages(self) -> int:
        return self.data.shape[0] // self.page_rows

    @property
    def nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize

    @property
    def page_nbytes(self) -> int:
        """Bytes of ONE page — the unit the streaming executor budgets."""
        return self.nbytes // max(self.num_pages, 1)

    def page_slice(self, first_page: int, num_pages: int):
        """[num_pages * page_rows, F] contiguous page range, a VIEW in the
        dataset's own tier (device slice / host numpy view / np.memmap
        view — a disk-tier slice stays lazy: only the OS pages the batch
        touches are faulted in, never the whole file)."""
        lo = first_page * self.page_rows
        if self.tier != "device":
            return self.data[lo: lo + num_pages * self.page_rows]
        return jax.lax.dynamic_slice_in_dim(
            self.data, lo, num_pages * self.page_rows, axis=0)

    def to_device(self, block, sharding=None):
        """ScanSource staging: host/disk tiers issue an (async) device_put
        honoring the store's data sharding (a disk-tier mmap view is read
        straight into the transfer — no intermediate host copy of the
        whole dataset ever exists); device tier is a no-op."""
        if self.tier == "device":
            return block
        return jax.device_put(block, sharding)

    def batches(self, pages_per_batch: int) -> Iterator[tuple[int, Any]]:
        """Deterministic (batch_index, block) iteration — the F3 batching
        loop AND the replay unit: batch k always covers the same pages."""
        for k, first in enumerate(range(0, self.num_pages, pages_per_batch)):
            n = min(pages_per_batch, self.num_pages - first)
            yield k, self.page_slice(first, n)


@dataclasses.dataclass
class SparseStoredDataset:
    """A CSR-paged dataset: the sparse plane's analogue of StoredDataset.

    Same page↔batch determinism (a batch is a contiguous page range and
    every page block has one fixed shape), but rows live compressed —
    pages beyond ``num_rows`` are EMPTY rows (every feature missing),
    mirroring the dense store's NaN padding rows.  On the host tier the
    three page arrays are numpy, on the disk tier three memory-mapped
    page files; ``to_device`` ships all three under the store's data
    sharding (a CSRPages pytree is one ``device_put``).
    """

    name: str
    pages: CSRPages                # CSR page blocks (device or host arrays)
    num_rows: int                  # true N (pre-padding)
    labels: jax.Array | None = None
    task: str = "classification"
    created_at: float = dataclasses.field(default_factory=time.time)
    storage_format: str = "csr"
    tier: str = "device"

    @property
    def num_features(self) -> int:
        return self.pages.n_features

    @property
    def page_rows(self) -> int:
        return self.pages.page_rows

    @property
    def num_pages(self) -> int:
        return self.pages.num_pages

    @property
    def nbytes(self) -> int:
        return self.pages.nbytes

    @property
    def page_nbytes(self) -> int:
        return self.nbytes // max(self.num_pages, 1)

    @property
    def nnz(self) -> int:
        """True stored-entry count (excludes capacity padding)."""
        return int(np.sum(np.asarray(self.pages.indptr[:, -1])))

    def page_slice(self, first_page: int, num_pages: int) -> CSRPages:
        return self.pages.page_slice(first_page, num_pages)

    def to_device(self, block: CSRPages, sharding=None) -> CSRPages:
        if self.tier == "device":
            return block
        return jax.device_put(block, sharding)

    def batches(self, pages_per_batch: int) -> Iterator[tuple[int, CSRPages]]:
        """Deterministic (batch_index, CSR block) iteration — identical
        page→batch mapping to the dense plane's ``batches``."""
        for k, first in enumerate(range(0, self.num_pages, pages_per_batch)):
            n = min(pages_per_batch, self.num_pages - first)
            yield k, self.page_slice(first, n)


class TensorBlockStore:
    """Catalog of tiered datasets (one store per pod; DESIGN §8).

    ``device_budget_bytes`` / ``host_budget_bytes``: soft caps on the
    device- and host-resident dataset totals.  ``tier="auto"`` ingests
    cascade down the ladder: past the device budget they spill to host,
    past the host budget too they spill to disk (page-aligned mmap files
    under ``spill_dir``), where the streaming scan executor pages them
    through device memory.
    """

    def __init__(self, mesh: Mesh | None = None, *,
                 default_page_rows: int = 1024,
                 device_budget_bytes: int | None = None,
                 host_budget_bytes: int | None = None,
                 spill_dir: str | None = None,
                 injector: FaultInjector | None = None,
                 retry_policy: RetryPolicy | None = None):
        self.mesh = mesh
        self.default_page_rows = default_page_rows
        self.device_budget_bytes = device_budget_bytes
        self.host_budget_bytes = host_budget_bytes
        # reliability wiring (db/faults.py): ``move`` reads off the disk
        # tier through the ``disk_page_read`` site under the policy, and
        # rolls back on exhaustion.  An armed injector with no explicit
        # policy gets the documented default retry contract.
        self.injector = injector
        self.retry_policy = retry_policy if retry_policy is not None \
            else (RetryPolicy() if injector is not None else None)
        self._spill_dir = spill_dir
        # spill files THIS store wrote, per dataset (loader-owned page
        # files handed over via put_sparse(pages=...) are not tracked —
        # the store only deletes what it created)
        self._disk_paths: dict[str, list[str]] = {}
        self._datasets: dict[str, StoredDataset | SparseStoredDataset] = {}
        # model catalog: the serving plane's tenancy anchor.  A
        # registered model is PINNED here (the forest object stays
        # alive, so its fingerprint-keyed cache entries stay coherent);
        # what gets EVICTED under pressure is its compiled plans, via
        # the engines' ModelReuseCache LRU — never the model itself.
        self._models: dict[str, dict[str, Any]] = {}
        # drop-invalidation hooks: engines register their
        # invalidate_dataset so dropping a dataset sweeps the compiled
        # plans built against it (weakrefs — a dead engine unregisters
        # itself by getting collected)
        self._invalidators: list[weakref.ref] = []
        # model-id invalidation hooks: engines register ``invalidate`` so
        # re-pinning a model NAME sweeps the replaced forest's compiled
        # plans and persisted decisions by fingerprint (re-train must not
        # serve the old verdict)
        self._model_invalidators: list[weakref.ref] = []
        # decision catalog (db/optimizer.py): persisted optimizer
        # verdicts keyed (model fingerprint, dataset name, dataset
        # signature, mesh signature).  Swept on the same events that
        # sweep compiled plans: drop / re-put of the dataset here,
        # ``ForestQueryEngine.invalidate(model_id)`` by fingerprint.
        self._decisions: dict[tuple, Any] = {}

    # -- disk-tier spill files ----------------------------------------------
    @property
    def spill_dir(self) -> str:
        """Directory holding this store's disk-tier page files (created
        lazily: stores that never spill to disk touch no filesystem)."""
        if self._spill_dir is None:
            self._spill_dir = tempfile.mkdtemp(prefix="tbstore-disk-")
        return self._spill_dir

    def _disk_path(self, name: str, label: str) -> str:
        """Spill-file path for one page array.  The filename carries a
        short digest of the RAW dataset name: sanitization is lossy
        ("a/b" and "a:b" both flatten to "a_b"), and two datasets sharing
        a path would unlink each other's backing files through the spill
        lifecycle."""
        digest = hashlib.blake2s(name.encode(), digest_size=4).hexdigest()
        stem = f"{re.sub(r'[^A-Za-z0-9._@+-]', '_', name)}-{digest}"
        return os.path.join(self.spill_dir, f"{stem}.{label}.bin")

    def _disk_array(self, name: str, label: str, arr: np.ndarray
                    ) -> np.memmap:
        """Spill one page array to ``spill_dir`` and track the file."""
        path = self._disk_path(name, label)
        mm = mmap_array(path, arr)
        self._disk_paths.setdefault(name, []).append(path)
        return mm

    def _disk_empty(self, name: str, label: str, shape, dtype
                    ) -> np.memmap:
        """Create an EMPTY page-aligned spill file and track it — the
        streamed-ingest target: batches are written straight into the map
        so the full array never exists in host RAM.  An existing file is
        unlinked first (same SIGBUS note as :func:`mmap_array`)."""
        path = self._disk_path(name, label)
        if os.path.exists(path):
            os.unlink(path)
        mm = np.memmap(path, dtype=np.dtype(dtype), mode="w+", shape=shape)
        self._disk_paths.setdefault(name, []).append(path)
        return mm

    def _release_disk(self, name: str) -> None:
        """Delete the spill files written for ``name`` (live memmap views
        keep the unlinked inodes readable until they are collected)."""
        for path in self._disk_paths.pop(name, ()):
            try:
                os.unlink(path)
            except OSError:
                pass

    # -- mesh contract ------------------------------------------------------
    @property
    def data_axis_size(self) -> int:
        """Mesh ``data``-axis size (1 off-mesh).  Every ingest pads its
        page count to a multiple of this, so any whole-dataset batch
        divides evenly for the query plans' shard_map."""
        if self.mesh is not None and "data" in self.mesh.axis_names:
            return int(self.mesh.shape["data"])
        return 1

    def data_sharding(self) -> NamedSharding | None:
        """Row/page sharding for stored blocks: dim 0 over ``data``,
        replicated over ``model`` (None off-mesh).  One definition for
        dense pages, CSR page arrays, result writes, AND the streaming
        executor's host->device page transfers."""
        if self.mesh is not None and "data" in self.mesh.axis_names:
            return NamedSharding(self.mesh, P("data", None))
        return None

    # -- tier accounting ----------------------------------------------------
    @property
    def device_nbytes(self) -> int:
        return sum(d.nbytes for d in self._datasets.values()
                   if d.tier == "device")

    @property
    def host_nbytes(self) -> int:
        return sum(d.nbytes for d in self._datasets.values()
                   if d.tier == "host")

    @property
    def disk_nbytes(self) -> int:
        return sum(d.nbytes for d in self._datasets.values()
                   if d.tier == "disk")

    def _resolve_tier(self, tier: str, ingest_nbytes: int) -> str:
        """``auto`` cascades down the tier ladder: an ingest that would
        push the device-resident total past ``device_budget_bytes``
        spills to host, and one that would also push the host-resident
        total past ``host_budget_bytes`` spills to disk."""
        if tier != "auto":
            return _check_tier(tier)
        if (self.device_budget_bytes is None
                or self.device_nbytes + ingest_nbytes
                <= self.device_budget_bytes):
            return "device"
        if (self.host_budget_bytes is None
                or self.host_nbytes + ingest_nbytes
                <= self.host_budget_bytes):
            return "host"
        return "disk"

    # -- ingestion ----------------------------------------------------------
    def put(self, name: str, data, **kw) -> StoredDataset:
        """Ingest [N, F] dense rows — see ``_put_impl`` for the full
        contract.  Instrumented: a ``store.put`` span (its ``tier`` attr
        is the RESOLVED tier, so auto-cascade spills are visible per
        ingest) and the ``store.puts`` counter."""
        with TRACER.span("store.put", dataset=name) as sp:
            ds = self._put_impl(name, data, **kw)
            sp.set(tier=ds.tier)
        METRICS.counter("store.puts").inc()
        return ds

    def _put_impl(
        self,
        name: str,
        data: np.ndarray | jax.Array,
        *,
        labels: np.ndarray | None = None,
        page_rows: int | None = None,
        task: str = "classification",
        dtype=jnp.float32,
        tier: str = "auto",
    ) -> StoredDataset:
        """Ingest [N, F] rows: pad to whole pages (NaN rows — never counted
        in results), resolve the tier, lay out (device: shard rows over the
        mesh ``data`` axis; host: keep page-aligned numpy; disk: write one
        page-aligned mmap file), register."""
        page_rows = page_rows or self.default_page_rows
        arr = np.asarray(jax.device_get(data))
        n = arr.shape[0]
        # page padding AND divisibility by the data axis
        row_multiple = self.data_axis_size * page_rows
        pad = (-n) % row_multiple
        if pad:
            arr = np.concatenate(
                [arr, np.full((pad, arr.shape[1]), np.nan, arr.dtype)])
        np_dtype = np.dtype(dtype)
        tier = self._resolve_tier(tier, arr.size * np_dtype.itemsize)
        self._release_disk(name)          # re-put: old spill files go away
        self.drop_decisions(dataset=name)  # re-put: old decisions are stale
        if tier == "host":
            stored = np.ascontiguousarray(arr, np_dtype)
        elif tier == "disk":
            stored = self._disk_array(
                name, "rows", np.ascontiguousarray(arr, np_dtype))
        else:
            stored = jnp.asarray(arr, dtype)
            sharding = self.data_sharding()
            if sharding is not None:
                stored = jax.device_put(stored, sharding)
        lab = None
        if labels is not None:
            lab = jnp.asarray(np.asarray(labels), jnp.float32)
        ds = StoredDataset(name=name, data=stored, num_rows=n,
                           page_rows=page_rows, labels=lab, task=task,
                           tier=tier)
        self._datasets[name] = ds
        return ds

    def put_sparse(self, name: str, data=None, **kw
                   ) -> SparseStoredDataset:
        """Ingest a CSR dataset — see ``_put_sparse_impl`` for the full
        contract.  Instrumented like ``put`` (``store.put_sparse`` span
        with the resolved tier + the ``store.puts`` counter)."""
        with TRACER.span("store.put_sparse", dataset=name) as sp:
            ds = self._put_sparse_impl(name, data, **kw)
            sp.set(tier=ds.tier)
        METRICS.counter("store.puts").inc()
        return ds

    def _put_sparse_impl(
        self,
        name: str,
        data: np.ndarray | None = None,
        *,
        csr: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None,
        num_rows: int | None = None,
        num_features: int | None = None,
        pages: CSRPages | None = None,
        labels: np.ndarray | None = None,
        page_rows: int | None = None,
        task: str = "classification",
        drop_zeros: bool = False,
        tier: str = "auto",
    ) -> SparseStoredDataset:
        """Ingest a CSR dataset (the sparse data plane).

        Three entry points, most-compressed first:
          * ``pages`` — already-paginated CSRPages, device, host, or disk
            arrays (the LIBSVM→CSR loader hands these over; with
            ``tier="host"`` / ``tier="disk"`` a loader result already on
            that tier is registered with ZERO device work AND zero copy —
            criteo-scale files never round-trip the device);
          * ``csr`` — host (indptr [N+1], indices, values) triple;
          * ``data`` — dense-with-NaN host rows (NaN = missing; explicit
            zeros kept unless ``drop_zeros``), converted here.

        Page padding mirrors ``put``: rows pad to whole pages as EMPTY
        rows, and the page count pads to the mesh ``data`` axis.
        """
        page_rows = page_rows or self.default_page_rows
        pages_multiple = self.data_axis_size
        self._release_disk(name)          # re-put: old spill files go away
        self.drop_decisions(dataset=name)  # re-put: old decisions are stale

        if pages is not None:
            # already-paginated pages: never round-trip through the host
            # (a handoff already on the resolved tier is zero-copy; only
            # a tier MISMATCH migrates)
            if num_rows is None:
                raise ValueError("num_rows is required with pages=")
            num_features = pages.n_features
            tier = self._resolve_tier(tier, pages.nbytes)
            if tier == "device":
                # jnp.asarray is a no-op on arrays already on device
                stored = CSRPages(indptr=jnp.asarray(pages.indptr),
                                  indices=jnp.asarray(pages.indices),
                                  values=jnp.asarray(pages.values),
                                  n_features=int(num_features))
                sharding = self.data_sharding()
                if sharding is not None:
                    stored = jax.device_put(stored, sharding)
            elif tier == pages.tier:
                stored = pages            # zero-copy handoff
            elif tier == "host":
                stored = CSRPages(
                    indptr=_host_copy(pages.indptr),
                    indices=_host_copy(pages.indices),
                    values=_host_copy(pages.values),
                    n_features=int(num_features))
            else:                         # spill the handoff to disk
                stored = CSRPages(
                    indptr=self._disk_array(
                        name, "indptr", _host_copy(pages.indptr)),
                    indices=self._disk_array(
                        name, "indices", _host_copy(pages.indices)),
                    values=self._disk_array(
                        name, "values", _host_copy(pages.values)),
                    n_features=int(num_features))
        else:
            if csr is None:
                if data is None:
                    raise ValueError("need one of data=, csr=, pages=")
                arr = np.asarray(jax.device_get(data))
                num_rows = arr.shape[0]
                num_features = arr.shape[1]
                csr = csr_from_dense(arr, drop_zeros=drop_zeros)
            if num_rows is None or num_features is None:
                raise ValueError("num_rows/num_features required with csr=")
            indptr, indices, values = csr
            ip, ix, vl = paginate_csr(indptr, indices, values,
                                      num_rows=num_rows, page_rows=page_rows,
                                      n_features=num_features,
                                      pages_multiple=pages_multiple)
            nbytes = sum(a.size * a.dtype.itemsize for a in (ip, ix, vl))
            tier = self._resolve_tier(tier, nbytes)
            if tier == "host":
                stored = CSRPages(indptr=ip, indices=ix, values=vl,
                                  n_features=int(num_features))
            elif tier == "disk":
                stored = CSRPages(
                    indptr=self._disk_array(name, "indptr", ip),
                    indices=self._disk_array(name, "indices", ix),
                    values=self._disk_array(name, "values", vl),
                    n_features=int(num_features))
            else:
                stored = CSRPages(indptr=jnp.asarray(ip),
                                  indices=jnp.asarray(ix),
                                  values=jnp.asarray(vl),
                                  n_features=int(num_features))
                sharding = self.data_sharding()
                if sharding is not None:
                    stored = jax.device_put(stored, sharding)
        lab = None
        if labels is not None:
            lab = jnp.asarray(np.asarray(labels), jnp.float32)
        ds = SparseStoredDataset(name=name, pages=stored,
                                 num_rows=int(num_rows),
                                 labels=lab, task=task, tier=tier)
        self._datasets[name] = ds
        return ds

    def put_result(self, name: str, result: jax.Array, num_rows: int) -> StoredDataset:
        """The WRITE operator's sink: register an output dataset."""
        ds = StoredDataset(name=name, data=result[:, None] if result.ndim == 1
                           else result,
                           num_rows=num_rows, page_rows=self.default_page_rows)
        self._datasets[name] = ds
        return ds

    # -- streamed ingest ------------------------------------------------------
    def stream_writer(self, name: str, *, num_rows: int, num_features: int,
                      dtype=jnp.float32, page_rows: int | None = None,
                      tier: str = "auto", fill=np.nan,
                      labels: np.ndarray | None = None,
                      task: str = "classification") -> "DenseStreamWriter":
        """Open a batch-by-batch dense ingest under ``name``.

        The full [N, F] array never needs to exist in caller memory: rows
        arrive in order via ``write(batch)`` and land DIRECTLY on the
        resolved tier — on the disk tier each batch is written straight
        into the page-aligned mmap file, so ingest-time host residency is
        bounded by the batch, not the dataset (the in-database trainer's
        binning pass ingests its binned relation this way).  ``fill``
        pads the page-alignment tail rows (NaN for float data, the
        MISSING bin for binned relations).  ``close()`` registers and
        returns the ``StoredDataset``; the tier is resolved UP FRONT from
        the declared total size, so the auto cascade sees the whole
        ingest, not the first batch.
        """
        return DenseStreamWriter(self, name, num_rows=num_rows,
                                 num_features=num_features, dtype=dtype,
                                 page_rows=page_rows or self.default_page_rows,
                                 tier=tier, fill=fill, labels=labels,
                                 task=task)

    def put_stream(self, name: str, batches, **kw) -> StoredDataset:
        """Ingest an iterator of [rows_i, F] host batches (in row order)
        through :meth:`stream_writer` — see there for the contract."""
        w = self.stream_writer(name, **kw)
        try:
            for batch in batches:
                w.write(batch)
        except BaseException:
            w.abort()
            raise
        return w.close()

    # -- tier migration -----------------------------------------------------
    def move(self, name: str, tier: str):
        """Migrate a dataset between tiers — see ``_move_impl`` for the
        full contract (rollback semantics included).  Instrumented: a
        ``store.move`` span carrying the from/to rungs and the
        ``store.moves`` counter (counted per ATTEMPT — a rolled-back
        move still counts, its span's ``error`` attr marks it)."""
        src_tier = self.get(name).tier
        METRICS.counter("store.moves").inc()
        with TRACER.span("store.move", dataset=name,
                         src=src_tier, dst=tier):
            return self._move_impl(name, tier)

    def _move_impl(self, name: str, tier: str):
        """Migrate a dataset between any two tiers of the ladder
        (eviction: device -> host -> disk; promotion: the reverse).  Page
        layout is preserved exactly, so the page↔batch mapping — and
        therefore every prediction — is unchanged; compiled plans stay
        valid (tier is a runtime property of the scan, not of the plan).
        Moving OFF the disk tier deletes the spill files this store wrote
        (after the copy — live views keep the unlinked inodes alive).

        Failure semantics: reading the source off the DISK tier goes
        through the ``disk_page_read`` fault site under the store's
        retry policy; a fault that survives the retries ROLLS THE MOVE
        BACK — any spill files this move already wrote are unlinked, the
        tracked-path list is restored, and the catalog entry (and with
        it the per-tier ``*_nbytes`` accounting) is untouched — then a
        structured ``ScanFault`` is raised.  A failed move never leaks
        orphaned page files and never corrupts tier accounting."""
        _check_tier(tier)
        ds = self.get(name)
        if ds.tier == tier:
            return ds
        was_disk = ds.tier == "disk"
        sharding = self.data_sharding()
        injector, policy = self.injector, self.retry_policy

        def read_source(arr) -> np.ndarray:
            """Materialize one source page array on the host — the
            ``disk_page_read`` site when the source is the disk tier."""
            if not was_disk or (injector is None and policy is None):
                return _host_copy(arr)
            if policy is None:
                injector.fire("disk_page_read")
                return _host_copy(arr)
            return policy.run(lambda: _host_copy(arr),
                              site="disk_page_read", injector=injector)

        def relocate(label: str, arr):
            """One page array, source tier -> target tier."""
            src = read_source(arr)
            if tier == "host":
                return src
            if tier == "disk":
                return self._disk_array(name, label, src)
            out = jnp.asarray(src)
            return out if sharding is None else jax.device_put(out, sharding)

        # rollback bookkeeping: anything _disk_array appends past this
        # snapshot was written BY THIS MOVE and must not survive a failure
        paths_before = list(self._disk_paths.get(name, ()))
        try:
            if ds.storage_format == "csr":
                pages = CSRPages(indptr=relocate("indptr", ds.pages.indptr),
                                 indices=relocate("indices",
                                                  ds.pages.indices),
                                 values=relocate("values", ds.pages.values),
                                 n_features=ds.pages.n_features)
                new = dataclasses.replace(ds, pages=pages, tier=tier)
            else:
                new = dataclasses.replace(ds, data=relocate("rows", ds.data),
                                          tier=tier)
        except BaseException as e:
            for path in self._disk_paths.get(name, ()):
                if path not in paths_before:
                    try:
                        os.unlink(path)
                    except OSError:
                        pass
            if paths_before:
                self._disk_paths[name] = paths_before
            else:
                self._disk_paths.pop(name, None)
            retryable = (policy.retryable if policy is not None
                         else (InjectedFault, OSError))
            # only the GUARDED disk read gets the structured wrap; a
            # failure elsewhere (e.g. the target-tier write) propagates
            # as itself — it is not a disk_page_read exhaustion
            if was_disk and isinstance(e, retryable):
                attempts = policy.max_attempts if policy is not None else 1
                raise ScanFault(
                    "disk_page_read", attempts=attempts, rows_completed=0,
                    cause=e,
                    detail=f"move({name!r} -> {tier!r}) rolled back") from e
            raise
        if was_disk:
            self._release_disk(name)
        self._datasets[name] = new
        return new

    # -- catalog --------------------------------------------------------------
    def get(self, name: str) -> StoredDataset:
        try:
            return self._datasets[name]
        except KeyError:
            raise KeyError(f"dataset {name!r} not in store; "
                           f"have {sorted(self._datasets)}")

    def register_invalidator(self, fn: Callable[[str], int]) -> None:
        """Register a per-dataset invalidation hook (weakly).  Engines
        register ``invalidate_dataset`` so ``drop`` sweeps the compiled
        plans whose keys carry the dropped dataset's name."""
        ref = weakref.WeakMethod(fn) if hasattr(fn, "__self__") \
            else weakref.ref(fn)
        self._invalidators.append(ref)

    def register_model_invalidator(self, fn: Callable[[str], int]) -> None:
        """Register a per-model-fingerprint invalidation hook (weakly).
        Engines register ``invalidate`` so re-pinning a model name via
        ``put_model`` sweeps the REPLACED forest's compiled plans."""
        ref = weakref.WeakMethod(fn) if hasattr(fn, "__self__") \
            else weakref.ref(fn)
        self._model_invalidators.append(ref)

    def drop(self, name: str) -> int:
        """Drop a dataset AND invalidate dependent engine cache entries
        (compiled plans close over batch signatures derived from the
        dataset — leaving them resident after a drop pins device buffers
        and serves entries for data that no longer exists).  Persisted
        optimizer decisions keyed on the dataset are swept the same way.
        Returns the number of cache entries (plans + decisions)
        invalidated across registered engines.  Disk-tier spill files
        this store wrote are deleted."""
        existed = self._datasets.pop(name, None)
        self._release_disk(name)
        invalidated = 0
        if existed is not None:
            # persisted optimizer decisions keyed on this dataset go
            # first (the engine hooks below then find nothing to re-drop)
            invalidated += self.drop_decisions(dataset=name)
            for ref in list(self._invalidators):
                fn = ref()
                if fn is None:
                    self._invalidators.remove(ref)
                else:
                    invalidated += int(fn(name) or 0)
        return invalidated

    # -- decision catalog (cost-based optimizer; db/optimizer.py) ------------
    def put_decision(self, key: tuple, decision) -> None:
        """Persist an optimizer decision.  Key layout is fixed by
        ``db/optimizer.py``: ``key[0]`` is the model fingerprint,
        ``key[1]`` the dataset name (or the ``#rows`` sentinel for
        serving-plane row-batch decisions) — the two slots the sweeps
        below match on."""
        self._decisions[key] = decision

    def get_decision(self, key: tuple):
        """Steady-state lookup (None on miss) — the dictionary read that
        replaces the score + autotune passes on repeat queries."""
        return self._decisions.get(key)

    def drop_decisions(self, *, model_id: str | None = None,
                       dataset: str | None = None) -> int:
        """Sweep persisted decisions by model fingerprint (``key[0]``)
        and/or dataset name (``key[1]``); both None sweeps everything.
        Returns entries dropped.  Mirrors the compiled-plan sweeps: a
        decision must never outlive the model or dataset it ranked."""
        doomed = [k for k in self._decisions
                  if (model_id is None or k[0] == model_id)
                  and (dataset is None or k[1] == dataset)]
        for k in doomed:
            del self._decisions[k]
        return len(doomed)

    def decision_catalog(self) -> dict[tuple, dict[str, Any]]:
        """Catalog view of persisted decisions (dataclass → dict)."""
        return {k: dataclasses.asdict(d)
                for k, d in self._decisions.items()}

    # -- model catalog (serving-plane tenancy) -------------------------------
    def put_model(self, name: str, forest, **meta) -> dict[str, Any]:
        """Pin a forest model in the catalog under ``name``.

        The store is the system of record for WHAT is served
        (``serve/forest.ForestServeEngine.register_model`` goes through
        here); the engines' ``ModelReuseCache`` LRU decides what stays
        COMPILED.  Re-putting a name REPLACES the pinned forest and
        sweeps the replaced fingerprint's state — its persisted
        optimizer decisions here, and its compiled plans through every
        registered model invalidator — so a re-trained model can never
        serve the old forest's verdicts (the stale-decision-after-retrain
        regression, ``tests/test_train_streaming.py``)."""
        old = self._models.get(name)
        entry = dict(forest=forest, trees=int(forest.num_trees),
                     depth=int(forest.depth),
                     features=int(forest.n_features),
                     model_type=forest.model_type, task=forest.task,
                     created_at=time.time(), **meta)
        self._models[name] = entry
        if old is not None and old["forest"] is not forest:
            old_fp = old.get("fingerprint")
            if old_fp is None:
                from repro.core.reuse import fingerprint_forest
                old_fp = fingerprint_forest(old["forest"])
            self.drop_decisions(model_id=old_fp)
            for ref in list(self._model_invalidators):
                fn = ref()
                if fn is None:
                    self._model_invalidators.remove(ref)
                else:
                    fn(old_fp)
        return entry

    def get_model(self, name: str):
        try:
            return self._models[name]["forest"]
        except KeyError:
            raise KeyError(f"model {name!r} not in store; "
                           f"have {sorted(self._models)}")

    def drop_model(self, name: str) -> bool:
        """Unpin a model.  Compiled plans keyed on its fingerprint are
        the caller's to sweep (``ForestQueryEngine.invalidate``) — the
        store only owns the pin."""
        return self._models.pop(name, None) is not None

    def model_catalog(self) -> dict[str, dict[str, Any]]:
        """Catalog view of pinned models (without the forest objects)."""
        return {n: {k: v for k, v in e.items() if k != "forest"}
                for n, e in self._models.items()}

    def __contains__(self, name: str) -> bool:
        return name in self._datasets

    def catalog(self) -> dict[str, dict[str, Any]]:
        out = {}
        for n, d in self._datasets.items():
            entry = dict(rows=d.num_rows, features=d.num_features,
                         pages=d.num_pages, page_rows=d.page_rows,
                         bytes=d.nbytes, task=d.task,
                         format=getattr(d, "storage_format", "dense"),
                         tier=getattr(d, "tier", "device"))
            if entry["format"] == "csr":
                entry["nnz"] = d.nnz
            out[n] = entry
        return out


class DenseStreamWriter:
    """Batch-by-batch dense ingest (``TensorBlockStore.stream_writer``).

    Rows arrive in order and are written straight into the resolved
    tier's backing storage — for the disk tier an EMPTY page-aligned
    mmap file created up front, so the full [N, F] matrix never exists
    in host RAM during ingest.  ``close()`` pads the page-alignment tail
    with ``fill``, flushes, registers, and returns the ``StoredDataset``;
    ``abort()`` unlinks anything this writer created.
    """

    def __init__(self, store: TensorBlockStore, name: str, *,
                 num_rows: int, num_features: int, dtype, page_rows: int,
                 tier: str, fill, labels, task: str):
        self.store = store
        self.name = name
        self.num_rows = int(num_rows)
        self.page_rows = int(page_rows)
        self.fill = fill
        self.labels = labels
        self.task = task
        self._np_dtype = np.dtype(dtype)
        row_multiple = store.data_axis_size * page_rows
        self.total_rows = self.num_rows + (-self.num_rows) % row_multiple
        nbytes = self.total_rows * int(num_features) * self._np_dtype.itemsize
        self.tier = store._resolve_tier(tier, nbytes)
        # re-put semantics mirror _put_impl: old spill files and stale
        # optimizer decisions for this name go away when the ingest opens
        store._release_disk(name)
        store.drop_decisions(dataset=name)
        shape = (self.total_rows, int(num_features))
        if self.tier == "disk":
            self._buf = store._disk_empty(name, "rows", shape,
                                          self._np_dtype)
        else:
            self._buf = np.empty(shape, self._np_dtype)
        self._cursor = 0
        self._closed = False

    def write(self, batch: np.ndarray) -> None:
        """Append one [rows, F] host batch at the current row cursor."""
        if self._closed:
            raise RuntimeError(f"stream_writer({self.name!r}) is closed")
        arr = np.asarray(batch)
        if arr.dtype != self._np_dtype:
            arr = arr.astype(self._np_dtype)
        end = self._cursor + arr.shape[0]
        if end > self.num_rows:
            raise ValueError(
                f"stream_writer({self.name!r}): batch overruns the "
                f"declared num_rows ({end} > {self.num_rows})")
        self._buf[self._cursor:end] = arr
        self._cursor = end

    def abort(self) -> None:
        """Drop everything this writer created (nothing is registered)."""
        if self._closed:
            return
        self._closed = True
        self._buf = None
        if self.tier == "disk":
            self.store._release_disk(self.name)

    def close(self) -> StoredDataset:
        """Pad, flush, register — returns the new ``StoredDataset``."""
        if self._closed:
            raise RuntimeError(f"stream_writer({self.name!r}) is closed")
        if self._cursor != self.num_rows:
            raise ValueError(
                f"stream_writer({self.name!r}): wrote {self._cursor} rows, "
                f"declared {self.num_rows}")
        self._closed = True
        store = self.store
        with TRACER.span("store.put", dataset=self.name,
                         streamed=True) as sp:
            if self._cursor < self.total_rows:  # page-alignment tail
                self._buf[self._cursor:] = self.fill
            if self.tier == "disk":
                self._buf.flush()
                stored = self._buf
            elif self.tier == "host":
                stored = self._buf
            else:
                stored = jnp.asarray(self._buf)
                sharding = store.data_sharding()
                if sharding is not None:
                    stored = jax.device_put(stored, sharding)
            lab = None
            if self.labels is not None:
                lab = jnp.asarray(np.asarray(self.labels), jnp.float32)
            ds = StoredDataset(name=self.name, data=stored,
                               num_rows=self.num_rows,
                               page_rows=self.page_rows, labels=lab,
                               task=self.task, tier=self.tier)
            store._datasets[self.name] = ds
            sp.set(tier=self.tier)
        METRICS.counter("store.puts").inc()
        return ds
