"""Physical query plans for in-database forest inference (netsDB's core).

Three plans over the same logical query  SCAN -> PREDICT -> AGGREGATE -> WRITE
(paper Sec. 3.2/3.3, Fig. 3):

  udf        UDF-centric: the whole forest inside one transform UDF;
             DATA parallelism (mesh axis ``data`` shards sample blocks, the
             forest is replicated per device).  Compiles to ONE stage.
  rel        Relation-centric: CROSS-PRODUCT(tree partitions x sample
             blocks) -> partial aggregate -> final aggregate -> postprocess/
             write.  MODEL parallelism (mesh axis ``model`` shards the tree
             dimension).  Compiles to FOUR stages, the first being the
             model-partitioning stage.
  rel+reuse  netsDB-OPT: the partition stage's output is materialized in the
             ModelReuseCache and reused across queries on the same model,
             collapsing steady-state execution to the three data stages.

Each stage is timed and its materialized bytes recorded, reproducing the
paper's latency breakdowns.  On a mesh the plans run under ``shard_map`` so
data/model parallelism is explicit; without a mesh a single-device path keeps
the same stage structure (model "partitions" become tree chunks).
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import algorithms as algs
from repro.core import postprocess as post
from repro.core.forest import Forest, hb_path_matrix, pad_trees, qs_bitvectors
from repro.core.reuse import GLOBAL_CACHE, MaterializedModel, ModelReuseCache, fingerprint_forest
from repro.db.operators import Operator, StageReport, run_stages, split_into_stages
from repro.db.store import TensorBlockStore

__all__ = ["QueryResult", "ForestQueryEngine"]


@dataclasses.dataclass
class QueryResult:
    predictions: jax.Array            # [N] final probabilities / regressands
    plan: str
    algorithm: str
    num_stages: int
    stage_reports: list[StageReport]
    partition_s: float                # model-partition stage (0 on reuse hit)
    infer_s: float                    # cross-product / UDF stages
    aggregate_s: float
    write_s: float
    total_s: float
    reuse_hit: bool = False

    def breakdown(self) -> dict[str, float]:
        return {
            "partition": self.partition_s,
            "inference": self.infer_s,
            "aggregate": self.aggregate_s,
            "write": self.write_s,
            "total": self.total_s,
        }


def _predict_fn(algorithm: str):
    """Raw per-tree score backend: jnp algorithms or Pallas kernels."""
    if algorithm in algs.ALGORITHMS:
        return partial(algs.predict_raw, algorithm=algorithm)
    from repro.kernels.ops import KERNEL_ALGORITHMS
    if algorithm in KERNEL_ALGORITHMS:
        return KERNEL_ALGORITHMS[algorithm]
    raise ValueError(f"unknown algorithm {algorithm!r}")


class ForestQueryEngine:
    """Executes forest-inference queries against a TensorBlockStore."""

    def __init__(self, store: TensorBlockStore, mesh: Mesh | None = None,
                 reuse_cache: ModelReuseCache | None = None):
        self.store = store
        self.mesh = mesh if mesh is not None else store.mesh
        self.cache = reuse_cache if reuse_cache is not None else GLOBAL_CACHE

    # ------------------------------------------------------------------
    # model partition stage (the reusable one)
    # ------------------------------------------------------------------
    def _partition_model(self, forest: Forest, algorithm: str,
                         num_parts: int) -> MaterializedModel:
        forest_p, true_T = pad_trees(forest, num_parts)
        aux: dict[str, Any] = {}
        if "hummingbird" in algorithm:
            C, D = hb_path_matrix(forest_p.depth)
            aux["C"] = jnp.asarray(C, jnp.float32)
            aux["D"] = jnp.asarray(D, jnp.float32)
        if "quickscorer" in algorithm:
            aux["bv"] = jnp.asarray(qs_bitvectors(forest_p.depth))
        spec = None
        if self.mesh is not None and "model" in self.mesh.axis_names:
            spec = P("model")
            sharding = NamedSharding(self.mesh, P("model", None))
            arrays = {k: jax.device_put(v, sharding)
                      for k, v in forest_p.arrays().items()}
            forest_p = dataclasses.replace(forest_p, **arrays)
        else:
            forest_p = jax.tree_util.tree_map(jnp.asarray, forest_p)
        jax.block_until_ready(forest_p.arrays())
        return MaterializedModel(forest=forest_p, true_num_trees=true_T,
                                 aux=aux, partition_spec=spec, build_time_s=0.0)

    # ------------------------------------------------------------------
    # plan bodies
    # ------------------------------------------------------------------
    def _udf_ops(self, forest: Forest, algorithm: str, true_T: int):
        predict = _predict_fn(algorithm)
        meta = dict(model_type=forest.model_type, task=forest.task,
                    num_trees=true_T, base_score=forest.base_score)

        def udf(state):
            x = state["x"]
            raw = predict(forest, x)
            state = dict(state)
            state["pred"] = post.postprocess(post.aggregate_raw(raw), **meta)
            return state

        return [
            Operator("scan", lambda s: s),
            Operator("transform:forest-udf", udf),
            Operator("write", lambda s: s, breaker=True),
        ]

    def _rel_ops(self, mat: MaterializedModel, algorithm: str):
        predict = _predict_fn(algorithm)
        forest = mat.forest
        meta = dict(model_type=forest.model_type, task=forest.task,
                    num_trees=mat.true_num_trees, base_score=forest.base_score)
        mesh = self.mesh
        n_parts = (mesh.shape["model"]
                   if mesh is not None and "model" in mesh.axis_names else 4)
        n_parts = min(n_parts, forest.num_trees)

        def cross_product(state):
            """CROSS-PRODUCT(tree partition, sample block) -> partial sums.

            Model parallelism: partial[p, b] = sum of tree scores of
            partition p on sample b.  On a mesh this runs under shard_map
            with the tree axis sharded; locally it is a reshaped vmap —
            identical math, same [n_parts, B] partials."""
            x = state["x"]

            def one_part(tree_part: Forest):
                return post.aggregate_raw(predict(tree_part, x))  # [B]

            T = forest.num_trees
            per = T // n_parts
            parts = jax.tree_util.tree_map(
                lambda a: a.reshape((n_parts, per) + a.shape[1:]),
                forest)
            partial_scores = jax.vmap(one_part)(parts)            # [P, B]
            state = dict(state)
            state["partials"] = partial_scores
            return state

        def aggregate(state):
            state = dict(state)
            state["summed"] = jnp.sum(state.pop("partials"), axis=0)
            return state

        def postprocess_op(state):
            state = dict(state)
            state["pred"] = post.postprocess(state.pop("summed"), **meta)
            return state

        return [
            Operator("scan", lambda s: s),
            Operator("cross-product:partial-agg", cross_product,
                     breaker=True),
            Operator("aggregate", aggregate, breaker=True),
            Operator("postprocess", postprocess_op),
            Operator("write", lambda s: s, breaker=True),
        ]

    # ------------------------------------------------------------------
    # entry point
    # ------------------------------------------------------------------
    def infer(
        self,
        dataset: str,
        forest: Forest,
        *,
        algorithm: str = "predicated",
        plan: str = "udf",
        batch_pages: int | None = None,
        write_as: str | None = None,
        model_id: str | None = None,
    ) -> QueryResult:
        """Run the end-to-end inference query (paper's measured pipeline)."""
        if plan not in ("udf", "rel", "rel+reuse"):
            raise ValueError(f"unknown plan {plan!r}")
        ds = self.store.get(dataset)
        t_query0 = time.perf_counter()

        partition_s = 0.0
        reuse_hit = False
        if plan == "udf":
            fp, true_T = pad_trees(forest, 1)
            ops = self._udf_ops(fp, algorithm, true_T)
            prefix_reports: list[StageReport] = []
        else:
            n_parts = (self.mesh.shape["model"]
                       if self.mesh is not None and
                       "model" in self.mesh.axis_names else 4)
            t0 = time.perf_counter()
            if plan == "rel+reuse":
                mid = model_id or fingerprint_forest(forest)
                key = (mid, algorithm, n_parts,
                       id(self.mesh) if self.mesh is not None else 0)
                before_hits = self.cache.stats.hits
                mat = self.cache.get_or_build(
                    key, lambda: self._partition_model(forest, algorithm,
                                                       n_parts))
                reuse_hit = self.cache.stats.hits > before_hits
            else:
                mat = self._partition_model(forest, algorithm, n_parts)
            partition_s = time.perf_counter() - t0
            prefix_reports = [StageReport(
                name="stageP:model-partition",
                operators=("partition-model",),
                seconds=partition_s,
                materialized_bytes=sum(
                    a.size * a.dtype.itemsize
                    for a in mat.forest.arrays().values()),
            )]
            ops = self._rel_ops(mat, algorithm)

        stages = split_into_stages(ops)

        # F3 batching: iterate page batches; deterministic batch->pages map.
        batch_pages = batch_pages or ds.num_pages
        preds = []
        reports: list[StageReport] = list(prefix_reports)
        for _, block in ds.batches(batch_pages):
            state = {"x": block}
            state, reps = run_stages(stages, state)
            preds.append(state["pred"])
            reports.extend(reps)
        predictions = jnp.concatenate(preds)[: ds.num_rows]

        write_s = 0.0
        if write_as is not None:
            t0 = time.perf_counter()
            out = self.store.put_result(write_as, predictions, ds.num_rows)
            jax.block_until_ready(out.data)
            write_s = time.perf_counter() - t0

        total_s = time.perf_counter() - t_query0

        def _has(rep, *names):
            return any(any(n in op for n in names) for op in rep.operators)

        infer_s = sum(r.seconds for r in reports
                      if _has(r, "forest-udf", "cross-product"))
        agg_s = sum(r.seconds for r in reports
                    if _has(r, "aggregate", "postprocess")
                    and not _has(r, "cross-product", "forest-udf"))
        return QueryResult(
            predictions=predictions,
            plan=plan,
            algorithm=algorithm,
            num_stages=len(stages) + (1 if plan != "udf" else 0),
            stage_reports=reports,
            partition_s=partition_s if not reuse_hit else 0.0,
            infer_s=infer_s,
            aggregate_s=agg_s,
            write_s=write_s,
            total_s=total_s,
            reuse_hit=reuse_hit,
        )
