"""Physical query plans for in-database forest inference (netsDB's core).

Three plans over the same logical query  SCAN -> PREDICT -> AGGREGATE -> WRITE
(paper Sec. 3.2/3.3, Fig. 3):

  udf        UDF-centric: the whole forest inside one transform UDF;
             DATA parallelism (mesh axis ``data`` shards sample blocks, the
             forest is replicated per device).  Compiles to ONE stage.
  rel        Relation-centric: CROSS-PRODUCT(tree partitions x sample
             blocks) -> partial aggregate -> final aggregate -> postprocess/
             write.  MODEL parallelism (mesh axis ``model`` shards the tree
             dimension).  Compiles to FOUR stages, the first being the
             model-partitioning stage.
  rel+reuse  netsDB-OPT: the partition stage's output is materialized in the
             ModelReuseCache and reused across queries on the same model,
             collapsing steady-state execution to the three data stages.

Fused backends (``*_pallas_fused``) run phase-2 aggregation INSIDE the
kernel: both plans then consume [B] (or [n_parts, B]) partial sums and the
[B, T] per-tree score matrix never exists in the query path — the
materialization the paper charges stage boundaries with, eliminated at the
kernel level.

Compiled-plan cache: ``ModelReuseCache`` generalized from the partition
stage's OUTPUT to the whole plan's EXECUTABLE.  The jitted stage list —
keyed on (model fingerprint, algorithm, plan, STORAGE FORMAT, batch
signature, mesh) — is built once; steady-state queries skip partitioning
AND tracing/compilation (the first-query vs steady-state distinction of
Sec. 3.3, lifted one level).  ``rel`` deliberately stays uncached: it is
the paper's no-reuse baseline.

Sparse data plane: a dataset stored as CSR pages (``store.put_sparse``)
runs the SAME logical plans through a feature-gather prepass — the plan
compacts the forest onto its used-feature union (``core.forest.
compact_forest``), scatters each CSR page block into dense
``[rows, F_used]`` compact tiles (``kernels.gather``), and feeds the
existing (fused) kernels.  The ``[BT, I, F]`` one-hot never exists at
full F, so criteo-scale feature counts execute instead of being modeled.
Dense and CSR plans over the same model are distinct cache entries (the
storage format is part of both cache keys).

Multi-device fused inference: on a mesh (``dist.sharding.make_forest_plan``
axis mapping: ``data`` shards sample blocks / CSR pages, ``model`` shards
tree blocks) the kernel stages run under ``jax.experimental.shard_map``.
The udf plan's body is the fused kernel over the LOCAL sample shard with a
replicated forest; the rel plan's cross-product + partial-aggregate
collapse into ONE local fused kernel launch per device followed by a
single ``psum`` over ``model`` — the ``[n_parts, B]`` partials never cross
a stage boundary (they exist only as the per-device ``[B_local]`` sums
inside the manual region).  The CSR feature-gather prepass also moves
INSIDE the body, so compact tiles only ever exist at the local batch.
Without a mesh (or without the relevant axis) the single-device template
keeps the same stage structure: the rel cross-product is an unrolled loop
over tree partitions (``n_parts`` derived from the kernel tree-block
heuristic, overridable per query), and the aggregate stage folds the
partials sequentially in partition order — the same association XLA:CPU's
all-reduce uses, which is what makes mesh and mesh-less fused predictions
bit-identical in f32.

Streaming scan execution: the per-batch loop lives in ONE place — the
``StreamingScanExecutor`` (``db/executor.py``).  Every plan (udf / rel),
storage format (dense / CSR), and memory tier (device-resident / host /
disk out-of-core) runs the same double-buffered loop: batch *i+1*'s
pages are in DMA flight (async ``device_put`` under the store's
``data_sharding``) while batch *i* runs its kernel stages and batch
*i−1*'s predictions drain — on a DEDICATED WORKER THREAD — into a
preallocated host result buffer, so the D2H never blocks the next
batch's kernels.  Device-tier datasets take the identical loop with a
no-op transfer stage; disk-tier datasets feed it ``np.memmap`` page
views, so a LIBSVM file larger than both the device and host budgets
streams end to end.  The result buffer also retired the jax-0.4.37
partially-replicated-concatenate workaround from the hot path (pinned
reproduction in ``tests/test_streaming.py``).

Each stage is timed and its materialized bytes recorded, reproducing the
paper's latency breakdowns.  See ``docs/architecture.md`` for the plan /
cache / tier design and ``docs/benchmarks.md`` for how the timings
surface in the BENCH_*.json trajectories.
"""

from __future__ import annotations

import dataclasses
import time
import weakref
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.core import algorithms as algs
from repro.core import postprocess as post
from repro.core.forest import (Forest, compact_forest, hb_path_matrix,
                               pad_trees, qs_bitvectors, tree_slice)
from repro.core.reuse import (GLOBAL_CACHE, GLOBAL_PLAN_CACHE,
                              MaterializedModel, ModelReuseCache,
                              fingerprint_forest, mesh_signature)
from repro.db.executor import (DEFAULT_STREAM_BATCH_BYTES, ScanStats,
                               StreamingScanExecutor)
from repro.db.faults import (Deadline, DegradedReport, FaultInjector,
                             RetryPolicy)
from repro.db.operators import (Operator, StageReport, ndevices,
                                run_stages, split_into_stages)
from repro.db.optimizer import CostBasedOptimizer, Decision
from repro.db.store import TensorBlockStore
from repro.dist.sharding import ForestShardingPlan, make_forest_plan
from repro.obs import METRICS, TRACER, TraceSummary
from repro.kernels.gather import csr_block_to_dense, gather_inverse_map
from repro.kernels.ops import default_tree_block

__all__ = ["QueryResult", "RowBatchResult", "CompiledQueryPlan",
           "ForestQueryEngine"]

#: sentinel occupying the DATASET slot (key[2]) of row-plan cache keys:
#: row batches come from the serving plane, not a stored dataset, so
#: ``store.drop`` -> ``invalidate_dataset`` must never sweep them ("#"
#: cannot appear in a catalog name a well-behaved caller would drop).
ROW_PLAN_DATASET = "#rows"


@dataclasses.dataclass
class QueryResult:
    predictions: jax.Array            # [N] final probabilities / regressands
    plan: str
    algorithm: str
    num_stages: int
    stage_reports: list[StageReport]
    partition_s: float                # model-partition stage (0 on reuse hit)
    infer_s: float                    # cross-product / UDF stages
    aggregate_s: float
    write_s: float
    total_s: float
    reuse_hit: bool = False           # model-cache OR plan-cache hit
    plan_reuse_hit: bool = False      # compiled-plan cache hit specifically
    storage_format: str = "dense"     # which data plane executed (dense/csr)
    n_parts: int = 1                  # tree partitions (rel plans; mesh =
    #                                   model-axis size, else heuristic)
    mesh_devices: int = 1             # devices the query executed across
    tier: str = "device"              # memory tier the scan read from
    scan: ScanStats | None = None     # streaming-executor telemetry
    degraded: DegradedReport | None = None   # set when the result is a
    #                                   PARTIAL (deadline_s expired):
    #                                   scored rows are exact, missing
    #                                   rows are NaN, row_mask says which
    trace: TraceSummary | None = None  # per-query span rollup when the
    #                                   obs TRACER is enabled (else None);
    #                                   the full span tree is exportable
    #                                   via TRACER.export_chrome()
    decision: Decision | None = None  # the optimizer verdict this query
    #                                   executed under (plan="auto" /
    #                                   algorithm="auto" only, else None)

    def breakdown(self) -> dict[str, float]:
        return {
            "partition": self.partition_s,
            "inference": self.infer_s,
            "aggregate": self.aggregate_s,
            "write": self.write_s,
            "total": self.total_s,
        }


@dataclasses.dataclass
class RowBatchResult:
    """Result of the row-level serving entry point (``infer_rows``).

    Much lighter than ``QueryResult`` on purpose: the serving plane
    calls this at request rate, so there is no stage-report list, no
    scan telemetry, no per-call store round-trip — just the predictions,
    whether the compiled plan was reused, and the wall the tick paid.
    """

    predictions: jax.Array            # [B]; masked-out padding rows are NaN
    plan_reuse_hit: bool              # compiled-plan cache hit (zero retrace)
    algorithm: str
    plan: str
    batch_rows: int                   # the padded batch signature B
    rows_scored: int                  # real rows (row_mask True count)
    total_s: float


@dataclasses.dataclass
class CompiledQueryPlan:
    """A materialized plan executable: the jitted stage list + its model.

    The stages close over the padded/partitioned device-resident forest, so
    a cache hit reuses BOTH the partition-stage output (model reuse) and
    every stage's jit cache (no re-tracing, no re-compilation for already
    seen batch shapes).
    """

    stages: list                      # list[operators.Stage]
    num_stages: int                   # reported count (incl. partition stage)
    mat: Any = None                   # rel plans: pins the MaterializedModel
    #                                   whose id() keys this entry, so the id
    #                                   cannot be reused while the entry lives
    build_time_s: float = 0.0         # set by ModelReuseCache.get_or_build


def _predict_fn(algorithm: str):
    """Raw per-tree score backend: jnp algorithms or Pallas kernels."""
    if algorithm in algs.ALGORITHMS:
        return partial(algs.predict_raw, algorithm=algorithm)
    from repro.kernels.ops import KERNEL_ALGORITHMS
    if algorithm in KERNEL_ALGORITHMS:
        return KERNEL_ALGORITHMS[algorithm]
    raise ValueError(f"unknown algorithm {algorithm!r}")


def _predict_sum_fn(algorithm: str):
    """(forest, x) -> [B] summed raw margins; returns (fn, is_fused).

    Fused Pallas backends aggregate in-kernel; everything else composes
    ``aggregate_raw`` over the raw [B, T] backend (the unfused reference
    data path).
    """
    from repro.kernels.ops import FUSED_KERNEL_ALGORITHMS
    if algorithm in FUSED_KERNEL_ALGORITHMS:
        return FUSED_KERNEL_ALGORITHMS[algorithm], True
    predict = _predict_fn(algorithm)
    return (lambda forest, x: post.aggregate_raw(predict(forest, x))), False


class ForestQueryEngine:
    """Executes forest-inference queries against a TensorBlockStore."""

    def __init__(self, store: TensorBlockStore, mesh: Mesh | None = None,
                 reuse_cache: ModelReuseCache | None = None,
                 plan_cache: ModelReuseCache | None = None):
        self.store = store
        self.mesh = mesh if mesh is not None else store.mesh
        # axis mapping for shard_map execution (data = sample blocks,
        # model = tree blocks); a None axis disables that parallelism
        self.fplan: ForestShardingPlan = make_forest_plan(self.mesh)
        self.cache = reuse_cache if reuse_cache is not None else GLOBAL_CACHE
        self.plan_cache = (plan_cache if plan_cache is not None
                           else GLOBAL_PLAN_CACHE)
        # id -> content fingerprint, invalidated when the Forest is GC'd
        self._fingerprints: dict[int, str] = {}
        # store.drop sweeps this engine's dataset-dependent plan entries
        store.register_invalidator(self.invalidate_dataset)
        # store.put_model re-pins sweep the replaced fingerprint's plans
        # and decisions through this engine (stale-after-retrain fix)
        store.register_model_invalidator(self.invalidate)
        # the cost-based optimizer behind plan="auto"/algorithm="auto"
        # (db/optimizer.py); replaceable — tests install tighter budgets
        self.optimizer = CostBasedOptimizer(self)

    # ------------------------------------------------------------------
    # cache-key components
    # ------------------------------------------------------------------
    # model identity: content hash, computed once per live Forest object
    def _model_key(self, forest: Forest, model_id: str | None) -> str:
        if model_id is not None:
            return model_id
        k = id(forest)
        fp = self._fingerprints.get(k)
        if fp is None:
            fp = fingerprint_forest(forest)
            self._fingerprints[k] = fp
            weakref.finalize(forest, self._fingerprints.pop, k, None)
        return fp

    # ------------------------------------------------------------------
    # cache sweeping (paper: model updates must drop BOTH materializations)
    # ------------------------------------------------------------------
    def invalidate(self, model_id: str | None = None) -> int:
        """Sweep BOTH the partition-model cache and the compiled-plan
        cache (all entries, or one model's).  Returns entries dropped.

        The raw ``ModelReuseCache.invalidate`` matches ``key[0]``, but
        plan keys lead with a kind tag (``'udf-plan'``/``'rel-plan'``) and
        carry the model id at ``key[1]`` — a key[0]-only sweep silently
        leaves every compiled plan (and the device buffers its stages
        close over) alive.  This is the engine-level sweep that gets both.
        """
        n = self.cache.invalidate(model_id)
        n += self.plan_cache.invalidate(model_id, key_index=1)
        # persisted optimizer decisions are keyed on the fingerprint at
        # key[0] — a model update must re-decide, not serve stale picks
        n += self.store.drop_decisions(model_id=model_id)
        return n

    def invalidate_dataset(self, dataset: str) -> int:
        """``TensorBlockStore.drop``'s hook: sweep compiled plans built
        against ``dataset`` (plan keys carry the dataset name at
        ``key[2]``) AND any persisted optimizer decisions keyed on it
        (``store.drop`` sweeps those itself first; this keeps direct
        calls equivalent).  Model materializations are
        dataset-independent and survive — only the plan executables,
        whose batch signatures came from the dropped dataset, are
        stale.  Returns entries dropped."""
        n = self.plan_cache.invalidate(dataset, key_index=2)
        n += self.store.drop_decisions(dataset=dataset)
        return n

    # ------------------------------------------------------------------
    # sparse prepass (the wide-sparse data plane's plan-build half)
    # ------------------------------------------------------------------
    def _sparse_prepass(self, forest: Forest):
        """Compact the forest onto its used-feature union and build the
        CSR gather's inverse map.  Host-side, once per plan build (cached
        with the plan/materialization, like the partition stage)."""
        cf, gather_idx = compact_forest(forest)
        inv_map = jnp.asarray(gather_inverse_map(gather_idx,
                                                 forest.n_features))
        return cf, inv_map, int(gather_idx.size)

    def _gather_operator(self, inv_map: jax.Array, f_used: int) -> Operator:
        """SCAN-side feature-gather prepass: CSR page block -> dense
        compact tile.  Not a breaker — it fuses into the same jitted
        stage as the kernel call (no extra materialization boundary)."""

        def gather(state):
            state = dict(state)
            state["x"] = csr_block_to_dense(state["x"], inv_map, f_used)
            return state

        return Operator("gather:csr-compact", gather)

    # ------------------------------------------------------------------
    # model partition stage (the reusable one)
    # ------------------------------------------------------------------
    def _partition_model(self, forest: Forest, algorithm: str,
                         num_parts: int, *,
                         storage_format: str = "dense") -> MaterializedModel:
        with TRACER.span("plan.partition", algorithm=algorithm,
                         num_parts=num_parts,
                         storage_format=storage_format):
            return self._partition_model_impl(
                forest, algorithm, num_parts, storage_format=storage_format)

    def _partition_model_impl(self, forest: Forest, algorithm: str,
                              num_parts: int, *,
                              storage_format: str = "dense"
                              ) -> MaterializedModel:
        aux: dict[str, Any] = {}
        if storage_format == "csr":
            forest, inv_map, f_used = self._sparse_prepass(forest)
            aux["inv_map"] = inv_map
            aux["f_used"] = f_used
        forest_p, true_T = pad_trees(forest, num_parts)
        if "hummingbird" in algorithm:
            C, D = hb_path_matrix(forest_p.depth)
            aux["C"] = jnp.asarray(C, jnp.float32)
            aux["D"] = jnp.asarray(D, jnp.float32)
        if "quickscorer" in algorithm:
            aux["bv"] = jnp.asarray(qs_bitvectors(forest_p.depth))
        spec = None
        shardings = self.fplan.forest_shardings(forest_p)
        if shardings is not None:
            spec = self.fplan.tree_spec
            forest_p = jax.device_put(forest_p, shardings)
        else:
            forest_p = jax.tree_util.tree_map(jnp.asarray, forest_p)
        jax.block_until_ready(forest_p.arrays())
        return MaterializedModel(forest=forest_p, true_num_trees=true_T,
                                 aux=aux, partition_spec=spec, build_time_s=0.0)

    # ------------------------------------------------------------------
    # plan bodies
    # ------------------------------------------------------------------
    def _udf_ops(self, forest: Forest, algorithm: str, true_T: int,
                 sparse_aux: tuple | None = None):
        """UDF-centric plan body.  ``sparse_aux`` = (inv_map, f_used) when
        the dataset is CSR pages (the feature-gather prepass input)."""
        predict_sum, _ = _predict_sum_fn(algorithm)
        meta = dict(model_type=forest.model_type, task=forest.task,
                    num_trees=true_T, base_score=forest.base_score)
        fplan = self.fplan

        if fplan.mesh is not None and fplan.data_axis is not None:
            # DATA parallelism under shard_map: sample blocks sharded over
            # ``data``, the forest replicated per device.  The CSR gather
            # runs INSIDE the body, so the dense compact tile only ever
            # exists at the LOCAL batch (never [B_global, F_used]).
            if sparse_aux is not None:
                inv_map, f_used = sparse_aux

                def body(x_local, f_local, inv_local):
                    tile = csr_block_to_dense(x_local, inv_local, f_used)
                    return predict_sum(f_local, tile)
            else:
                inv_map = jnp.zeros((1,), jnp.int32)    # unused placeholder

                def body(x_local, f_local, inv_local):
                    return predict_sum(f_local, x_local)

            sm = shard_map(body, mesh=fplan.mesh,
                           in_specs=(fplan.x_spec, fplan.replicated_spec,
                                     fplan.replicated_spec),
                           out_specs=fplan.out_spec, check_rep=False)

            def udf(state):
                state = dict(state)
                x = state.pop("x")
                state["pred"] = post.postprocess(sm(x, forest, inv_map),
                                                 **meta)
                return state

            return [
                Operator("scan", lambda s: s),
                Operator("transform:forest-udf@shard_map", udf),
                Operator("write", lambda s: s, breaker=True),
            ]

        # single-device template (also: mesh without a data axis)
        def udf(state):
            x = state["x"]
            state = dict(state)
            state["pred"] = post.postprocess(predict_sum(forest, x), **meta)
            return state

        ops = [Operator("scan", lambda s: s)]
        if sparse_aux is not None:
            ops.append(self._gather_operator(*sparse_aux))
        ops += [
            Operator("transform:forest-udf", udf),
            Operator("write", lambda s: s, breaker=True),
        ]
        return ops

    def _rel_ops(self, mat: MaterializedModel, algorithm: str,
                 n_parts: int):
        predict_sum, fused = _predict_sum_fn(algorithm)
        forest = mat.forest
        meta = dict(model_type=forest.model_type, task=forest.task,
                    num_trees=mat.true_num_trees, base_score=forest.base_score)
        fplan = self.fplan
        sparse_aux = (mat.aux["inv_map"], mat.aux["f_used"]) \
            if "inv_map" in mat.aux else None

        def postprocess_op(state):
            state = dict(state)
            state["pred"] = post.postprocess(state.pop("summed"), **meta)
            return state

        if fplan.mesh is not None and fplan.model_axis is not None:
            # MODEL (x DATA) parallelism under shard_map: the partition
            # stage laid the tree axis out over ``model`` (n_parts ==
            # n_model), so CROSS-PRODUCT + PARTIAL-AGGREGATE collapse into
            # ONE local fused kernel launch per device — each body call
            # sums its LOCAL tree shard in-kernel — followed by a single
            # psum over ``model``.  The [n_parts, B] partials never cross
            # a stage boundary; the CSR gather prepass runs inside the
            # body so compact tiles only ever exist at the local batch.
            inv_map = sparse_aux[0] if sparse_aux else \
                jnp.zeros((1,), jnp.int32)
            f_used = sparse_aux[1] if sparse_aux else 0
            model_axis = fplan.model_axis

            def body(x_local, f_local, inv_local):
                if sparse_aux is not None:
                    x_local = csr_block_to_dense(x_local, inv_local, f_used)
                part = predict_sum(f_local, x_local)       # [B_local]
                return jax.lax.psum(part, model_axis)

            sm = shard_map(body, mesh=fplan.mesh,
                           in_specs=(fplan.x_spec, fplan.tree_spec,
                                     fplan.replicated_spec),
                           out_specs=fplan.out_spec, check_rep=False)

            def cross_product(state):
                state = dict(state)
                state["summed"] = sm(state.pop("x"), forest, inv_map)
                return state

            return [
                Operator("scan", lambda s: s),
                Operator("cross-product:psum-agg", cross_product,
                         breaker=True),
                Operator("postprocess", postprocess_op),
                Operator("write", lambda s: s, breaker=True),
            ]

        # --- mesh-less template (the paper's stage-by-stage rel plan) ----
        def cross_product(state):
            """CROSS-PRODUCT(tree partition, sample block) -> partial sums.

            Model parallelism: partial[p, b] = sum of tree scores of
            partition p on sample b.  Fused backends aggregate in-kernel
            per partition, so the per-partition call already yields [B]
            and the unrolled partition loop replaces the vmap (pallas
            grids don't batch).  This unrolled loop is the template the
            shard_map path above distributes: partition p's launch is
            device p's local launch."""
            x = state["x"]
            T = forest.num_trees
            per = T // n_parts

            if fused:
                partial_scores = jnp.stack(
                    [predict_sum(tree_slice(forest, p * per, per), x)
                     for p in range(n_parts)])                # [P, B]
            else:
                parts = jax.tree_util.tree_map(
                    lambda a: a.reshape((n_parts, per) + a.shape[1:]),
                    forest)
                partial_scores = jax.vmap(
                    lambda tree_part: predict_sum(tree_part, x))(parts)
            state = dict(state)
            state["partials"] = partial_scores
            return state

        def aggregate(state):
            state = dict(state)
            parts = state.pop("partials")                     # [P, B]
            # sequential fold in partition order — the association
            # XLA:CPU's all-reduce uses, so the shard_map+psum path above
            # reproduces this sum BIT-identically in f32 (jnp.sum's
            # reduction tree would not)
            summed = parts[0]
            for p in range(1, parts.shape[0]):
                summed = summed + parts[p]
            state["summed"] = summed
            return state

        ops = [Operator("scan", lambda s: s)]
        if sparse_aux is not None:
            # sparse plane: the gather prepass shares the cross-product
            # stage (the compact tile is its VMEM input, not a new
            # materialization boundary)
            ops.append(self._gather_operator(*sparse_aux))
        ops += [
            Operator("cross-product:partial-agg", cross_product,
                     breaker=True),
            Operator("aggregate", aggregate, breaker=True),
            Operator("postprocess", postprocess_op),
            Operator("write", lambda s: s, breaker=True),
        ]
        return ops

    # ------------------------------------------------------------------
    # rel-plan partitioning granularity
    # ------------------------------------------------------------------
    def _resolve_n_parts(self, forest: Forest, algorithm: str,
                         n_parts: int | None) -> int:
        """Tree-partition count for the rel plans.

        On a model mesh the physical partitioning IS the mesh: one tree
        shard per device along ``model`` (an explicit ``n_parts`` is
        ignored — the partition stage must lay trees out evenly over the
        axis).  Mesh-less, kernel-backed algorithms derive the default
        from the kernel's tree-block heuristic (ceil(T / tree_block)):
        one partition per kernel tree block, so the unrolled
        cross-product launches exactly the passes the kernel would make
        anyway — replacing the old magic ``n_parts = 4``.  The jnp
        backends have no tree blocks (their vmap'd partial is one fused
        XLA op regardless), so they keep the small thread-count-like
        default.  Callers can override via ``infer(..., n_parts=...)``.
        """
        if self.fplan.model_axis is not None:
            return self.fplan.n_model
        if n_parts is None:
            if "pallas" not in algorithm:
                return min(4, forest.num_trees)
            _, fused = _predict_sum_fn(algorithm)
            bt = default_tree_block(forest, fused=fused)
            return max(1, -(-forest.num_trees // bt))
        return max(1, int(n_parts))

    # ------------------------------------------------------------------
    # entry point
    # ------------------------------------------------------------------
    def infer(self, dataset: str, forest: Forest, **kw) -> QueryResult:
        """Run the end-to-end inference query — see ``_infer`` for the
        full parameter contract.  This wrapper is the observability
        boundary: with ``obs.TRACER`` enabled the whole query runs under
        a ``query.infer`` root span and the result carries a
        ``TraceSummary`` (per-phase wall totals + the ``METRICS``
        counter deltas the query accrued) at ``QueryResult.trace``;
        disabled (the default), it is a tail call with zero overhead.
        """
        if not TRACER.enabled:
            return self._infer(dataset, forest, **kw)
        mark = TRACER.mark()
        before = METRICS.counter_values()
        with TRACER.span("query.infer", dataset=dataset,
                         plan=kw.get("plan", "udf"),
                         algorithm=kw.get("algorithm", "predicated")
                         ) as root:
            res = self._infer(dataset, forest, **kw)
            root.set(tier=res.tier, storage_format=res.storage_format,
                     reuse_hit=res.reuse_hit)
        res.trace = TRACER.summarize(
            root, since=mark, counters_before=before,
            counters_now=METRICS.counter_values())
        return res

    # ------------------------------------------------------------------
    # in-database training entry point (db/train.py)
    # ------------------------------------------------------------------
    def train(self, dataset: str, cfg, **kw):
        """Train a forest ON a stored dataset, streaming through the same
        tier ladder and ``StreamingScanExecutor`` the inference plans use
        — the other half of the lifecycle (see ``db/train.py`` and
        ``docs/training.md`` for the full contract).

        The trained ``Forest`` lands in the store's model catalog under
        ``model_name`` (default ``f"{dataset}:model"``), tree blocks
        sharded over the mesh ``model`` axis, so it flows straight into
        the serving plane and the optimizer's decision catalog without
        leaving the database.  Returns a ``TrainResult`` whose forest is
        bit-identical to ``core.train.train_forest`` on the resident
        rows given identical bin edges.
        """
        from repro.db.train import train_streaming
        return train_streaming(self, dataset, cfg, **kw)

    # ------------------------------------------------------------------
    # row-level serving entry point (serve/forest.py's hot path)
    # ------------------------------------------------------------------
    def infer_rows(
        self,
        forest: Forest,
        x,
        *,
        row_mask: np.ndarray | None = None,
        algorithm: str = "predicated",
        plan: str = "udf",
        model_id: str | None = None,
        n_parts: int | None = None,
    ) -> RowBatchResult:
        """Score a PRE-PADDED row batch against the compiled-plan cache.

        The serving plane's hot path: ``x`` is ``[B, F]`` dense rows
        already padded to a fixed batch signature (the coalescer's
        bucket ladder), so every call with the same ``(model, algorithm,
        plan, B, F, mesh)`` hits an existing ``CompiledQueryPlan`` —
        no store round-trip, no scan executor, no re-partitioning
        (``rel+reuse`` reuses the cached ``MaterializedModel``), and in
        the steady state ZERO re-tracing (asserted via the
        ``plan.cache_hits``/``plan.cache_misses`` counters and
        ``plan.traces``, exactly like ``infer``).

        ``row_mask`` marks the real rows: predictions for padding rows
        are forced to NaN so coalescer padding can never leak into a
        caller's results.  The bare ``rel`` plan is rejected — serving
        always runs cached executables.

        On a data mesh ``B`` must divide the ``data`` axis; the batch is
        placed under the store's ``data_sharding`` like any scan batch.

        ``plan="auto"`` / ``algorithm="auto"`` resolve through the
        optimizer's row-batch decision (``decide_rows``, persisted per
        (model, batch signature, mesh) — the serve plane resolves this
        once at ``register_model`` instead of per call).
        """
        if plan == "auto" or algorithm == "auto":
            dec = self.optimizer.decide_rows(
                forest, int(getattr(x, "shape", (len(x),))[0]),
                model_id=model_id,
                algorithms=None if algorithm == "auto" else (algorithm,),
                plans=None if plan == "auto" else (plan,))
            algorithm, plan = dec.algorithm, dec.plan
            if n_parts is None:
                n_parts = dec.n_parts
        if plan not in ("udf", "rel+reuse"):
            raise ValueError(
                f"infer_rows serves cached plans only (udf / rel+reuse), "
                f"got {plan!r}")
        t0 = time.perf_counter()
        x = jnp.asarray(x, jnp.float32)
        if x.ndim != 2:
            raise ValueError(f"expected [B, F] rows, got shape {x.shape}")
        B, F = int(x.shape[0]), int(x.shape[1])
        if self.fplan.n_data > 1 and B % self.fplan.n_data:
            raise ValueError(
                f"row batch {B} must divide the mesh data axis "
                f"({self.fplan.n_data}) — pick bucket sizes that are "
                f"axis multiples")
        sharding = self.store.data_sharding()
        if sharding is not None:
            x = jax.device_put(x, sharding)
        mid = self._model_key(forest, model_id)
        mesh_id = mesh_signature(self.mesh)
        batch_sig = (B, F)

        with TRACER.span("query.infer_rows", plan=plan,
                         algorithm=algorithm, batch_rows=B) as sp:
            if plan == "udf":
                pkey = ("udf-row-plan", mid, ROW_PLAN_DATASET, algorithm,
                        "dense", batch_sig, mesh_id)

                def build() -> CompiledQueryPlan:
                    with TRACER.span("plan.build", plan="udf-rows",
                                     algorithm=algorithm):
                        fp, true_T = pad_trees(forest, 1)
                        stages = split_into_stages(
                            self._udf_ops(fp, algorithm, true_T))
                        return CompiledQueryPlan(stages=stages,
                                                 num_stages=len(stages))
            else:
                n_parts = self._resolve_n_parts(forest, algorithm, n_parts)
                mkey = (mid, algorithm, n_parts, mesh_id, "dense")
                mat = self.cache.get_or_build(
                    mkey, lambda: self._partition_model(
                        forest, algorithm, n_parts))
                pkey = ("rel-row-plan", mid, ROW_PLAN_DATASET, algorithm,
                        n_parts, "dense", batch_sig, mesh_id, id(mat))

                def build() -> CompiledQueryPlan:
                    with TRACER.span("plan.build", plan="rel-rows",
                                     algorithm=algorithm):
                        stages = split_into_stages(
                            self._rel_ops(mat, algorithm, n_parts))
                        return CompiledQueryPlan(stages=stages,
                                                 num_stages=len(stages) + 1,
                                                 mat=mat)

            before = self.plan_cache.stats.hits
            qplan = self.plan_cache.get_or_build(pkey, build)
            plan_hit = self.plan_cache.stats.hits > before
            METRICS.counter("plan.cache_hits" if plan_hit
                            else "plan.cache_misses").inc()
            TRACER.event("plan.cache", hit=plan_hit, plan=f"{plan}-rows")

            state, _ = run_stages(qplan.stages, {"x": x})
            preds = state["pred"]
            rows_scored = B
            if row_mask is not None:
                mask = np.asarray(row_mask, bool)
                if mask.shape != (B,):
                    raise ValueError(
                        f"row_mask shape {mask.shape} != ({B},)")
                rows_scored = int(mask.sum())
                # padding rows never leak: their predictions are NaN
                preds = jnp.where(jnp.asarray(mask), preds, jnp.nan)
            sp.set(reuse_hit=plan_hit, rows=rows_scored)

        return RowBatchResult(
            predictions=preds,
            plan_reuse_hit=plan_hit,
            algorithm=algorithm,
            plan=plan,
            batch_rows=B,
            rows_scored=rows_scored,
            total_s=time.perf_counter() - t0,
        )

    def _infer(
        self,
        dataset: str,
        forest: Forest,
        *,
        algorithm: str = "predicated",
        plan: str = "udf",
        batch_pages: int | None = None,
        write_as: str | None = None,
        model_id: str | None = None,
        n_parts: int | None = None,
        prefetch_depth: int = 2,
        deadline_s: float | None = None,
        injector: FaultInjector | None = None,
        retry_policy: RetryPolicy | None = None,
        auto_move: bool = False,
    ) -> QueryResult:
        """Run the end-to-end inference query (paper's measured pipeline).

        ``plan="auto"`` / ``algorithm="auto"`` route through the
        cost-based optimizer (``db/optimizer.py``): the first query per
        (model fingerprint, dataset signature, mesh) pays a bounded
        score + measure pass, every later query resolves the persisted
        decision with a dictionary lookup.  Either axis can be pinned
        while the other stays auto; explicit ``n_parts`` /
        ``batch_pages`` always win over the decision's.  ``auto_move``
        additionally applies the decision's TIER recommendation
        (``store.move`` promotion before the scan — off by default: a
        query should not silently migrate a dataset).

        ``n_parts`` overrides the rel plans' tree-partition count on the
        MESH-LESS path (default: one partition per kernel tree block); a
        model mesh fixes the count to its ``model``-axis size.
        ``prefetch_depth`` controls the streaming executor: 2 (default)
        double-buffers page DMA against compute, 1 runs the synchronous
        reference pipeline (the benchmarks' overlap baseline).

        Reliability (``db/faults.py``, ``docs/reliability.md``):
        ``injector`` / ``retry_policy`` arm the scan's fault sites and
        bound their recovery; ``deadline_s`` is the per-query budget —
        checked cooperatively at batch boundaries, an expired budget
        returns a PARTIAL result whose ``degraded`` report carries the
        rows scored / missing and the exact ``row_mask`` (scored rows
        are bit-identical to an unbounded run; missing rows are NaN).
        """
        decision: Decision | None = None
        if plan == "auto" or algorithm == "auto":
            decision = self.optimizer.decide(
                dataset, forest, model_id=model_id,
                algorithms=None if algorithm == "auto" else (algorithm,),
                plans=None if plan == "auto" else (plan,))
            if auto_move and decision.tier != \
                    getattr(self.store.get(dataset), "tier", "device"):
                self.store.move(dataset, decision.tier)
                # the move changed the dataset signature; re-decide once
                # under the new tier (persisted, so still one-shot)
                decision = self.optimizer.decide(
                    dataset, forest, model_id=model_id,
                    algorithms=None if algorithm == "auto"
                    else (algorithm,),
                    plans=None if plan == "auto" else (plan,))
            algorithm, plan = decision.algorithm, decision.plan
            if n_parts is None:
                n_parts = decision.n_parts
            if batch_pages is None:
                batch_pages = decision.batch_pages
        if plan not in ("udf", "rel", "rel+reuse"):
            raise ValueError(f"unknown plan {plan!r}")
        ds = self.store.get(dataset)
        fmt = getattr(ds, "storage_format", "dense")
        tier = getattr(ds, "tier", "device")
        t_query0 = time.perf_counter()
        # the deadline budgets the WHOLE query from here (plan build +
        # scan), matching what a caller on the request path experiences
        deadline = Deadline(deadline_s, start=t_query0) \
            if deadline_s is not None else None
        if batch_pages is None:
            batch_pages = ds.num_pages
            if tier != "device":
                # out-of-core default (host AND disk tiers): a batch is
                # half the device budget (two in-flight page buffers
                # together fit it), or a fixed footprint when no budget
                # is set — an explicit off-device ingest must still
                # stream, never whole-dataset device_put.  Sized in
                # data-axis units, rounding DOWN, so the mesh
                # divisibility round-up below cannot push the pair past
                # the budget (floor: one page per device).
                budget = self.store.device_budget_bytes
                target = budget // 2 if budget else DEFAULT_STREAM_BATCH_BYTES
                unit = max(1, self.fplan.n_data)
                fit = target // max(ds.page_nbytes, 1)
                batch_pages = min(ds.num_pages,
                                  max(unit, fit // unit * unit))
        if self.fplan.n_data > 1:
            # shard_map needs page batches that divide evenly over the
            # data axis; num_pages itself is a data-axis multiple (the
            # store pads ingests to guarantee it), so round up and clamp
            nd = self.fplan.n_data
            batch_pages = min(-(-batch_pages // nd) * nd, ds.num_pages)

        # the batch signature pins every block shape the stage jits will
        # see, so a plan-cache hit implies zero re-tracing.  The storage
        # format itself is a SEPARATE plan-key component (a dense and a
        # CSR plan over the same model are different executables); the
        # CSR signature additionally pins the per-page entry capacity.
        mesh_id = mesh_signature(self.mesh)
        if fmt == "csr":
            batch_sig = (ds.num_features, ds.pages.capacity,
                         ds.num_pages, ds.page_rows, batch_pages)
        else:
            batch_sig = (ds.data.shape[1], ds.num_pages,
                         ds.page_rows, batch_pages)

        partition_s = 0.0
        model_hit = False
        plan_hit = False
        prefix_reports: list[StageReport] = []

        # plan keys carry the model id at key[1] (engine.invalidate) and
        # the DATASET NAME at key[2] (store.drop -> invalidate_dataset:
        # a dropped dataset must not leave compiled plans keyed on its
        # batch signature resident)
        if plan == "udf":
            mid = self._model_key(forest, model_id)
            pkey = ("udf-plan", mid, dataset, algorithm, fmt, batch_sig,
                    mesh_id)

            def build_udf() -> CompiledQueryPlan:
                with TRACER.span("plan.build", plan="udf",
                                 algorithm=algorithm, storage_format=fmt):
                    f, sparse_aux = forest, None
                    if fmt == "csr":
                        cf, inv_map, f_used = self._sparse_prepass(forest)
                        f = cf
                        sparse_aux = (inv_map, f_used)
                    fp, true_T = pad_trees(f, 1)
                    stages = split_into_stages(
                        self._udf_ops(fp, algorithm, true_T,
                                      sparse_aux=sparse_aux))
                    return CompiledQueryPlan(stages=stages,
                                             num_stages=len(stages))

            before = self.plan_cache.stats.hits
            qplan = self.plan_cache.get_or_build(pkey, build_udf)
            plan_hit = self.plan_cache.stats.hits > before
            n_parts = 1
        else:
            n_parts = self._resolve_n_parts(forest, algorithm, n_parts)
            t0 = time.perf_counter()
            if plan == "rel+reuse":
                mid = self._model_key(forest, model_id)
                mkey = (mid, algorithm, n_parts, mesh_id, fmt)
                before_hits = self.cache.stats.hits
                mat = self.cache.get_or_build(
                    mkey, lambda: self._partition_model(
                        forest, algorithm, n_parts, storage_format=fmt))
                model_hit = self.cache.stats.hits > before_hits
            else:
                mat = self._partition_model(forest, algorithm, n_parts,
                                            storage_format=fmt)
            partition_s = time.perf_counter() - t0
            prefix_reports = [StageReport(
                name="stageP:model-partition",
                operators=("partition-model",),
                seconds=partition_s,
                materialized_bytes=sum(
                    a.size * a.dtype.itemsize
                    for a in mat.forest.arrays().values()),
                devices=ndevices(mat.forest.arrays()),
            )]

            if plan == "rel+reuse":
                # id(mat) ties the plan entry to THIS materialization: if
                # the model cache evicted and rebuilt the model, the new
                # mat has a new id and the stale plan misses instead of
                # serving stages over the old arrays.  The entry stores
                # mat itself (CompiledQueryPlan.mat) so the keyed id stays
                # pinned for the entry's lifetime — the stage closures
                # alone only capture mat.forest, which would let the
                # wrapper be freed and its id reused
                pkey = ("rel-plan", mid, dataset, algorithm, n_parts, fmt,
                        batch_sig, mesh_id, id(mat))

                def build_rel() -> CompiledQueryPlan:
                    with TRACER.span("plan.build", plan="rel+reuse",
                                     algorithm=algorithm,
                                     storage_format=fmt):
                        stages = split_into_stages(
                            self._rel_ops(mat, algorithm, n_parts))
                        return CompiledQueryPlan(stages=stages,
                                                 num_stages=len(stages) + 1,
                                                 mat=mat)

                before = self.plan_cache.stats.hits
                qplan = self.plan_cache.get_or_build(pkey, build_rel)
                plan_hit = self.plan_cache.stats.hits > before
            else:
                stages = split_into_stages(
                    self._rel_ops(mat, algorithm, n_parts))
                qplan = CompiledQueryPlan(stages=stages,
                                          num_stages=len(stages) + 1)

        reuse_hit = model_hit or plan_hit
        if plan != "rel":
            # the compiled-plan cache was consulted (rel is the paper's
            # deliberately uncached baseline — no consult, no count)
            METRICS.counter("plan.cache_hits" if plan_hit
                            else "plan.cache_misses").inc()
            TRACER.event("plan.cache", hit=plan_hit, plan=plan)

        # F3 batching through the streaming scan executor: ONE loop for
        # every plan/format/tier.  Host-tier pages double-buffer their
        # DMA against the kernel stages; device-tier datasets take the
        # no-op transfer stage.  Per-batch predictions land in the
        # executor's preallocated host buffer — no concatenate (and no
        # jax-0.4.37 partially-replicated-concatenate workaround) on the
        # hot path.
        executor = StreamingScanExecutor(
            qplan.stages,
            sharding=self.store.data_sharding(),
            prefetch_depth=prefetch_depth,
            injector=injector,
            retry_policy=retry_policy,
            deadline=deadline,
            # the device-transfer halving ladder's floor: halved batches
            # must stay divisible by the mesh data axis
            min_batch_pages=max(1, self.fplan.n_data))
        out_np, batch_reports, scan = executor.execute(ds, batch_pages)
        reports: list[StageReport] = list(prefix_reports) + batch_reports
        predictions = jnp.asarray(out_np)

        degraded = None
        if scan.deadline_hit:
            mask = executor.last_mask
            rows_scored = int(mask.sum()) if mask is not None else 0
            degraded = DegradedReport(
                rows_scored=rows_scored,
                rows_missing=ds.num_rows - rows_scored,
                cause="deadline", deadline_s=deadline_s, row_mask=mask)

        write_s = 0.0
        if write_as is not None:
            t0 = time.perf_counter()
            with TRACER.span("query.write", dataset=write_as):
                out = self.store.put_result(write_as, predictions,
                                            ds.num_rows)
                jax.block_until_ready(out.data)
            write_s = time.perf_counter() - t0

        total_s = time.perf_counter() - t_query0

        def _has(rep, *names):
            return any(any(n in op for n in names) for op in rep.operators)

        infer_s = sum(r.seconds for r in reports
                      if _has(r, "forest-udf", "cross-product"))
        agg_s = sum(r.seconds for r in reports
                    if _has(r, "aggregate", "postprocess")
                    and not _has(r, "cross-product", "forest-udf"))
        return QueryResult(
            predictions=predictions,
            plan=plan,
            algorithm=algorithm,
            num_stages=qplan.num_stages,
            stage_reports=reports,
            partition_s=partition_s if not reuse_hit else 0.0,
            infer_s=infer_s,
            aggregate_s=agg_s,
            write_s=write_s,
            total_s=total_s,
            reuse_hit=reuse_hit,
            plan_reuse_hit=plan_hit,
            storage_format=fmt,
            n_parts=n_parts,
            mesh_devices=(self.mesh.size if self.mesh is not None else 1),
            tier=tier,
            scan=scan,
            degraded=degraded,
            decision=decision,
        )
