"""In-database layer: tensor-block store, external loaders, query plans."""
