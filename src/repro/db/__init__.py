"""In-database layer: tiered tensor-block store, external loaders,
query plans, and the streaming scan executor (out-of-core paging)."""
