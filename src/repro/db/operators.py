"""Dataflow-graph operator framework (netsDB's query compilation model).

Paper Sec. 3.1: applications are dataflow graphs of relational operators
customized by UDFs; at runtime a graph is split into PIPELINE STAGES at
pipeline breakers (hash / partition / aggregate / write), each stage runs
multi-threaded over vectors of sample blocks, and every stage boundary
MATERIALIZES its output.  The stage count is the crux of the paper's
UDF-centric vs relation-centric trade-off: one stage vs four, and the
per-stage scheduling + materialization overhead is what model-reuse removes.

Mapping here: an operator's ``apply`` is traced into the stage's single
jitted function; breakers end the stage, force materialization
(block_until_ready — the honest TPU analogue of netsDB writing pages), and
record per-stage wall time.  Query plans in db/query.py are built from these
primitives so the benchmark's stage-count/overhead story is measured, not
narrated.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax

from repro.obs import METRICS, TRACER

__all__ = ["Operator", "Stage", "StageReport", "ndevices", "run_stages",
           "TRACE_STATS"]

# Tracing telemetry: a stage's fused body runs as Python only while jax.jit
# TRACES it (cache hits go straight to the compiled executable), so this
# counter counts (re)traces — the compiled-plan cache's "no re-tracing"
# guarantee is asserted against it.  Lives in the process-global metrics
# registry as ``plan.traces`` (docs/observability.md).
_TRACES = METRICS.counter("plan.traces")


class _TraceStatsView:
    """Backwards-compat dict facade over the ``plan.traces`` counter.

    The pre-obs API was a mutable module-global ``TRACE_STATS`` dict;
    callers that still read (or ``+=``-increment) ``TRACE_STATS
    ["traces"]`` keep working against the registry counter.  New code
    should use ``obs.METRICS.counter("plan.traces")`` directly.
    """

    _KEY = "traces"

    def __getitem__(self, key: str) -> int:
        if key != self._KEY:
            raise KeyError(key)
        return _TRACES.value

    def __setitem__(self, key: str, value: int) -> None:
        if key != self._KEY:
            raise KeyError(key)
        _TRACES.set(value)

    def get(self, key: str, default=None):
        return _TRACES.value if key == self._KEY else default

    def keys(self):
        return (self._KEY,)

    def __repr__(self) -> str:
        return f"{{'traces': {_TRACES.value}}}"


TRACE_STATS = _TraceStatsView()


@dataclasses.dataclass(frozen=True)
class Operator:
    """One relational operator: a name + a traceable transform.

    ``fn(state) -> state`` where state is a pytree threaded through the
    stage.  ``breaker=True`` ends the pipeline stage after this operator
    (aggregate / partition / write in the paper's taxonomy).
    """

    name: str
    fn: Callable[[Any], Any]
    breaker: bool = False


@dataclasses.dataclass
class StageReport:
    name: str
    operators: tuple[str, ...]
    seconds: float
    materialized_bytes: int
    devices: int = 1                 # devices the stage output spans (a
    #                                  shard_map'd stage materializes its
    #                                  boundary on every mesh device)


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "dtype"))


def ndevices(tree) -> int:
    """Device span of a stage's materialized output (1 off-mesh)."""
    n = 1
    for x in jax.tree_util.tree_leaves(tree):
        sharding = getattr(x, "sharding", None)
        device_set = getattr(sharding, "device_set", None)
        if device_set:
            n = max(n, len(device_set))
    return n


@dataclasses.dataclass
class Stage:
    """A maximal breaker-terminated run of operators, jitted as one unit."""

    name: str
    operators: Sequence[Operator]
    jit: bool = True

    def __post_init__(self):
        def fused(state):
            _TRACES.inc()
            for op in self.operators:
                state = op.fn(state)
            return state
        self._fn = jax.jit(fused) if self.jit else fused

    def run(self, state):
        t0 = time.perf_counter()
        # the kernel-launch leaf of the span tree: one span per stage
        # per batch, so Perfetto shows exactly which stage of which
        # batch the wall went to (name documented as the `stage:` prefix)
        with TRACER.span(f"stage:{self.name}"):
            out = self._fn(state)
            jax.block_until_ready(out)   # stage boundary materializes
        dt = time.perf_counter() - t0
        report = StageReport(
            name=self.name,
            operators=tuple(op.name for op in self.operators),
            seconds=dt,
            materialized_bytes=_nbytes(out),
            devices=ndevices(out),
        )
        return out, report


def split_into_stages(ops: Sequence[Operator], *, prefix: str = "stage",
                      jit: bool = True) -> list[Stage]:
    """Split an operator chain at breakers (the netsDB compiler rule)."""
    stages: list[Stage] = []
    current: list[Operator] = []
    for op in ops:
        current.append(op)
        if op.breaker:
            stages.append(Stage(f"{prefix}{len(stages)}:{op.name}",
                                tuple(current), jit=jit))
            current = []
    if current:
        stages.append(Stage(f"{prefix}{len(stages)}:{current[-1].name}",
                            tuple(current), jit=jit))
    return stages


def run_stages(stages: Sequence[Stage], state) -> tuple[Any, list[StageReport]]:
    reports = []
    for st in stages:
        state, rep = st.run(state)
        reports.append(rep)
    return state, reports
