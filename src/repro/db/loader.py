"""The EXTERNAL data path: parse + convert + transfer (what netsDB avoids).

Paper Sec. 4: every non-netsDB platform loads testing data from an external
store (PostgreSQL via ConnectorX, or LIBSVM files for Criteo) into the ML
runtime's format before inference; the paper measures this load/convert time
as part of end-to-end latency and finds it DOMINATES for small-model/
large-data and wide-data workloads.

Our boundary (DESIGN.md Sec. 3/6.4): host-side text parse (CSV = the tabular
PostgreSQL stand-in, LIBSVM = the sparse path, array-typed rows = the Epsilon
path) → dtype/layout conversion → host→device transfer.  Every stage is
timed separately so benchmarks reproduce the paper's latency-breakdown
figures (Fig. 4/5/6/7).

Also here: synthetic generators for the paper's dataset grid (Tab. 1),
shape-faithful but scale-parameterized so benchmarks run on CPU.

Out-of-core ingest: ``load_libsvm_csr_external`` accepts ``tier=`` so a
criteo-scale file can parse straight onto any rung of the store's tier
ladder — ``"host"`` (page-aligned numpy, no device transfer) or
``"disk"`` (page-aligned mmap files; scan-time residency bounded by the
batch, though the parse itself still holds the CSR arrays in host RAM
once) — with ``transfer_s == 0``; ``store.put_sparse(pages=...)`` then
registers the result zero-copy.  See ``db/store.py`` and
``docs/architecture.md`` §1.
"""

from __future__ import annotations

import dataclasses
import io
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import METRICS, TRACER

__all__ = [
    "LoadTiming",
    "DATASETS",
    "synth_dataset",
    "write_csv",
    "load_csv_external",
    "write_libsvm",
    "load_libsvm_external",
    "load_libsvm_csr_external",
    "write_array_rows",
    "load_array_rows_external",
]


@dataclasses.dataclass
class LoadTiming:
    parse_s: float = 0.0       # text -> host arrays
    convert_s: float = 0.0     # layout/dtype conversion (e.g. array-col -> matrix)
    transfer_s: float = 0.0    # host -> device
    total_s: float = 0.0


def _guarded_transfer(fn, *, injector=None, retry_policy=None):
    """Run one host->device transfer through the ``page_dma_in`` fault
    site under a retry policy (``db/faults.py``) — the loaders' leg of
    the reliability layer.  The default (no injector, no policy) is a
    direct call, so the measured transfer timings are untouched."""
    if injector is None and retry_policy is None:
        return fn()
    from repro.db.faults import RetryPolicy
    policy = retry_policy if retry_policy is not None else RetryPolicy()
    return policy.run(fn, site="page_dma_in", injector=injector)


# ---------------------------------------------------------------------------
# Synthetic replicas of the paper's Tab. 1 grid (scale-parameterized).
# `rows` are the full-size row counts; benchmarks pass a scale factor.
# ---------------------------------------------------------------------------

DATASETS = {
    # name: (rows, features, task, nan_fraction, kind)
    "epsilon": (100_000, 2000, "classification", 0.0, "wide-dense"),
    "fraud": (285_000, 28, "classification", 0.0, "narrow-dense"),
    "year": (515_000, 90, "regression", 0.0, "narrow-dense"),
    "bosch": (1_184_000, 968, "classification", 0.81, "wide-sparse"),
    "higgs": (11_000_000, 28, "classification", 0.0, "narrow-dense"),
    "criteo": (51_000_000, 10_000, "classification", 0.96, "sparse-libsvm"),
    "airline": (115_000_000, 13, "classification", 0.0, "narrow-dense"),
    "tpcxai": (131_000_000, 7, "classification", 0.0, "narrow-dense"),
}


def synth_dataset(name: str, *, scale: float = 1.0, seed: int = 0,
                  max_rows: int | None = None):
    """Generate (x [N, F] float32 w/ NaNs, y [N]) mirroring Tab. 1 shapes.

    Criteo's 1M one-hot features are scale-reduced (10k) but stay extremely
    sparse — the claim under test (sparse format shrinks transfer) is about
    density, not the absolute feature count.
    """
    rows, F, task, nan_frac, kind = DATASETS[name]
    n = int(rows * scale)
    if max_rows is not None:
        n = min(n, max_rows)
    rng = np.random.default_rng(seed + hash(name) % 2**16)
    x = rng.normal(size=(n, F)).astype(np.float32)
    w = rng.normal(size=(min(F, 16),)).astype(np.float32)
    signal = x[:, : w.size] @ w
    if task == "regression":
        y = signal + 0.1 * rng.normal(size=n).astype(np.float32)
    else:
        y = (signal > 0).astype(np.float32)
    if nan_frac > 0:
        x[rng.random((n, F)) < nan_frac] = np.nan
    return x, y


# ---------------------------------------------------------------------------
# CSV (tabular PostgreSQL stand-in)
# ---------------------------------------------------------------------------


def write_csv(path: str, x: np.ndarray) -> None:
    np.savetxt(path, x, delimiter=",", fmt="%.6g")


def load_csv_external(path: str, *, device=None, dtype=jnp.float32,
                      injector=None, retry_policy=None):
    """Timed external load: parse CSV -> convert -> device transfer."""
    METRICS.counter("load.external_loads").inc()
    t0 = time.perf_counter()
    with TRACER.span("load.parse", format="csv"):
        host = np.loadtxt(path, delimiter=",", dtype=np.float64, ndmin=2)
    t1 = time.perf_counter()
    with TRACER.span("load.convert"):
        host32 = np.ascontiguousarray(host, dtype=np.float32)
    t2 = time.perf_counter()
    with TRACER.span("load.transfer"):
        dev = _guarded_transfer(
            lambda: jax.device_put(jnp.asarray(host32, dtype), device),
            injector=injector, retry_policy=retry_policy)
        dev.block_until_ready()
    t3 = time.perf_counter()
    return dev, LoadTiming(parse_s=t1 - t0, convert_s=t2 - t1,
                           transfer_s=t3 - t2, total_s=t3 - t0)


# ---------------------------------------------------------------------------
# LIBSVM (the sparse Criteo path)
# ---------------------------------------------------------------------------


def write_libsvm(path: str, x: np.ndarray, y: np.ndarray) -> None:
    """Rows as 'label idx:val idx:val ...' with NaN treated as missing."""
    with open(path, "w") as fh:
        for i in range(x.shape[0]):
            row = x[i]
            nz = np.flatnonzero(~np.isnan(row) & (row != 0.0))
            items = " ".join(f"{j}:{row[j]:.6g}" for j in nz)
            fh.write(f"{y[i]:g} {items}\n")


def _parse_libsvm(path: str):
    """Text -> host CSR lists (the parse stage both LIBSVM loaders share)."""
    indptr = [0]
    indices: list[int] = []
    values: list[float] = []
    labels: list[float] = []
    with open(path) as fh:
        for line in fh:
            parts = line.split()
            labels.append(float(parts[0]))
            for item in parts[1:]:
                j, v = item.split(":")
                indices.append(int(j))
                values.append(float(v))
            indptr.append(len(indices))
    return indptr, indices, values, labels


def load_libsvm_external(path: str, num_features: int, *, device=None,
                         dtype=jnp.float32, missing_as_nan: bool = True,
                         injector=None, retry_policy=None):
    """Timed sparse load: parse text -> CSR -> densify -> transfer.

    The densify step is the "conversion" the paper's Criteo/Bosch pipelines
    pay (sparse store format -> the dense blocks inference kernels want).
    This is the DENSE-FALLBACK baseline; ``load_libsvm_csr_external`` is
    the sparse data plane's path, which skips the densify entirely.
    """
    METRICS.counter("load.external_loads").inc()
    t0 = time.perf_counter()
    with TRACER.span("load.parse", format="libsvm"):
        indptr, indices, values, labels = _parse_libsvm(path)
        indptr_np = np.asarray(indptr, np.int64)
        indices_np = np.asarray(indices, np.int64)
        values_np = np.asarray(values, np.float32)
    t1 = time.perf_counter()
    with TRACER.span("load.convert", densify=True):
        n = len(labels)
        fill = np.nan if missing_as_nan else 0.0
        dense = np.full((n, num_features), fill, np.float32)
        rows = np.repeat(np.arange(n), np.diff(indptr_np))
        dense[rows, indices_np] = values_np
    t2 = time.perf_counter()
    with TRACER.span("load.transfer"):
        dev = _guarded_transfer(
            lambda: jax.device_put(jnp.asarray(dense, dtype), device),
            injector=injector, retry_policy=retry_policy)
        dev.block_until_ready()
    t3 = time.perf_counter()
    timing = LoadTiming(parse_s=t1 - t0, convert_s=t2 - t1,
                        transfer_s=t3 - t2, total_s=t3 - t0)
    return dev, np.asarray(labels, np.float32), timing


def load_libsvm_csr_external(path: str, num_features: int, *,
                             page_rows: int = 512, pages_multiple: int = 1,
                             tier: str = "device",
                             spill_dir: str | None = None,
                             injector=None, retry_policy=None):
    """Timed sparse load, SPARSE data plane: parse -> CSR pages -> transfer.

    Never materializes [N, F] on the host: parse builds host CSR lists,
    convert lays them out as fixed-capacity CSR page blocks
    (``db/sparse.paginate_csr`` — the layout the tensor-block store holds),
    and transfer ships indptr/indices/values only.  For criteo-density
    data that is a ~``1/density`` shrink of both the host working set and
    the host->device transfer, which is exactly the term the paper's
    sparse-storage claim is about.  Same LoadTiming contract as every
    other external loader.

    ``tier="host"`` skips the device transfer entirely (``transfer_s``
    records 0): criteo-scale files parse straight into page-aligned host
    CSR blocks, ready for ``store.put_sparse(pages=..., tier="host")``
    and the streaming scan executor — the out-of-core ingest path, with
    no device round-trip at load time.  ``tier="disk"`` goes one rung
    lower: the three page arrays are written to page-aligned
    memory-mapped files and handed back as lazy ``np.memmap`` views —
    ``store.put_sparse(pages=..., tier="disk")`` registers the maps
    zero-copy and the SCAN faults in only the pages each batch touches,
    so a file larger than both the device and host budgets streams
    through inference (the parse/convert stages themselves still hold
    the CSR arrays in host RAM once while writing the files).  The mmap
    writes are part of the CONVERT stage; ``transfer_s`` is 0 for both
    off-device tiers.

    Page-file lifecycle: the files are owned by the CALLER, not by the
    store (``put_sparse(pages=...)`` registers them zero-copy and will
    not delete them on ``drop``).  Pass ``spill_dir`` to control where
    they live; with ``spill_dir=None`` they land in a fresh
    ``tempfile.mkdtemp`` directory that persists until the OS cleans
    /tmp — each returned array's ``.filename`` attribute carries its
    path for manual cleanup.

    Returns (CSRPages on ``tier``, labels [N] np, LoadTiming).
    """
    from repro.db.sparse import CSRPages, paginate_csr
    from repro.db.store import mmap_array

    if tier not in ("device", "host", "disk"):
        raise ValueError(f"unknown tier {tier!r}")
    METRICS.counter("load.external_loads").inc()
    t0 = time.perf_counter()
    with TRACER.span("load.parse", format="libsvm-csr"):
        indptr, indices, values, labels = _parse_libsvm(path)
    t1 = time.perf_counter()
    with TRACER.span("load.convert", tier=tier):
        ip, ix, vl = paginate_csr(
            np.asarray(indptr, np.int64), np.asarray(indices, np.int32),
            np.asarray(values, np.float32), num_rows=len(labels),
            page_rows=page_rows, n_features=num_features,
            pages_multiple=pages_multiple)
        if tier == "disk":
            import tempfile
            d = spill_dir or tempfile.mkdtemp(prefix="libsvm-disk-")
            stem = os.path.splitext(os.path.basename(path))[0]
            ip, ix, vl = (mmap_array(os.path.join(d, f"{stem}.{lbl}.bin"), a)
                          for lbl, a in
                          (("indptr", ip), ("indices", ix), ("values", vl)))
    t2 = time.perf_counter()
    if tier in ("host", "disk"):
        pages = CSRPages(indptr=ip, indices=ix, values=vl,
                         n_features=int(num_features))
        t3 = t2               # no device transfer: transfer_s == 0
    else:
        with TRACER.span("load.transfer"):
            pages = _guarded_transfer(
                lambda: CSRPages(indptr=jnp.asarray(ip),
                                 indices=jnp.asarray(ix),
                                 values=jnp.asarray(vl),
                                 n_features=int(num_features)),
                injector=injector, retry_policy=retry_policy)
            jax.block_until_ready((pages.indptr, pages.indices,
                                   pages.values))
        t3 = time.perf_counter()
    timing = LoadTiming(parse_s=t1 - t0, convert_s=t2 - t1,
                        transfer_s=t3 - t2, total_s=t3 - t0)
    return pages, np.asarray(labels, np.float32), timing


# ---------------------------------------------------------------------------
# Array-typed rows (the Epsilon path: PostgreSQL array columns)
# ---------------------------------------------------------------------------


def write_array_rows(path: str, x: np.ndarray) -> None:
    """Each row as a '{v1,v2,...}' array literal — the PostgreSQL array-type
    storage the paper is forced into for >1600-column tables (Sec. 6.3.2)."""
    with open(path, "w") as fh:
        for row in x:
            fh.write("{" + ",".join(f"{v:.6g}" for v in row) + "}\n")


def load_array_rows_external(path: str, *, device=None, dtype=jnp.float32):
    """Timed array-column load; the expensive step is the per-row array
    parse + stack (the paper's 'converting a PostgreSQL array type back to
    a NumPy array ... becomes the bottleneck')."""
    METRICS.counter("load.external_loads").inc()
    t0 = time.perf_counter()
    with TRACER.span("load.parse", format="array-rows"):
        rows = []
        with open(path) as fh:
            for line in fh:
                rows.append(np.fromstring(line.strip()[1:-1], sep=","))
    t1 = time.perf_counter()
    with TRACER.span("load.convert"):
        host = np.stack(rows).astype(np.float32)
    t2 = time.perf_counter()
    with TRACER.span("load.transfer"):
        dev = jax.device_put(jnp.asarray(host, dtype), device)
        dev.block_until_ready()
    t3 = time.perf_counter()
    return dev, LoadTiming(parse_s=t1 - t0, convert_s=t2 - t1,
                           transfer_s=t3 - t2, total_s=t3 - t0)
