"""Streaming scan executor: ONE batch loop for every plan and tier.

The paper's headline scenario — in-database inference over datasets that
dwarf the model — only works because netsDB STREAMS page-partitioned
tensor blocks through the scan instead of requiring the whole table to be
resident (Sec. 3.1/6).  Our analogue: the tensor-block store grew a HOST
memory tier (``db/store.py``: page-aligned numpy blocks, spilled to
automatically when an ingest exceeds ``device_budget_bytes``), and this
module is the scan loop that pages those blocks through device memory.

``StreamingScanExecutor`` replaces the hand-rolled per-batch loop that
used to live inside ``ForestQueryEngine.infer``: every plan (udf / rel),
every storage format (dense rows / CSR pages), and every tier (device /
host) runs the SAME loop.  Sources implement the ``ScanSource`` protocol
(``page_slice`` + ``to_device``), so nothing downstream ever branches on
where the pages live.

The loop is a double-buffered DMA pipeline (``prefetch_depth=2``):

    batch i+1   pages in flight via async ``jax.device_put`` honoring the
                store's ``data_sharding`` (host tier; a no-op view on the
                device tier)
    batch i     runs its (shard_map-wrapped or mesh-less) fused kernel
                stages
    batch i-1   predictions drain (``copy_to_host_async``) into a
                preallocated host result buffer

At most ``MAX_IN_FLIGHT = 2`` device page buffers exist at any moment —
asserted on every acquire, and reported as ``ScanStats.max_in_flight``.

The preallocated result buffer also retires the jax-0.4.37 concatenate
workaround from the hot path: per-batch outputs are written into host
memory slot by slot, so the eager ``jnp.concatenate`` over PARTIALLY
replicated operands (which XLA:CPU miscompiles by summing replicas) never
runs.  ``tests/test_streaming.py`` keeps a pinned reproduction of the
miscompile so a future jax bump can delete the note entirely; the host
gather used here (per-shard copy + stitch) is not affected.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Iterator, Protocol, runtime_checkable

import jax
import numpy as np

from repro.db.operators import StageReport, run_stages

__all__ = ["ScanSource", "ScanStats", "StreamingScanExecutor",
           "MAX_IN_FLIGHT"]

#: hard ceiling on simultaneously live device page buffers: the one being
#: computed on plus the one in DMA flight.  The executor asserts it.
MAX_IN_FLIGHT = 2

#: default per-batch device footprint for HOST-tier scans when the store
#: has no ``device_budget_bytes``: an explicit host ingest must still
#: STREAM (a whole-dataset device_put would defeat the tier), so the
#: query engine caps the default batch at this many bytes per in-flight
#: buffer.
DEFAULT_STREAM_BATCH_BYTES = 64 << 20


@runtime_checkable
class ScanSource(Protocol):
    """What the executor needs from a stored dataset (any tier/format).

    Both ``StoredDataset`` and ``SparseStoredDataset`` implement this
    structurally — callers (the executor, the query engine) never branch
    on ``tier`` or ``storage_format``; the source's own ``page_slice`` /
    ``to_device`` encapsulate where pages live and how they reach the
    device.
    """

    name: str
    tier: str                        # "device" | "host"
    num_rows: int                    # true N (pre-padding)

    @property
    def num_pages(self) -> int: ...

    @property
    def page_rows(self) -> int: ...

    def page_slice(self, first_page: int, num_pages: int) -> Any:
        """Contiguous page range in the source's OWN tier (device view or
        host numpy view — views, not copies, on both tiers)."""
        ...

    def to_device(self, block: Any, sharding: Any = None) -> Any:
        """Stage a block onto device(s).  Host tier: an (async)
        ``jax.device_put`` honoring ``sharding``; device tier: identity
        (the no-op transfer stage)."""
        ...


@dataclasses.dataclass
class ScanStats:
    """Per-query streaming telemetry (attached to ``QueryResult.scan``)."""

    tier: str                        # source tier the scan ran against
    batches: int                     # page batches executed
    batch_pages: int                 # pages per (full) batch
    prefetch_depth: int              # 1 = synchronous, 2 = double-buffered
    max_in_flight: int = 0           # peak live device page buffers (<= 2)
    bytes_streamed: int = 0          # host->device bytes actually shipped
    transfer_issue_s: float = 0.0    # time spent ISSUING device_puts
    transfer_wait_s: float = 0.0     # EXPOSED wait for pages to be ready
    #                                  (what double-buffering hides)
    compute_s: float = 0.0           # kernel-stage wall time
    drain_s: float = 0.0             # device->host result-buffer writes
    wall_s: float = 0.0              # whole scan loop


@dataclasses.dataclass
class _InFlight:
    """One acquired batch: its page span + the (maybe mid-DMA) block."""

    index: int
    first_page: int
    num_pages: int
    block: Any


def _block_nbytes(block) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(block)
               if hasattr(x, "dtype"))


class StreamingScanExecutor:
    """Runs compiled plan stages over a ``ScanSource``, page batch by
    page batch, with double-buffered host->device paging.

    One instance per query execution; ``stages`` is the compiled stage
    list (``db/operators.Stage``) whose final state carries the per-batch
    predictions under ``result_key``.
    """

    def __init__(self, stages, *, sharding=None, prefetch_depth: int = 2,
                 result_key: str = "pred"):
        if not 1 <= prefetch_depth <= MAX_IN_FLIGHT:
            raise ValueError(
                f"prefetch_depth must be in [1, {MAX_IN_FLIGHT}], "
                f"got {prefetch_depth}")
        self.stages = stages
        self.sharding = sharding          # store.data_sharding() (or None)
        self.prefetch_depth = prefetch_depth
        self.result_key = result_key

    # -- batch plan ---------------------------------------------------------
    @staticmethod
    def batch_plan(num_pages: int, batch_pages: int
                   ) -> Iterator[tuple[int, int, int]]:
        """Deterministic (batch_index, first_page, num_pages) plan — the
        F3 batching loop AND the replay unit: batch k always covers the
        same pages, whatever tier they live on."""
        for k, first in enumerate(range(0, num_pages, batch_pages)):
            yield k, first, min(batch_pages, num_pages - first)

    # -- execution ----------------------------------------------------------
    def execute(self, source: ScanSource, batch_pages: int
                ) -> tuple[np.ndarray, list[StageReport], ScanStats]:
        """Stream every page batch of ``source`` through the stages.

        Returns (predictions [num_rows] host f32, per-batch stage
        reports, ScanStats).  Predictions land in a PREALLOCATED host
        buffer slot by slot — no concatenate anywhere on the hot path.
        """
        R = source.page_rows
        plan = list(self.batch_plan(source.num_pages, batch_pages))
        stats = ScanStats(tier=source.tier, batches=len(plan),
                          batch_pages=batch_pages,
                          prefetch_depth=self.prefetch_depth)
        reports: list[StageReport] = []
        result: np.ndarray | None = None   # allocated at first drain
        bufs: deque[_InFlight] = deque()   # acquired, not yet computed
        drains: deque = deque()            # computed, not yet written out
        live = 0                           # live device page buffers
        next_i = 0
        t_wall = time.perf_counter()

        def acquire():
            nonlocal live, next_i
            k, first, n = plan[next_i]
            next_i += 1
            block = source.page_slice(first, n)
            t0 = time.perf_counter()
            block = source.to_device(block, self.sharding)  # async DMA
            stats.transfer_issue_s += time.perf_counter() - t0
            if source.tier == "host":
                stats.bytes_streamed += _block_nbytes(block)
            live += 1
            stats.max_in_flight = max(stats.max_in_flight, live)
            assert live <= MAX_IN_FLIGHT, \
                f"{live} device page buffers in flight (max {MAX_IN_FLIGHT})"
            bufs.append(_InFlight(k, first, n, block))

        def drain(keep: int):
            nonlocal result
            while len(drains) > keep:
                first, n, pred = drains.popleft()
                t0 = time.perf_counter()
                host = np.asarray(pred)       # per-shard copy + stitch
                if result is None:
                    result = np.empty(source.num_pages * R, host.dtype)
                result[first * R:(first + n) * R] = host.reshape(-1)
                stats.drain_s += time.perf_counter() - t0

        while next_i < len(plan) or bufs:
            if not bufs:
                acquire()
            cur = bufs.popleft()
            # batch i+1: issue its page DMA while batch i computes
            while len(bufs) + 1 < self.prefetch_depth and next_i < len(plan):
                acquire()
            # batch i-1: drain while batch i's pages finish their DMA
            drain(keep=0)
            t0 = time.perf_counter()
            jax.block_until_ready(cur.block)
            stats.transfer_wait_s += time.perf_counter() - t0
            t0 = time.perf_counter()
            state, reps = run_stages(self.stages, {"x": cur.block})
            stats.compute_s += time.perf_counter() - t0
            reports.extend(reps)
            pred = state[self.result_key]
            if hasattr(pred, "copy_to_host_async"):
                pred.copy_to_host_async()     # overlap with the next batch
            drains.append((cur.first_page, cur.num_pages, pred))
            # release the page buffer NOW: some plans thread "x" through
            # to the final stage output, so dropping `state` (not just
            # cur.block) is what actually frees the device pages — else a
            # third buffer would be alive during the next prefetch
            state = None
            cur.block = None                  # at most 2 ever live
            live -= 1
        drain(keep=0)

        stats.wall_s = time.perf_counter() - t_wall
        assert result is not None, "scan produced no batches"
        return result[: source.num_rows], reports, stats
