"""Streaming scan executor: ONE batch loop for every plan and tier.

The paper's headline scenario — in-database inference over datasets that
dwarf the model — only works because netsDB STREAMS page-partitioned
tensor blocks through the scan instead of requiring the whole table to be
resident (Sec. 3.1/6).  Our analogue: the tensor-block store grew a HOST
memory tier (``db/store.py``: page-aligned numpy blocks, spilled to
automatically when an ingest exceeds ``device_budget_bytes``), and this
module is the scan loop that pages those blocks through device memory.

``StreamingScanExecutor`` replaces the hand-rolled per-batch loop that
used to live inside ``ForestQueryEngine.infer``: every plan (udf / rel),
every storage format (dense rows / CSR pages), and every tier (device /
host / disk) runs the SAME loop.  Sources implement the ``ScanSource``
protocol (``page_slice`` + ``to_device``), so nothing downstream ever
branches on where the pages live — a disk-tier source's ``page_slice``
is an ``np.memmap`` view, so its DMA reads straight off the file.

The loop is a double-buffered DMA pipeline (``prefetch_depth=2``) with a
TRULY asynchronous drain:

    batch i+1   pages in flight via async ``jax.device_put`` honoring the
                store's ``data_sharding`` (host/disk tiers; a no-op view
                on the device tier) — issued by the compute thread
    batch i     runs its (shard_map-wrapped or mesh-less) fused kernel
                stages on the compute thread
    batch i-1   predictions drain on a DEDICATED DRAIN WORKER THREAD:
                the compute thread issues ``copy_to_host_async`` and
                hands the prediction to the worker, which completes the
                D2H (through a pinned host staging buffer where the
                backend supports the ``pinned_host`` memory kind) and
                writes it into the preallocated host result buffer

Because the drain runs off the compute thread, batch i−1's D2H no longer
blocks batch i's kernel stages — on hardware with real DMA engines the
copy overlaps compute, and on XLA:CPU the (cheap) host writes still come
off the critical path.  ``ScanStats`` accounts it honestly:
``drain_s`` is the worker's total write time, ``drain_wait_s`` the time
the COMPUTE thread was actually blocked on the drain (queue backpressure
+ the final join), and ``drain_overlap_s`` their difference — the share
of drain work hidden behind compute.  ``prefetch_depth=1`` is the fully
synchronous reference pipeline (inline drain, no worker), which the
benchmarks use as the overlap baseline.

RELIABILITY (``db/faults.py``, ``docs/reliability.md``): every fallible
call in the loop is a named injection site wrapped in a bounded
``RetryPolicy``, and recovery that retries cannot buy degrades down a
ladder instead of failing hard:

  ``disk_page_read``   retries, then RE-ENQUEUES the batch once at the
                       end of the scan plan (slots are deterministic, so
                       order never matters), then raises ``ScanFault``;
  ``page_dma_in``      retries, then resubmits the batch at HALVED
                       ``batch_pages`` (aligned to the data-axis unit —
                       the OOM/transfer-fault ladder), down to one unit,
                       then raises ``ScanFault``;
  ``kernel_launch``    retries, then raises ``ScanFault``;
  ``drain_copy_out``   retries on the worker, then surfaces as a
                       ``ScanFault`` on the compute thread;
  ``drain_worker``     worker-thread DEATH: the submit path uses a
                       timeout-put that re-checks worker liveness (a
                       dead worker + full queue can no longer deadlock
                       the compute thread), recovers the worker's
                       orphaned items, and falls back MID-SCAN to the
                       synchronous ``prefetch_depth=1`` reference path —
                       which is bit-identical, so the fallback is
                       parity-safe (``degraded_to_sync``).

A ``Deadline`` makes the scan budgeted: checked between batches (and
before retry backoffs — cooperative, never preempting a jitted call),
an expired budget stops the scan with ``deadline_hit`` set and the rows
already drained intact; ``ForestQueryEngine.infer(deadline_s=...)``
turns that into a partial ``QueryResult`` with a ``DegradedReport``.
All of this lives in Python driver code between jitted calls — nothing
is traced, so the zero-fault path stays the compiled hot path
(measured in ``BENCH_faults.json``).

At most ``MAX_IN_FLIGHT = 2`` device page buffers exist at any moment —
asserted on every acquire, and reported as ``ScanStats.max_in_flight``.
The drain worker holds per-batch PREDICTIONS ([rows]-sized, not page
buffers), bounded by the queue, so the invariant is unaffected.

The preallocated result buffer also retires the jax-0.4.37 concatenate
workaround from the hot path: per-batch outputs are written into host
memory slot by slot, so the eager ``jnp.concatenate`` over PARTIALLY
replicated operands (which XLA:CPU miscompiles by summing replicas) never
runs.  ``tests/test_streaming.py`` keeps a pinned reproduction of the
miscompile so a future jax bump can delete the note entirely; the host
gather used here (per-shard copy + stitch) is not affected.

See ``docs/architecture.md`` (tier ladder, drain pipeline),
``docs/reliability.md`` (fault sites, ladders, deadline contract) and
``docs/benchmarks.md`` (how the stats surface in BENCH_stream.json /
BENCH_faults.json).
"""

from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
from collections import deque
from typing import Any, Iterator, Protocol, runtime_checkable

import jax
import numpy as np

from repro.db.faults import (Deadline, DeadlineExceeded, FaultInjector,
                             InjectedFault, RetryPolicy, ScanFault)
from repro.db.operators import StageReport, run_stages
from repro.obs import METRICS, TRACER

__all__ = ["ScanSource", "ScanStats", "StreamingScanExecutor",
           "MAX_IN_FLIGHT"]

#: hard ceiling on simultaneously live device page buffers: the one being
#: computed on plus the one in DMA flight.  The executor asserts it.
MAX_IN_FLIGHT = 2

#: default per-batch device footprint for HOST-tier scans when the store
#: has no ``device_budget_bytes``: an explicit host ingest must still
#: STREAM (a whole-dataset device_put would defeat the tier), so the
#: query engine caps the default batch at this many bytes per in-flight
#: buffer.
DEFAULT_STREAM_BATCH_BYTES = 64 << 20

#: how long one ``queue.put`` attempt blocks before the submit path
#: re-checks drain-worker liveness.  The put still wakes IMMEDIATELY
#: when the worker frees a slot (condition notify) — the timeout only
#: bounds how long a DEAD worker with a full queue can stall the
#: compute thread before the sync-drain fallback kicks in.
DRAIN_PUT_TIMEOUT_S = 0.05


@runtime_checkable
class ScanSource(Protocol):
    """What the executor needs from a stored dataset (any tier/format).

    Both ``StoredDataset`` and ``SparseStoredDataset`` implement this
    structurally — callers (the executor, the query engine) never branch
    on ``tier`` or ``storage_format``; the source's own ``page_slice`` /
    ``to_device`` encapsulate where pages live and how they reach the
    device.
    """

    name: str
    tier: str                        # "device" | "host" | "disk"
    num_rows: int                    # true N (pre-padding)

    @property
    def num_pages(self) -> int: ...

    @property
    def page_rows(self) -> int: ...

    def page_slice(self, first_page: int, num_pages: int) -> Any:
        """Contiguous page range in the source's OWN tier (device view,
        host numpy view, or disk mmap view — views, not copies, on every
        tier; a disk view faults in only the pages the batch touches)."""
        ...

    def to_device(self, block: Any, sharding: Any = None) -> Any:
        """Stage a block onto device(s).  Host/disk tiers: an (async)
        ``jax.device_put`` honoring ``sharding``; device tier: identity
        (the no-op transfer stage)."""
        ...


@dataclasses.dataclass
class ScanStats:
    """Per-query streaming telemetry (attached to ``QueryResult.scan``).

    Every field is documented, with its BENCH_stream.json /
    BENCH_faults.json counterpart, in ``docs/benchmarks.md``.
    """

    tier: str                        # source tier the scan ran against
    batches: int                     # page batches actually executed
    batch_pages: int                 # pages per (full) batch as planned
    prefetch_depth: int              # 1 = synchronous, 2 = double-buffered
    max_in_flight: int = 0           # peak live device page buffers (<= 2)
    bytes_streamed: int = 0          # off-device->device bytes shipped
    transfer_issue_s: float = 0.0    # time spent ISSUING device_puts
    transfer_wait_s: float = 0.0     # EXPOSED wait for pages to be ready
    #                                  (what double-buffering hides)
    compute_s: float = 0.0           # kernel-stage wall time
    drain_s: float = 0.0             # device->host result-buffer writes
    #                                  (on the WORKER thread when async)
    drain_wait_s: float = 0.0        # compute-thread time BLOCKED on the
    #                                  drain (backpressure + final join) —
    #                                  the drain's EXPOSED cost
    drain_async: bool = False        # drain ran on a dedicated worker
    pinned_staging: bool = False     # D2H staged through pinned host mem
    wall_s: float = 0.0              # whole scan loop
    # -- reliability accounting (docs/reliability.md) -----------------------
    retries: int = 0                 # retry re-attempts across all sites
    faults_injected: int = 0         # injector fires observed this scan
    degraded_to_sync: bool = False   # drain-worker death -> mid-scan
    #                                  fallback to the synchronous path
    batch_resubmits: int = 0         # batches re-enqueued (disk-read
    #                                  ladder) or resubmitted at halved
    #                                  size (device-transfer ladder)
    deadline_hit: bool = False       # scan stopped early on its deadline
    #                                  (the result is a PARTIAL)

    @property
    def drain_overlap_s(self) -> float:
        """Drain work hidden behind compute: worker write time minus the
        compute thread's exposed drain wait.  The inline drain (depth 1)
        charges every write to BOTH fields, so this is 0 there — only the
        async drain can hide work."""
        return max(0.0, self.drain_s - self.drain_wait_s)


@dataclasses.dataclass
class _InFlight:
    """One acquired batch: its page span + the (maybe mid-DMA) block."""

    index: int
    first_page: int
    num_pages: int
    block: Any


def _block_nbytes(block) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(block)
               if hasattr(x, "dtype"))


def _pinned_host_sharding():
    """A ``pinned_host`` single-device sharding when the backend has that
    memory kind (TPU/GPU — where D2H through pinned staging is a real DMA
    fast path), else None (XLA:CPU only exposes ``unpinned_host``)."""
    try:
        dev = jax.local_devices()[0]
        dev.memory("pinned_host")     # raises if the kind doesn't exist
        return jax.sharding.SingleDeviceSharding(
            dev, memory_kind="pinned_host")
    except Exception:
        return None


class _ResultSink:
    """The preallocated host result buffer + the drain that fills it.

    ``write`` completes one batch's D2H (optionally staging through a
    pinned host buffer) and stores the rows at their deterministic slot
    — retried under the ``drain_copy_out`` site (the slot write is
    idempotent, so a retried write is parity-safe).  ``drain_loop`` is
    the dedicated worker thread's body: it consumes (first_page,
    num_pages, prediction) items until the ``None`` sentinel, never
    letting one batch's failure wedge the queue (a write error is kept
    and re-raised on the compute thread; an injected ``drain_worker``
    fault models the THREAD dying — the worker parks the item it was
    holding in ``orphans`` and exits, and the compute thread recovers
    it through ``drain_pending`` when the sync fallback kicks in).
    """

    def __init__(self, total_rows: int, page_rows: int,
                 stats: ScanStats, pinned=None, *,
                 injector: FaultInjector | None = None,
                 policy: RetryPolicy | None = None,
                 track_mask: bool = False):
        self.total_rows = total_rows
        self.page_rows = page_rows
        self.stats = stats
        self.pinned = pinned
        self.injector = injector
        self.policy = policy
        self.result: np.ndarray | None = None    # allocated at first write
        self.error: BaseException | None = None
        self.dead = False                # drain_worker fault: thread died
        self.orphans: list = []          # items a dying worker parked
        self.rows_written = 0            # padded rows landed so far
        # which rows landed — only tracked for deadline-budgeted scans
        # (the partial result's DegradedReport needs the exact mask; the
        # unbudgeted hot path skips the bookkeeping)
        self.mask: np.ndarray | None = (
            np.zeros(total_rows, bool) if track_mask else None)

    def wants_pinned(self, pred) -> bool:
        """Pinned staging applies to single-device predictions only:
        sharded mesh outputs take the per-shard host gather instead."""
        return (self.pinned is not None
                and getattr(pred, "sharding", None) is not None
                and len(pred.sharding.device_set) == 1)

    def _write_once(self, first_page: int, num_pages: int, pred) -> None:
        t0 = time.perf_counter()
        if self.wants_pinned(pred):
            # D2H DMA into pinned staging; np.asarray of a pinned_host
            # array is then a cheap host-side view/copy.  This is the
            # ONLY transfer on this path — submit() skips the plain
            # copy_to_host_async for pinned-eligible predictions, else
            # every batch would pay the D2H twice.
            pred = jax.device_put(pred, self.pinned)
            self.stats.pinned_staging = True
        host = np.asarray(pred)                  # per-shard copy + stitch
        if self.result is None:
            fill = (np.full(self.total_rows, np.nan, host.dtype)
                    if self.mask is not None
                    else np.empty(self.total_rows, host.dtype))
            self.result = fill
        lo = first_page * self.page_rows
        hi = lo + num_pages * self.page_rows
        self.result[lo:hi] = host.reshape(-1)
        if self.mask is not None:
            self.mask[lo:hi] = True
        self.rows_written += hi - lo
        self.stats.drain_s += time.perf_counter() - t0

    def _count_retry(self):
        self.stats.retries += 1

    def write(self, first_page: int, num_pages: int, pred,
              parent=None) -> None:
        """One batch's drain, guarded at the ``drain_copy_out`` site.

        ``parent`` is the owning batch's span (captured on the COMPUTE
        thread): the drain worker's write span nests under it even
        though the two live on different threads — that cross-thread
        edge is what makes the async drain's overlap legible in the
        exported trace."""
        with TRACER.span("scan.drain_write", parent=parent,
                         first_page=first_page, num_pages=num_pages):
            if self.policy is None and self.injector is None:
                return self._write_once(first_page, num_pages, pred)
            if self.policy is None:
                self.injector.fire("drain_copy_out")
                return self._write_once(first_page, num_pages, pred)
            return self.policy.run(
                lambda: self._write_once(first_page, num_pages, pred),
                site="drain_copy_out", injector=self.injector,
                on_retry=self._count_retry)

    def drain_loop(self, q: queue_mod.Queue) -> None:
        while True:
            item = q.get()
            try:
                if item is None:
                    return
                if self.injector is not None:
                    try:
                        self.injector.fire("drain_worker")
                    except InjectedFault:
                        # the THREAD dies here (not a write error): park
                        # the item so the compute thread can recover it,
                        # then exit without draining the rest
                        self.dead = True
                        self.orphans.append(item)
                        return
                if self.error is None:           # fail fast, keep draining
                    try:
                        self.write(*item)
                    except BaseException as e:   # noqa: BLE001 — re-raised
                        self.error = e           # on the compute thread
            finally:
                q.task_done()

    def drain_pending(self, q: queue_mod.Queue | None) -> None:
        """Compute-thread recovery: write everything a dead worker left
        behind — its parked orphan plus any queued-but-unprocessed items
        (and swallow the stranded sentinel).  Idempotent and safe on a
        healthy shutdown (both lists empty)."""
        items = list(self.orphans)
        self.orphans = []
        if q is not None:
            while True:
                try:
                    items.append(q.get_nowait())
                except queue_mod.Empty:
                    break
        for it in items:
            if it is None or self.error is not None:
                continue
            try:
                self.write(*it)
            except BaseException as e:           # noqa: BLE001 — re-raised
                self.error = e                   # by the caller


class StreamingScanExecutor:
    """Runs compiled plan stages over a ``ScanSource``, page batch by
    page batch, with double-buffered host->device paging.

    One instance per query execution; ``stages`` is the compiled stage
    list (``db/operators.Stage``) whose final state carries the per-batch
    predictions under ``result_key``.

    ``injector`` / ``retry_policy`` / ``deadline`` opt the scan into the
    reliability layer (``db/faults.py``); all three default off, and the
    fault-free path with them off is byte-for-byte the old loop.
    ``min_batch_pages`` is the floor of the device-transfer halving
    ladder (the query engine passes the mesh data-axis unit so halved
    batches stay shard_map-divisible).
    """

    def __init__(self, stages, *, sharding=None, prefetch_depth: int = 2,
                 result_key: str | None = "pred",
                 injector: FaultInjector | None = None,
                 retry_policy: RetryPolicy | None = None,
                 deadline: Deadline | None = None,
                 min_batch_pages: int = 1):
        if not 1 <= prefetch_depth <= MAX_IN_FLIGHT:
            raise ValueError(
                f"prefetch_depth must be in [1, {MAX_IN_FLIGHT}], "
                f"got {prefetch_depth}")
        self.stages = stages
        self.sharding = sharding          # store.data_sharding() (or None)
        self.prefetch_depth = prefetch_depth
        self.result_key = result_key
        self.injector = injector
        # an armed injector with no explicit policy still recovers: the
        # default policy is the documented 3-attempt/backoff contract
        self.retry_policy = retry_policy if retry_policy is not None \
            else (RetryPolicy() if injector is not None else None)
        self.deadline = deadline
        self.min_batch_pages = max(1, int(min_batch_pages))
        # row mask of the last execute() when it hit its deadline (the
        # engine turns it into the DegradedReport); None otherwise
        self.last_mask: np.ndarray | None = None

    # -- batch plan ---------------------------------------------------------
    @staticmethod
    def batch_plan(num_pages: int, batch_pages: int
                   ) -> Iterator[tuple[int, int, int]]:
        """Deterministic (batch_index, first_page, num_pages) plan — the
        F3 batching loop AND the replay unit: batch k always covers the
        same pages, whatever tier they live on.  Under the fault ladders
        the plan is only ever REORDERED or SPLIT (re-enqueue, halving) —
        every page span still lands at its deterministic slot."""
        for k, first in enumerate(range(0, num_pages, batch_pages)):
            yield k, first, min(batch_pages, num_pages - first)

    # -- guarded sites ------------------------------------------------------
    def _guard(self, fn, site: str, stats: ScanStats):
        """Run ``fn`` at injection site ``site`` under the retry policy.
        The zero-instrumentation path (no injector, no policy) is a
        direct call — nothing on the hot path but this dispatch."""
        if self.retry_policy is None:
            if self.injector is not None:
                self.injector.fire(site)
            return fn()

        def count():
            stats.retries += 1

        return self.retry_policy.run(fn, site=site, injector=self.injector,
                                     on_retry=count, deadline=self.deadline)

    @property
    def _retryable(self) -> tuple:
        return (self.retry_policy.retryable if self.retry_policy is not None
                else (InjectedFault, OSError))

    @property
    def _attempts(self) -> int:
        return (self.retry_policy.max_attempts
                if self.retry_policy is not None else 1)

    # -- execution ----------------------------------------------------------
    def execute(self, source: ScanSource, batch_pages: int, *,
                extras=None, on_batch=None
                ) -> tuple[np.ndarray | None, list[StageReport], ScanStats]:
        """Stream every page batch of ``source`` through the stages.

        Returns (predictions [num_rows] host f32, per-batch stage
        reports, ScanStats).  Predictions land in a PREALLOCATED host
        buffer slot by slot — no concatenate anywhere on the hot path.
        With ``prefetch_depth=2`` the buffer is filled by a dedicated
        drain worker thread, so batch i−1's D2H never blocks batch i's
        kernel stages; depth 1 drains inline (the synchronous reference).

        Two hooks open the loop to REDUCTION scans (the in-database
        trainer, ``db/train.py``); both default off and cost nothing when
        unused:

          * ``extras(first_page, num_pages) -> dict`` — per-batch extra
            stage inputs, merged into the initial stage state next to
            ``"x"`` (the trainer feeds each batch's slice of the node-of
            relation to the routing stage this way);
          * ``on_batch(first_page, num_pages, state)`` — called on the
            compute thread right after the stages, BEFORE the drain
            submit, in plan order (the trainer accumulates its gradient
            histograms here).  On an injector-free scan the plan is never
            reordered or split, so the hook sees every batch exactly once
            in global row order — order-sensitive reductions must run
            with the reliability ladders off.

        A scan whose only product flows through ``on_batch`` can pass
        ``result_key=None`` to the constructor: the drain (worker thread,
        result buffer, D2H) is skipped entirely and ``execute`` returns
        ``None`` predictions.

        Failure semantics: transient faults at the injection sites are
        retried and degraded down the ladders (see the module
        docstring); recovery is bit-identical.  Exhausted ladders raise
        a structured ``ScanFault``; an expired ``deadline`` returns the
        PARTIAL buffer with ``stats.deadline_hit`` set and
        ``self.last_mask`` marking the rows that landed.
        """
        R = source.page_rows
        # (first_page, num_pages) spans; a deque because the fault
        # ladders re-enqueue (append) and split (appendleft) mid-scan
        pending: deque[tuple[int, int]] = deque(
            (first, n) for _, first, n in
            self.batch_plan(source.num_pages, batch_pages))
        n_planned = len(pending)
        stats = ScanStats(tier=source.tier, batches=0,
                          batch_pages=batch_pages,
                          prefetch_depth=self.prefetch_depth)
        reports: list[StageReport] = []
        bufs: deque[_InFlight] = deque()   # acquired, not yet computed
        live = 0                           # live device page buffers
        batch_idx = 0
        resubmitted: set[tuple[int, int]] = set()   # disk-ladder once-only
        fired0 = self.injector.total_fired if self.injector else 0
        deadline = self.deadline
        retryable = self._retryable
        t_wall = time.perf_counter()

        # the async drain rides with double-buffering; depth 1 keeps the
        # drain inline as the fully synchronous reference pipeline
        # (drainless reduction scans skip the worker entirely)
        async_drain = (self.prefetch_depth >= 2 and n_planned > 1
                       and self.result_key is not None)
        # effective depth can DEGRADE mid-scan (drain-worker death ->
        # the synchronous reference path); the stats keep the requested
        # depth and flag the degradation separately
        depth = self.prefetch_depth
        sink = _ResultSink(source.num_pages * R, R, stats,
                           pinned=_pinned_host_sharding(),
                           injector=self.injector,
                           policy=self.retry_policy,
                           track_mask=deadline is not None)
        drain_q: queue_mod.Queue | None = None
        worker: threading.Thread | None = None
        drain_active = False
        if async_drain:
            stats.drain_async = True
            # bounded: backpressure caps how many [rows]-sized prediction
            # arrays (NOT page buffers) the drain can hold behind compute
            drain_q = queue_mod.Queue(maxsize=MAX_IN_FLIGHT)
            worker = threading.Thread(target=sink.drain_loop,
                                      args=(drain_q,),
                                      name="scan-drain", daemon=True)
            worker.start()
            drain_active = True

        def put_drain(item) -> bool:
            """Timeout-put that re-checks worker liveness: a dead worker
            with a full queue can no longer wedge the compute thread in
            a blocking ``put`` forever (the latent deadlock).  Returns
            False when the worker is dead — the caller degrades to the
            synchronous drain."""
            while True:
                if sink.dead or not worker.is_alive():
                    return False
                try:
                    drain_q.put(item, timeout=DRAIN_PUT_TIMEOUT_S)
                    return True
                except queue_mod.Full:
                    continue

        def degrade_to_sync():
            """Drain-worker death ladder: recover the worker's orphaned
            items on the compute thread and continue as the synchronous
            ``prefetch_depth=1`` reference path — bit-identical, so the
            mid-scan switch is parity-safe."""
            nonlocal drain_active, depth
            drain_active = False
            depth = 1
            stats.degraded_to_sync = True
            TRACER.event("degrade.sync_drain")
            worker.join(timeout=5.0)
            sink.drain_pending(drain_q)

        def try_acquire() -> bool:
            """Acquire the next pending span through the disk-read and
            device-transfer sites.  Returns False when a fault ladder
            consumed the attempt (the span was re-enqueued or split) —
            the caller just loops."""
            nonlocal live
            first, n = pending[0]
            try:
                if source.tier == "disk":
                    with TRACER.span("scan.disk_read", first_page=first,
                                     num_pages=n):
                        block = self._guard(
                            lambda: source.page_slice(first, n),
                            "disk_page_read", stats)
                else:
                    block = source.page_slice(first, n)
            except DeadlineExceeded:
                raise
            except retryable as e:
                # disk-read ladder: re-enqueue the batch ONCE at the end
                # of the plan (deterministic slots: order is irrelevant),
                # then fail structured
                pending.popleft()
                if (first, n) not in resubmitted:
                    resubmitted.add((first, n))
                    pending.append((first, n))
                    stats.batch_resubmits += 1
                    TRACER.event("batch.resubmit", site="disk_page_read",
                                 first_page=first, num_pages=n)
                    return False
                raise ScanFault("disk_page_read",
                                attempts=2 * self._attempts,
                                rows_completed=min(sink.rows_written,
                                                   source.num_rows),
                                cause=e) from e
            t0 = time.perf_counter()
            try:
                with TRACER.span("scan.dma_in", first_page=first,
                                 num_pages=n):
                    block = self._guard(
                        lambda: source.to_device(block, self.sharding),
                        "page_dma_in", stats)         # async DMA
            except DeadlineExceeded:
                raise
            except retryable as e:
                # device-transfer ladder: resubmit at HALVED batch size
                # (aligned to the data-axis unit) before erroring — the
                # OOM answer: two half-batches fit where one batch faulted
                unit = self.min_batch_pages
                pending.popleft()
                if n > unit:
                    n1 = max(unit, (n // 2) // unit * unit)
                    pending.appendleft((first + n1, n - n1))
                    pending.appendleft((first, n1))
                    stats.batch_resubmits += 1
                    TRACER.event("batch.resubmit", site="page_dma_in",
                                 first_page=first, num_pages=n)
                    return False
                raise ScanFault("page_dma_in", attempts=self._attempts,
                                rows_completed=min(sink.rows_written,
                                                   source.num_rows),
                                cause=e) from e
            stats.transfer_issue_s += time.perf_counter() - t0
            pending.popleft()
            if source.tier != "device":
                stats.bytes_streamed += _block_nbytes(block)
            live += 1
            stats.max_in_flight = max(stats.max_in_flight, live)
            assert live <= MAX_IN_FLIGHT, \
                f"{live} device page buffers in flight (max {MAX_IN_FLIGHT})"
            bufs.append(_InFlight(len(resubmitted) + live, first, n, block))
            return True

        def submit(first: int, n: int, pred, batch_span=None):
            """Hand batch i's prediction to the drain.  The D2H copy is
            issued async HERE (on the compute thread) so it progresses
            while the worker is busy; the worker completes and writes it.
            Pinned-eligible predictions skip the plain async copy — their
            one and only D2H is the worker's device_put into pinned
            staging (two transfers would waste the DMA bandwidth the
            pinned path exists to save).  ``batch_span`` rides the queue
            item so the drain worker's ``scan.drain_write`` span nests
            under the owning batch even across the thread hop."""
            if not sink.wants_pinned(pred) \
                    and hasattr(pred, "copy_to_host_async"):
                pred.copy_to_host_async()
            t0 = time.perf_counter()
            with TRACER.span("scan.drain_submit", first_page=first,
                             num_pages=n):
                if drain_active:
                    if put_drain((first, n, pred, batch_span)):
                        stats.drain_wait_s += time.perf_counter() - t0
                        return
                    degrade_to_sync()    # dead worker: recover + go sync
                try:
                    sink.write(first, n, pred, batch_span)
                except retryable as e:
                    raise ScanFault("drain_copy_out",
                                    attempts=self._attempts,
                                    rows_completed=min(sink.rows_written,
                                                       source.num_rows),
                                    cause=e) from e
                finally:
                    stats.drain_wait_s += time.perf_counter() - t0

        # one span per execute(); everything the loop does — dma-in,
        # per-batch compute, the drain worker's cross-thread writes —
        # nests under it, so one exported trace IS the scan timeline
        with TRACER.span("scan.execute", tier=source.tier,
                         batch_pages=batch_pages,
                         prefetch_depth=self.prefetch_depth) as scan_span:
            try:
                while pending or bufs:
                    if sink.error is not None:
                        break             # a drained batch already
                    #                       failed: don't pay for the
                    #                       rest of the scan first
                    if deadline is not None and deadline.expired:
                        stats.deadline_hit = True
                        TRACER.event("deadline.hit")
                        break             # budget spent: keep what landed
                    try:
                        if not bufs:
                            if not try_acquire():
                                continue  # ladder adjusted the plan
                        cur = bufs.popleft()
                        # batch i+1: issue its page DMA while batch i
                        # computes.  The prefetch acquire runs BEFORE the
                        # batch span opens so next-batch scan.dma_in spans
                        # parent to scan.execute, not to a batch they
                        # don't belong to.
                        while len(bufs) + 1 < depth and pending:
                            if not try_acquire():
                                break     # ladder adjusted the plan
                        with TRACER.span("scan.batch", index=batch_idx,
                                         first_page=cur.first_page,
                                         num_pages=cur.num_pages
                                         ) as batch_span:
                            t0 = time.perf_counter()
                            with TRACER.span("scan.transfer_wait"):
                                jax.block_until_ready(cur.block)
                            stats.transfer_wait_s += \
                                time.perf_counter() - t0
                            t0 = time.perf_counter()
                            init_state = {"x": cur.block}
                            if extras is not None:
                                init_state.update(
                                    extras(cur.first_page, cur.num_pages))
                            try:
                                with TRACER.span("scan.compute"):
                                    state, reps = self._guard(
                                        lambda: run_stages(
                                            self.stages, init_state),
                                        "kernel_launch", stats)
                            except retryable as e:
                                raise ScanFault(
                                    "kernel_launch",
                                    attempts=self._attempts,
                                    rows_completed=min(sink.rows_written,
                                                       source.num_rows),
                                    cause=e) from e
                            stats.compute_s += time.perf_counter() - t0
                            reports.extend(reps)
                            stats.batches += 1
                            batch_idx += 1
                            if on_batch is not None:
                                on_batch(cur.first_page, cur.num_pages,
                                         state)
                            if self.result_key is not None:
                                submit(cur.first_page, cur.num_pages,
                                       state[self.result_key], batch_span)
                        # release the page buffer NOW: some plans thread
                        # "x" through to the final stage output, so
                        # dropping `state` (not just cur.block) is what
                        # actually frees the device pages — else a third
                        # buffer would be alive during the next prefetch
                        state = None
                        cur.block = None          # at most 2 ever live
                        live -= 1
                    except DeadlineExceeded:
                        # budget expired inside a retry loop: same
                        # graceful exit as the between-batches check
                        stats.deadline_hit = True
                        TRACER.event("deadline.hit")
                        break
            finally:
                # shut the worker down on EVERY exit: a failing stage
                # (or the in-flight assert) must not strand the daemon
                # thread in q.get() pinning the result buffer for the
                # process lifetime.  put_drain (not a blocking put) so a
                # dead worker + full queue cannot deadlock the shutdown
                # either; drain_pending then recovers anything a dead
                # worker left behind.
                if async_drain:
                    t0 = time.perf_counter()
                    if drain_active:
                        put_drain(None)   # sentinel: no more batches
                    worker.join(timeout=5.0)
                    if sink.dead:
                        stats.degraded_to_sync = True
                    sink.drain_pending(drain_q)
                    stats.drain_wait_s += time.perf_counter() - t0
                scan_span.set(batches=stats.batches,
                              bytes_streamed=stats.bytes_streamed,
                              retries=stats.retries,
                              batch_resubmits=stats.batch_resubmits,
                              degraded_to_sync=stats.degraded_to_sync,
                              deadline_hit=stats.deadline_hit)
                # process-global rollups (docs/observability.md):
                # counted on every exit — a faulted scan still counts
                METRICS.counter("scan.batches").inc(stats.batches)
                METRICS.counter("scan.bytes_streamed").inc(
                    stats.bytes_streamed)
                METRICS.counter("scan.retries").inc(stats.retries)
                METRICS.counter("scan.batch_resubmits").inc(
                    stats.batch_resubmits)
                if stats.degraded_to_sync:
                    METRICS.counter("scan.degraded_to_sync").inc()
                if stats.deadline_hit:
                    METRICS.counter("scan.deadline_hits").inc()
        if self.injector is not None:
            stats.faults_injected = self.injector.total_fired - fired0
            METRICS.counter("scan.faults_injected").inc(
                stats.faults_injected)
        if sink.error is not None:
            e = sink.error
            if isinstance(e, retryable):
                raise ScanFault("drain_copy_out", attempts=self._attempts,
                                rows_completed=min(sink.rows_written,
                                                   source.num_rows),
                                cause=e) from e
            raise e

        stats.wall_s = time.perf_counter() - t_wall
        if self.result_key is None:   # drainless reduction scan
            self.last_mask = None
            return None, reports, stats
        if sink.result is None:
            assert stats.deadline_hit, "scan produced no batches"
            # deadline expired before the first batch landed: an all-NaN
            # partial (rows_scored == 0) is still the graceful contract
            sink.result = np.full(source.num_pages * R, np.nan, np.float32)
        self.last_mask = (sink.mask[: source.num_rows]
                          if stats.deadline_hit and sink.mask is not None
                          else None)
        return sink.result[: source.num_rows], reports, stats
