"""Streaming scan executor: ONE batch loop for every plan and tier.

The paper's headline scenario — in-database inference over datasets that
dwarf the model — only works because netsDB STREAMS page-partitioned
tensor blocks through the scan instead of requiring the whole table to be
resident (Sec. 3.1/6).  Our analogue: the tensor-block store grew a HOST
memory tier (``db/store.py``: page-aligned numpy blocks, spilled to
automatically when an ingest exceeds ``device_budget_bytes``), and this
module is the scan loop that pages those blocks through device memory.

``StreamingScanExecutor`` replaces the hand-rolled per-batch loop that
used to live inside ``ForestQueryEngine.infer``: every plan (udf / rel),
every storage format (dense rows / CSR pages), and every tier (device /
host / disk) runs the SAME loop.  Sources implement the ``ScanSource``
protocol (``page_slice`` + ``to_device``), so nothing downstream ever
branches on where the pages live — a disk-tier source's ``page_slice``
is an ``np.memmap`` view, so its DMA reads straight off the file.

The loop is a double-buffered DMA pipeline (``prefetch_depth=2``) with a
TRULY asynchronous drain:

    batch i+1   pages in flight via async ``jax.device_put`` honoring the
                store's ``data_sharding`` (host/disk tiers; a no-op view
                on the device tier) — issued by the compute thread
    batch i     runs its (shard_map-wrapped or mesh-less) fused kernel
                stages on the compute thread
    batch i-1   predictions drain on a DEDICATED DRAIN WORKER THREAD:
                the compute thread issues ``copy_to_host_async`` and
                hands the prediction to the worker, which completes the
                D2H (through a pinned host staging buffer where the
                backend supports the ``pinned_host`` memory kind) and
                writes it into the preallocated host result buffer

Because the drain runs off the compute thread, batch i−1's D2H no longer
blocks batch i's kernel stages — on hardware with real DMA engines the
copy overlaps compute, and on XLA:CPU the (cheap) host writes still come
off the critical path.  ``ScanStats`` accounts it honestly:
``drain_s`` is the worker's total write time, ``drain_wait_s`` the time
the COMPUTE thread was actually blocked on the drain (queue backpressure
+ the final join), and ``drain_overlap_s`` their difference — the share
of drain work hidden behind compute.  ``prefetch_depth=1`` is the fully
synchronous reference pipeline (inline drain, no worker), which the
benchmarks use as the overlap baseline.

At most ``MAX_IN_FLIGHT = 2`` device page buffers exist at any moment —
asserted on every acquire, and reported as ``ScanStats.max_in_flight``.
The drain worker holds per-batch PREDICTIONS ([rows]-sized, not page
buffers), bounded by the queue, so the invariant is unaffected.

The preallocated result buffer also retires the jax-0.4.37 concatenate
workaround from the hot path: per-batch outputs are written into host
memory slot by slot, so the eager ``jnp.concatenate`` over PARTIALLY
replicated operands (which XLA:CPU miscompiles by summing replicas) never
runs.  ``tests/test_streaming.py`` keeps a pinned reproduction of the
miscompile so a future jax bump can delete the note entirely; the host
gather used here (per-shard copy + stitch) is not affected.

See ``docs/architecture.md`` (tier ladder, drain pipeline) and
``docs/benchmarks.md`` (how the stats surface in BENCH_stream.json).
"""

from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
from collections import deque
from typing import Any, Iterator, Protocol, runtime_checkable

import jax
import numpy as np

from repro.db.operators import StageReport, run_stages

__all__ = ["ScanSource", "ScanStats", "StreamingScanExecutor",
           "MAX_IN_FLIGHT"]

#: hard ceiling on simultaneously live device page buffers: the one being
#: computed on plus the one in DMA flight.  The executor asserts it.
MAX_IN_FLIGHT = 2

#: default per-batch device footprint for HOST-tier scans when the store
#: has no ``device_budget_bytes``: an explicit host ingest must still
#: STREAM (a whole-dataset device_put would defeat the tier), so the
#: query engine caps the default batch at this many bytes per in-flight
#: buffer.
DEFAULT_STREAM_BATCH_BYTES = 64 << 20


@runtime_checkable
class ScanSource(Protocol):
    """What the executor needs from a stored dataset (any tier/format).

    Both ``StoredDataset`` and ``SparseStoredDataset`` implement this
    structurally — callers (the executor, the query engine) never branch
    on ``tier`` or ``storage_format``; the source's own ``page_slice`` /
    ``to_device`` encapsulate where pages live and how they reach the
    device.
    """

    name: str
    tier: str                        # "device" | "host" | "disk"
    num_rows: int                    # true N (pre-padding)

    @property
    def num_pages(self) -> int: ...

    @property
    def page_rows(self) -> int: ...

    def page_slice(self, first_page: int, num_pages: int) -> Any:
        """Contiguous page range in the source's OWN tier (device view,
        host numpy view, or disk mmap view — views, not copies, on every
        tier; a disk view faults in only the pages the batch touches)."""
        ...

    def to_device(self, block: Any, sharding: Any = None) -> Any:
        """Stage a block onto device(s).  Host/disk tiers: an (async)
        ``jax.device_put`` honoring ``sharding``; device tier: identity
        (the no-op transfer stage)."""
        ...


@dataclasses.dataclass
class ScanStats:
    """Per-query streaming telemetry (attached to ``QueryResult.scan``).

    Every field is documented, with its BENCH_stream.json counterpart,
    in ``docs/benchmarks.md``.
    """

    tier: str                        # source tier the scan ran against
    batches: int                     # page batches executed
    batch_pages: int                 # pages per (full) batch
    prefetch_depth: int              # 1 = synchronous, 2 = double-buffered
    max_in_flight: int = 0           # peak live device page buffers (<= 2)
    bytes_streamed: int = 0          # off-device->device bytes shipped
    transfer_issue_s: float = 0.0    # time spent ISSUING device_puts
    transfer_wait_s: float = 0.0     # EXPOSED wait for pages to be ready
    #                                  (what double-buffering hides)
    compute_s: float = 0.0           # kernel-stage wall time
    drain_s: float = 0.0             # device->host result-buffer writes
    #                                  (on the WORKER thread when async)
    drain_wait_s: float = 0.0        # compute-thread time BLOCKED on the
    #                                  drain (backpressure + final join) —
    #                                  the drain's EXPOSED cost
    drain_async: bool = False        # drain ran on a dedicated worker
    pinned_staging: bool = False     # D2H staged through pinned host mem
    wall_s: float = 0.0              # whole scan loop

    @property
    def drain_overlap_s(self) -> float:
        """Drain work hidden behind compute: worker write time minus the
        compute thread's exposed drain wait.  The inline drain (depth 1)
        charges every write to BOTH fields, so this is 0 there — only the
        async drain can hide work."""
        return max(0.0, self.drain_s - self.drain_wait_s)


@dataclasses.dataclass
class _InFlight:
    """One acquired batch: its page span + the (maybe mid-DMA) block."""

    index: int
    first_page: int
    num_pages: int
    block: Any


def _block_nbytes(block) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(block)
               if hasattr(x, "dtype"))


def _pinned_host_sharding():
    """A ``pinned_host`` single-device sharding when the backend has that
    memory kind (TPU/GPU — where D2H through pinned staging is a real DMA
    fast path), else None (XLA:CPU only exposes ``unpinned_host``)."""
    try:
        dev = jax.local_devices()[0]
        dev.memory("pinned_host")     # raises if the kind doesn't exist
        return jax.sharding.SingleDeviceSharding(
            dev, memory_kind="pinned_host")
    except Exception:
        return None


class _ResultSink:
    """The preallocated host result buffer + the drain that fills it.

    ``write`` completes one batch's D2H (optionally staging through a
    pinned host buffer) and stores the rows at their deterministic slot.
    ``drain_loop`` is the dedicated worker thread's body: it consumes
    (first_page, num_pages, prediction) items until the ``None`` sentinel,
    never letting one batch's failure wedge the queue (the error is kept
    and re-raised on the compute thread after the join).
    """

    def __init__(self, total_rows: int, page_rows: int,
                 stats: ScanStats, pinned=None):
        self.total_rows = total_rows
        self.page_rows = page_rows
        self.stats = stats
        self.pinned = pinned
        self.result: np.ndarray | None = None    # allocated at first write
        self.error: BaseException | None = None

    def wants_pinned(self, pred) -> bool:
        """Pinned staging applies to single-device predictions only:
        sharded mesh outputs take the per-shard host gather instead."""
        return (self.pinned is not None
                and getattr(pred, "sharding", None) is not None
                and len(pred.sharding.device_set) == 1)

    def write(self, first_page: int, num_pages: int, pred) -> None:
        t0 = time.perf_counter()
        if self.wants_pinned(pred):
            # D2H DMA into pinned staging; np.asarray of a pinned_host
            # array is then a cheap host-side view/copy.  This is the
            # ONLY transfer on this path — submit() skips the plain
            # copy_to_host_async for pinned-eligible predictions, else
            # every batch would pay the D2H twice.
            pred = jax.device_put(pred, self.pinned)
            self.stats.pinned_staging = True
        host = np.asarray(pred)                  # per-shard copy + stitch
        if self.result is None:
            self.result = np.empty(self.total_rows, host.dtype)
        lo = first_page * self.page_rows
        self.result[lo: lo + num_pages * self.page_rows] = host.reshape(-1)
        self.stats.drain_s += time.perf_counter() - t0

    def drain_loop(self, q: queue_mod.Queue) -> None:
        while True:
            item = q.get()
            try:
                if item is None:
                    return
                if self.error is None:           # fail fast, keep draining
                    try:
                        self.write(*item)
                    except BaseException as e:   # noqa: BLE001 — re-raised
                        self.error = e           # on the compute thread
            finally:
                q.task_done()


class StreamingScanExecutor:
    """Runs compiled plan stages over a ``ScanSource``, page batch by
    page batch, with double-buffered host->device paging.

    One instance per query execution; ``stages`` is the compiled stage
    list (``db/operators.Stage``) whose final state carries the per-batch
    predictions under ``result_key``.
    """

    def __init__(self, stages, *, sharding=None, prefetch_depth: int = 2,
                 result_key: str = "pred"):
        if not 1 <= prefetch_depth <= MAX_IN_FLIGHT:
            raise ValueError(
                f"prefetch_depth must be in [1, {MAX_IN_FLIGHT}], "
                f"got {prefetch_depth}")
        self.stages = stages
        self.sharding = sharding          # store.data_sharding() (or None)
        self.prefetch_depth = prefetch_depth
        self.result_key = result_key

    # -- batch plan ---------------------------------------------------------
    @staticmethod
    def batch_plan(num_pages: int, batch_pages: int
                   ) -> Iterator[tuple[int, int, int]]:
        """Deterministic (batch_index, first_page, num_pages) plan — the
        F3 batching loop AND the replay unit: batch k always covers the
        same pages, whatever tier they live on."""
        for k, first in enumerate(range(0, num_pages, batch_pages)):
            yield k, first, min(batch_pages, num_pages - first)

    # -- execution ----------------------------------------------------------
    def execute(self, source: ScanSource, batch_pages: int
                ) -> tuple[np.ndarray, list[StageReport], ScanStats]:
        """Stream every page batch of ``source`` through the stages.

        Returns (predictions [num_rows] host f32, per-batch stage
        reports, ScanStats).  Predictions land in a PREALLOCATED host
        buffer slot by slot — no concatenate anywhere on the hot path.
        With ``prefetch_depth=2`` the buffer is filled by a dedicated
        drain worker thread, so batch i−1's D2H never blocks batch i's
        kernel stages; depth 1 drains inline (the synchronous reference).
        """
        R = source.page_rows
        plan = list(self.batch_plan(source.num_pages, batch_pages))
        stats = ScanStats(tier=source.tier, batches=len(plan),
                          batch_pages=batch_pages,
                          prefetch_depth=self.prefetch_depth)
        reports: list[StageReport] = []
        bufs: deque[_InFlight] = deque()   # acquired, not yet computed
        live = 0                           # live device page buffers
        next_i = 0
        t_wall = time.perf_counter()

        # the async drain rides with double-buffering; depth 1 keeps the
        # drain inline as the fully synchronous reference pipeline
        async_drain = self.prefetch_depth >= 2 and len(plan) > 1
        sink = _ResultSink(source.num_pages * R, R, stats,
                           pinned=_pinned_host_sharding())
        drain_q: queue_mod.Queue | None = None
        worker: threading.Thread | None = None
        if async_drain:
            stats.drain_async = True
            # bounded: backpressure caps how many [rows]-sized prediction
            # arrays (NOT page buffers) the drain can hold behind compute
            drain_q = queue_mod.Queue(maxsize=MAX_IN_FLIGHT)
            worker = threading.Thread(target=sink.drain_loop,
                                      args=(drain_q,),
                                      name="scan-drain", daemon=True)
            worker.start()

        def acquire():
            nonlocal live, next_i
            k, first, n = plan[next_i]
            next_i += 1
            block = source.page_slice(first, n)
            t0 = time.perf_counter()
            block = source.to_device(block, self.sharding)  # async DMA
            stats.transfer_issue_s += time.perf_counter() - t0
            if source.tier != "device":
                stats.bytes_streamed += _block_nbytes(block)
            live += 1
            stats.max_in_flight = max(stats.max_in_flight, live)
            assert live <= MAX_IN_FLIGHT, \
                f"{live} device page buffers in flight (max {MAX_IN_FLIGHT})"
            bufs.append(_InFlight(k, first, n, block))

        def submit(first: int, n: int, pred):
            """Hand batch i's prediction to the drain.  The D2H copy is
            issued async HERE (on the compute thread) so it progresses
            while the worker is busy; the worker completes and writes it.
            Pinned-eligible predictions skip the plain async copy — their
            one and only D2H is the worker's device_put into pinned
            staging (two transfers would waste the DMA bandwidth the
            pinned path exists to save)."""
            if not sink.wants_pinned(pred) \
                    and hasattr(pred, "copy_to_host_async"):
                pred.copy_to_host_async()
            if async_drain:
                t0 = time.perf_counter()
                drain_q.put((first, n, pred))
                stats.drain_wait_s += time.perf_counter() - t0
            else:
                t0 = time.perf_counter()
                sink.write(first, n, pred)
                stats.drain_wait_s += time.perf_counter() - t0

        try:
            while next_i < len(plan) or bufs:
                if sink.error is not None:
                    break                     # a drained batch already
                #                               failed: don't pay for the
                #                               rest of the scan first
                if not bufs:
                    acquire()
                cur = bufs.popleft()
                # batch i+1: issue its page DMA while batch i computes
                while len(bufs) + 1 < self.prefetch_depth \
                        and next_i < len(plan):
                    acquire()
                t0 = time.perf_counter()
                jax.block_until_ready(cur.block)
                stats.transfer_wait_s += time.perf_counter() - t0
                t0 = time.perf_counter()
                state, reps = run_stages(self.stages, {"x": cur.block})
                stats.compute_s += time.perf_counter() - t0
                reports.extend(reps)
                submit(cur.first_page, cur.num_pages,
                       state[self.result_key])
                # release the page buffer NOW: some plans thread "x"
                # through to the final stage output, so dropping `state`
                # (not just cur.block) is what actually frees the device
                # pages — else a third buffer would be alive during the
                # next prefetch
                state = None
                cur.block = None              # at most 2 ever live
                live -= 1
        finally:
            # shut the worker down on EVERY exit: a failing stage (or
            # the in-flight assert) must not strand the daemon thread in
            # q.get() pinning the result buffer for the process lifetime
            if async_drain:
                t0 = time.perf_counter()
                drain_q.put(None)             # sentinel: no more batches
                worker.join()
                stats.drain_wait_s += time.perf_counter() - t0
        if async_drain and sink.error is not None:
            raise sink.error

        stats.wall_s = time.perf_counter() - t_wall
        assert sink.result is not None, "scan produced no batches"
        return sink.result[: source.num_rows], reports, stats
