"""Deterministic synthetic token pipeline.

Every batch is a pure function of (seed, step) — the property that makes
failure replay exact (DESIGN.md §8): a restarted worker regenerates byte-
identical batches for any step range, so checkpoint-restore at step k
continues the exact same data order with no shared state between hosts.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 50304
    batch: int = 8
    seq_len: int = 512


def synthetic_batch(dc: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Markov-ish synthetic tokens with learnable local structure (so loss
    actually goes down in the examples — pure uniform noise would not)."""
    rng = np.random.default_rng(dc.seed * 1_000_003 + step)
    B, S, V = dc.batch, dc.seq_len, dc.vocab_size
    # piecewise-repeating pattern: next token = (prev * a + b) % V on most
    # positions, with 10% noise
    a = 31, 17
    base = rng.integers(0, V, size=(B, 1))
    toks = [base]
    for _ in range(S):
        nxt = (toks[-1] * a[0] + a[1]) % V
        noise = rng.integers(0, V, size=(B, 1))
        mask = rng.random((B, 1)) < 0.1
        toks.append(np.where(mask, noise, nxt))
    seq = np.concatenate(toks, axis=1)
    return {"tokens": seq[:, :S].astype(np.int32),
            "labels": seq[:, 1:S + 1].astype(np.int32)}


def batch_for(cfg: ModelConfig, shape: ShapeConfig, step: int,
              *, seed: int = 0) -> dict[str, np.ndarray]:
    dc = DataConfig(seed=seed, vocab_size=cfg.vocab_size,
                    batch=shape.global_batch, seq_len=shape.seq_len)
    b = synthetic_batch(dc, step)
    if cfg.encoder_layers:
        rng = np.random.default_rng(seed * 7 + step)
        Sd = max(shape.seq_len // cfg.dec_len_ratio, 1)
        return {
            "frames": rng.normal(size=(shape.global_batch, shape.seq_len,
                                       cfg.d_model)).astype(np.float32),
            "tokens": b["tokens"][:, :Sd],
            "labels": b["labels"][:, :Sd],
        }
    return b
