"""Optimizers, from scratch (no optax dependency).

Two choices, selected per architecture by memory budget (DESIGN.md §5):

  adamw      fp32 master + 2 fp32 moments (14 bytes/param with bf16 compute
             copy) — the default for ≤ ~40B-param models on a 256-chip pod.
  adafactor  factored second moment (row+col statistics), NO first moment,
             params updated in-place in their stored dtype — ~2.01
             bytes/param of state; what makes llama4-maverick-400b fit a
             single v5e pod (16 GB/chip) at all.

State pytrees mirror the param tree so ``dist.sharding.param_specs`` shards
them identically (ZeRO-style optimizer-state sharding comes for free).
Gradient clipping is global-norm; both optimizers take the same
``(grads, state, params) -> (updates, state)`` interface.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any

__all__ = ["OptimizerConfig", "Optimizer", "make_optimizer",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"              # adamw | adafactor | sgd
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    decay_rate: float = 0.8          # beta2_t = 1 - t^-decay_rate
    epsilon1: float = 1e-30
    # schedule
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


@dataclasses.dataclass(frozen=True)
class Optimizer:
    cfg: OptimizerConfig
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params, jax.Array], tuple[Params, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum((step + 1.0) / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def _decayable(path) -> bool:
    """Weight decay only on matrices (not norms/biases/scalars)."""
    name = str(getattr(path[-1], "key", "")) if path else ""
    return name not in ("scale", "bias", "A_log", "D", "dt_bias")


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _adamw(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        f32 = lambda x: jnp.zeros(x.shape, jnp.float32)
        return {
            "mu": jax.tree_util.tree_map(f32, params),
            "nu": jax.tree_util.tree_map(f32, params),
            "master": jax.tree_util.tree_map(
                lambda x: x.astype(jnp.float32), params),
        }

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        lr = lr_schedule(cfg, step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - cfg.beta1 ** t
        c2 = 1.0 - cfg.beta2 ** t

        def one(path, g, mu, nu, master):
            g = g.astype(jnp.float32)
            mu = cfg.beta1 * mu + (1 - cfg.beta1) * g
            nu = cfg.beta2 * nu + (1 - cfg.beta2) * g * g
            upd = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
            if _decayable(path):
                upd = upd + cfg.weight_decay * master
            master = master - lr * upd
            return mu, nu, master

        flat = jax.tree_util.tree_map_with_path(
            one, grads, state["mu"], state["nu"], state["master"])
        mu = jax.tree_util.tree_map(lambda x: x[0], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree_util.tree_map(lambda x: x[1], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
        master = jax.tree_util.tree_map(lambda x: x[2], flat,
                                        is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree_util.tree_map(
            lambda m, p: m.astype(p.dtype), master, params)
        return new_params, {"mu": mu, "nu": nu, "master": master,
                            "gnorm": gnorm}

    return Optimizer(cfg, init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; state ~= params/row + params/col)
# ---------------------------------------------------------------------------


def _adafactor(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        def one(x):
            if x.ndim >= 2:
                # factor over the two largest dims; store row/col means
                return {"vr": jnp.zeros(x.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(x.shape[:-2] + x.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(x.shape, jnp.float32)}
        return {"v": jax.tree_util.tree_map(one, params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        lr = lr_schedule(cfg, step)
        t = step.astype(jnp.float32) + 1.0
        beta2t = 1.0 - jnp.power(t, -cfg.decay_rate)

        def one(path, g, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + cfg.epsilon1
            if g.ndim >= 2:
                vr = beta2t * v["vr"] + (1 - beta2t) * jnp.mean(g2, axis=-1)
                vc = beta2t * v["vc"] + (1 - beta2t) * jnp.mean(g2, axis=-2)
                vr_mean = jnp.mean(vr, axis=-1, keepdims=True)
                precond = (vr[..., None] / jnp.maximum(vr_mean[..., None],
                                                       cfg.epsilon1)
                           ) * vc[..., None, :]
                upd = g / jnp.sqrt(jnp.maximum(precond, cfg.epsilon1))
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta2t * v["v"] + (1 - beta2t) * g2
                upd = g / jnp.sqrt(jnp.maximum(vv, cfg.epsilon1))
                new_v = {"v": vv}
            # update clipping (Shazeer & Stern RMS rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms)
            pf = p.astype(jnp.float32)
            if _decayable(path):
                upd = upd + cfg.weight_decay * pf
            return (pf - lr * upd).astype(p.dtype), new_v

        flat = jax.tree_util.tree_map_with_path(
            lambda path, g, v, p: one(path, g, v, p),
            grads, state["v"], params,
            is_leaf=lambda x: isinstance(x, dict) and
            ("vr" in x or "v" in x))
        # the above maps over param leaves because grads drives the structure
        new_params = jax.tree_util.tree_map(
            lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(
            lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"v": new_v, "gnorm": gnorm}

    return Optimizer(cfg, init, update)


def _sgd(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        return {}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        lr = lr_schedule(cfg, step)
        new_params = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_params, {"gnorm": gnorm}

    return Optimizer(cfg, init, update)


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    if cfg.name == "adamw":
        return _adamw(cfg)
    if cfg.name == "adafactor":
        return _adafactor(cfg)
    if cfg.name == "sgd":
        return _sgd(cfg)
    raise ValueError(f"unknown optimizer {cfg.name!r}")
