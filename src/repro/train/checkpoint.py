"""Checkpointing with reshard-on-restore (elastic restart).

Design (DESIGN.md §5/§8):
  * save is SHARD-PARALLEL: each host writes the shards it owns (here: one
    process writes all, but the layout is per-shard files keyed by leaf
    path, so the multi-host generalization is a loop bound);
  * the manifest records the tree structure + shapes + dtypes + the step,
    NOT the mesh — restore reshards every leaf to the CURRENT mesh's specs,
    which is what makes restart-after-node-loss elastic: lose a pod, build
    a smaller mesh, restore, continue;
  * atomic: writes go to <dir>.tmp then rename, so a crash mid-save never
    corrupts the latest checkpoint;
  * with the deterministic data pipeline (train/data.py) a restore at step
    k replays batch k exactly → bit-identical continuation (tested in
    tests/test_checkpoint.py).
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.dist.sharding import param_specs

Params = Any

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[name] = leaf
    return flat


def save_checkpoint(ckpt_dir: str, state: Params, step: int) -> str:
    """Write state (any pytree of arrays) as <dir>/step_<k>/ shards."""
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = out + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    manifest = {"step": int(step), "leaves": {}}
    for name, leaf in flat.items():
        host = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), host)
        manifest["leaves"][name] = {
            "file": fname, "shape": list(host.shape),
            "dtype": str(host.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    if os.path.isdir(out):
        shutil.rmtree(out)
    os.replace(tmp, out)
    return out


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like: Params, *,
                       mesh: Mesh | None = None,
                       step: int | None = None) -> tuple[Params, int]:
    """Restore into the structure of ``like`` (a state pytree or its
    eval_shape), resharding every leaf onto ``mesh`` (the CURRENT mesh —
    possibly different from the one that saved).
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as fh:
        manifest = json.load(fh)

    specs = param_specs(like, mesh) if mesh is not None else None
    flat_specs = _flatten(specs) if specs is not None else {}
    flat_like = _flatten(like)

    leaves_by_name = {}
    for name, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(src, meta["file"]))
        want = flat_like.get(name)
        if want is not None and tuple(arr.shape) != tuple(want.shape):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs "
                f"model {tuple(want.shape)}")
        if want is not None:
            arr = arr.astype(want.dtype)
        if mesh is not None and name in flat_specs:
            arr = jax.device_put(arr, NamedSharding(mesh, flat_specs[name]))
        else:
            arr = jax.device_put(arr)
        leaves_by_name[name] = arr

    # rebuild the tree in `like`'s structure
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for path, _ in paths:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if name not in leaves_by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        ordered.append(leaves_by_name[name])
    return jax.tree_util.tree_unflatten(treedef, ordered), int(step)
