"""Fault-tolerant training loop: checkpoint/restart, failure injection,
elastic re-meshing, straggler mitigation.

At 1000+ nodes the mean time between node failures drops below the job
length, so the loop is structured around three invariants (DESIGN.md §8):

  1. deterministic data  — batch k is a pure function of (seed, k)
                           (train/data.py), so any restart replays exactly;
  2. atomic checkpoints  — save every ``ckpt_every`` steps, crash-safe
                           (train/checkpoint.py);
  3. elastic restore     — the restore path reshards onto whatever mesh
                           the restarted job has (fewer/more nodes).

Straggler mitigation: the step path is one jitted SPMD program — there is
no per-host work distribution to rebalance *within* a step; stragglers
appear as slow steps.  The loop keeps an EWMA of step time and flags
outliers (> ``straggler_factor`` × EWMA); the deployment hook
(``on_straggler``) is where a cluster manager would reschedule the slow
host.  ``FailureInjector`` drives the tests: it raises at a chosen step to
simulate a node loss, and the harness restarts on a different mesh and
verifies bit-identical continuation.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.train.checkpoint import restore_checkpoint, save_checkpoint

__all__ = ["FailureInjector", "TrainLoop", "LoopReport"]


class FailureInjector:
    """Raises RuntimeError at step ``fail_at`` (once)."""

    def __init__(self, fail_at: int | None = None):
        self.fail_at = fail_at
        self.fired = False

    def maybe_fail(self, step: int):
        if self.fail_at is not None and step == self.fail_at \
                and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class LoopReport:
    steps_run: int
    final_step: int
    losses: list[float]
    step_times: list[float]
    stragglers: list[int]
    restored_from: int | None = None


class TrainLoop:
    """Deterministic, restartable training loop."""

    def __init__(self, step_fn: Callable, batch_fn: Callable[[int], Any],
                 *, ckpt_dir: str | None = None, ckpt_every: int = 50,
                 straggler_factor: float = 3.0,
                 on_straggler: Callable[[int, float], None] | None = None,
                 injector: FailureInjector | None = None):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.on_straggler = on_straggler
        self.injector = injector

    def run(self, state: Any, num_steps: int, *,
            start_step: int | None = None) -> tuple[Any, LoopReport]:
        step = int(start_step if start_step is not None
                   else jax.device_get(state["step"]))
        losses, times, stragglers = [], [], []
        ewma = None
        end = step + num_steps
        while step < end:
            if self.injector is not None:
                self.injector.maybe_fail(step)
            batch = self.batch_fn(step)
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.perf_counter() - t0
            losses.append(loss)
            times.append(dt)
            # the first step includes XLA compile — exclude it from the EWMA
            if len(times) > 1:
                if ewma is not None and dt > self.straggler_factor * ewma \
                        and len(times) > 3:
                    stragglers.append(step)
                    if self.on_straggler:
                        self.on_straggler(step, dt)
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            step += 1
            if self.ckpt_dir and step % self.ckpt_every == 0:
                save_checkpoint(self.ckpt_dir, state, step)
        if self.ckpt_dir:
            save_checkpoint(self.ckpt_dir, state, step)
        return state, LoopReport(steps_run=num_steps, final_step=step,
                                 losses=losses, step_times=times,
                                 stragglers=stragglers)

    def restore(self, like: Any, *, mesh=None) -> tuple[Any, int]:
        """Elastic restart: reshard the latest checkpoint onto ``mesh``."""
        assert self.ckpt_dir is not None
        return restore_checkpoint(self.ckpt_dir, like, mesh=mesh)
