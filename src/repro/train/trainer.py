"""Train step assembly: loss -> grad -> (accumulate) -> clip -> update.

``make_train_step`` returns ONE jitted function over global arrays — no
host-side Python in the step path (DESIGN.md §8).  Microbatch gradient
accumulation runs as a ``lax.scan`` over the leading microbatch axis so
the HLO stays compact.  Optional cross-pod gradient compression (int8 +
error feedback) hooks in between accumulation and the optimizer — XLA's
all-reduce then moves 4× fewer bytes over the DCN 'pod' axis.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.sharding import (ShardingPlan, batch_specs, make_plan,
                                 param_specs, tree_named)
from repro.models.registry import ModelBundle, get_bundle
from repro.train.optimizer import Optimizer, OptimizerConfig, make_optimizer

Params = Any


@dataclasses.dataclass
class TrainState:
    params: Params
    opt: Any
    step: jax.Array

    def tree(self):
        return {"params": self.params, "opt": self.opt, "step": self.step}


def init_state(cfg: ModelConfig, opt: Optimizer, key,
               *, dtype=jnp.bfloat16) -> dict:
    bundle = get_bundle(cfg)
    params = bundle.init(cfg, key, dtype=dtype)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_shapes(cfg: ModelConfig, opt: Optimizer,
                 *, dtype=jnp.bfloat16) -> dict:
    """abstract state for the dry-run (no allocation)."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(partial(init_state, cfg, opt, dtype=dtype), key)


def make_train_step(cfg: ModelConfig, opt: Optimizer,
                    splan: ShardingPlan, *, microbatches: int = 1,
                    grad_compress: bool = False,
                    vocab_chunk: int = 16_384) -> Callable:
    """(state, batch) -> (state, metrics); pure, jit-able, mesh-aware."""
    bundle = get_bundle(cfg)

    def loss_fn(params, batch):
        return bundle.loss(cfg, params, batch, splan)

    def step_fn(state, batch):
        params = state["params"]
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(carry, mb):
                acc, = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc,), l

            zeros = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches) +
                                    x.shape[1:]), batch)
            (grads,), losses = jax.lax.scan(micro, (zeros,), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = jnp.mean(losses)

        if grad_compress and splan.mesh is not None and \
                "pod" in splan.mesh.axis_names:
            from repro.dist.compression import compress_grads_crosspod
            grads = compress_grads_crosspod(grads, splan.mesh)

        new_params, new_opt = opt.update(grads, state["opt"], params,
                                         state["step"])
        metrics = {"loss": loss, "gnorm": new_opt.pop("gnorm")}
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return step_fn


def jit_train_step(cfg: ModelConfig, opt: Optimizer, mesh: Mesh | None,
                   **kw):
    """jit with explicit in/out shardings derived from the plan."""
    splan = make_plan(cfg, mesh)
    step_fn = make_train_step(cfg, opt, splan, **kw)
    if mesh is None:
        return jax.jit(step_fn), splan

    abstract = state_shapes(cfg, opt)
    pspecs = param_specs(abstract["params"], mesh)
    ospecs = {"params": pspecs,
              "opt": param_specs(abstract["opt"], mesh),
              "step": P()}
    bspec_all = batch_specs(splan)
    in_shardings = (tree_named(mesh, ospecs), None)
    out_shardings = (tree_named(mesh, ospecs), None)
    jitted = jax.jit(step_fn, in_shardings=in_shardings,
                     out_shardings=out_shardings, donate_argnums=(0,))
    return jitted, splan
