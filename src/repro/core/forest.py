"""Dense complete-tree tensor encoding of a decision forest.

The paper's workloads use max_depth=8 trees (Sec. 4).  We adopt a *dense
complete binary tree* layout: every tree is embedded in a perfect binary
tree of depth ``depth`` using the classic heap indexing

    root = 0, children(i) = (2i+1, 2i+2)
    internal nodes: positions [0, 2^depth - 1)
    leaves:         positions [2^depth - 1, 2^(depth+1) - 1)

Trees whose real shape is smaller are *completed*: a premature leaf becomes
a pass-through internal node (threshold = +inf, default_left = True, so every
sample — including NaN — goes left) and its value is propagated to every
dense leaf below it.  This makes the traversal fixed-length and branch-free,
which is what the TPU VPU wants, and makes the HummingBird path matrix and
the QuickScorer bitvectors *structure-only* (identical for all trees of the
same depth) — see ``hb_path_matrix`` / ``qs_bitvectors``.

All per-tree arrays carry the tree dimension T in front, so the paper's
relation-centric *model parallelism* is literally "shard dim 0".
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Forest",
    "num_internal",
    "num_leaves",
    "make_forest",
    "complete_from_nodes",
    "hb_path_matrix",
    "qs_bitvectors",
    "pad_trees",
    "tree_slice",
    "used_feature_counts",
    "compact_forest",
]


def num_internal(depth: int) -> int:
    return (1 << depth) - 1


def num_leaves(depth: int) -> int:
    return 1 << depth


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Forest:
    """A forest of T depth-``depth`` complete binary trees.

    feature      int32  [T, I]  feature tested at each internal node
    threshold    f32    [T, I]  split threshold; x < t goes left
    default_left bool   [T, I]  where NaN inputs go
    leaf_value   f32    [T, L]  per-leaf raw score / class-1 probability
    node_is_leaf bool   [T, I]  True where the original tree had a leaf
    node_value   f32    [T, I]  value of that premature leaf (naive early exit)
    """

    feature: jax.Array
    threshold: jax.Array
    default_left: jax.Array
    leaf_value: jax.Array
    node_is_leaf: jax.Array
    node_value: jax.Array
    # --- static metadata -------------------------------------------------
    depth: int = dataclasses.field(metadata=dict(static=True), default=8)
    n_features: int = dataclasses.field(metadata=dict(static=True), default=0)
    model_type: str = dataclasses.field(metadata=dict(static=True), default="xgboost")
    task: str = dataclasses.field(metadata=dict(static=True), default="classification")
    base_score: float = dataclasses.field(metadata=dict(static=True), default=0.0)

    @property
    def num_trees(self) -> int:
        return self.feature.shape[0]

    @property
    def num_internal(self) -> int:
        return num_internal(self.depth)

    @property
    def num_leaves(self) -> int:
        return num_leaves(self.depth)

    def astype(self, dtype) -> "Forest":
        return dataclasses.replace(
            self,
            threshold=self.threshold.astype(dtype),
            leaf_value=self.leaf_value.astype(dtype),
            node_value=self.node_value.astype(dtype),
        )

    def arrays(self) -> dict[str, jax.Array]:
        return {
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(self)
            if not f.metadata.get("static", False)
        }


def make_forest(
    feature: np.ndarray,
    threshold: np.ndarray,
    leaf_value: np.ndarray,
    *,
    default_left: np.ndarray | None = None,
    node_is_leaf: np.ndarray | None = None,
    node_value: np.ndarray | None = None,
    n_features: int,
    model_type: str = "xgboost",
    task: str = "classification",
    base_score: float = 0.0,
) -> Forest:
    """Build a Forest from already-dense arrays (e.g. the in-JAX trainer)."""
    T, I = feature.shape
    depth = int(np.log2(I + 1))
    assert (1 << depth) - 1 == I, f"I={I} is not 2^d - 1"
    L = leaf_value.shape[1]
    assert L == 1 << depth
    if default_left is None:
        default_left = np.ones((T, I), dtype=bool)
    if node_is_leaf is None:
        node_is_leaf = np.zeros((T, I), dtype=bool)
    if node_value is None:
        node_value = np.zeros((T, I), dtype=np.float32)
    return Forest(
        feature=jnp.asarray(feature, jnp.int32),
        threshold=jnp.asarray(threshold, jnp.float32),
        default_left=jnp.asarray(default_left, bool),
        leaf_value=jnp.asarray(leaf_value, jnp.float32),
        node_is_leaf=jnp.asarray(node_is_leaf, bool),
        node_value=jnp.asarray(node_value, jnp.float32),
        depth=depth,
        n_features=int(n_features),
        model_type=model_type,
        task=task,
        base_score=float(base_score),
    )


# ---------------------------------------------------------------------------
# Conversion from a generic node-list model (the "external model import" path;
# this is what the paper's model-conversion benchmark, Fig. 8, measures).
# ---------------------------------------------------------------------------


def complete_from_nodes(
    trees: list[dict[str, np.ndarray]],
    *,
    depth: int,
    n_features: int,
    model_type: str = "xgboost",
    task: str = "classification",
    base_score: float = 0.0,
) -> Forest:
    """Convert sklearn-style node lists into the dense complete layout.

    Each tree dict has arrays ``children_left``, ``children_right``,
    ``feature``, ``threshold``, ``value`` (leaf score; ignored at internals),
    optionally ``default_left``; -1 children mean leaf.  Trees deeper than
    ``depth`` are rejected (the dense layout is the paper's depth-8 regime;
    deeper models use the jnp sparse path, see algorithms.naive_predict).
    """
    T = len(trees)
    I, L = num_internal(depth), num_leaves(depth)
    feature = np.zeros((T, I), np.int32)
    threshold = np.full((T, I), np.inf, np.float32)
    default_left = np.ones((T, I), bool)
    node_is_leaf = np.zeros((T, I), bool)
    node_value = np.zeros((T, I), np.float32)
    leaf_value = np.zeros((T, L), np.float32)

    for t, tr in enumerate(trees):
        cl, cr = tr["children_left"], tr["children_right"]
        feat, thr, val = tr["feature"], tr["threshold"], tr["value"]
        dl = tr.get("default_left")
        # BFS: (orig_node, dense_pos). A leaf reached at dense depth d < depth
        # turns into a pass-through chain; we propagate its value to all dense
        # leaves underneath in one go.
        stack = [(0, 0)]
        while stack:
            node, pos = stack.pop()
            d = int(np.floor(np.log2(pos + 1)))
            is_leaf = cl[node] < 0
            if is_leaf:
                if pos < I:
                    node_is_leaf[t, pos] = True
                    node_value[t, pos] = val[node]
                # all dense leaves under `pos`: leftmost descendant chain.
                lo = pos
                for _ in range(depth - d):
                    lo = 2 * lo + 1
                span = 1 << (depth - d)
                leaf_value[t, lo - I : lo - I + span] = val[node]
            else:
                if d >= depth:
                    raise ValueError(
                        f"tree {t} deeper than dense depth {depth}"
                    )
                feature[t, pos] = feat[node]
                threshold[t, pos] = thr[node]
                if dl is not None:
                    default_left[t, pos] = dl[node]
                stack.append((int(cl[node]), 2 * pos + 1))
                stack.append((int(cr[node]), 2 * pos + 2))

    return make_forest(
        feature,
        threshold,
        leaf_value,
        default_left=default_left,
        node_is_leaf=node_is_leaf,
        node_value=node_value,
        n_features=n_features,
        model_type=model_type,
        task=task,
        base_score=base_score,
    )


# ---------------------------------------------------------------------------
# Structure-only auxiliary tensors (shared across all trees of a depth).
# ---------------------------------------------------------------------------


def _leaf_ancestry(depth: int) -> tuple[np.ndarray, np.ndarray]:
    """For each leaf l: (ancestor internal positions [L, depth],
    went_left flags [L, depth])."""
    I, L = num_internal(depth), num_leaves(depth)
    anc = np.zeros((L, depth), np.int64)
    left = np.zeros((L, depth), bool)
    for l in range(L):
        pos = I + l
        for d in range(depth - 1, -1, -1):
            parent = (pos - 1) // 2
            anc[l, d] = parent
            left[l, d] = pos == 2 * parent + 1
            pos = parent
    return anc, left


def hb_path_matrix(depth: int) -> tuple[np.ndarray, np.ndarray]:
    """HummingBird tensors, structure-only for the complete layout.

    Returns (C [I, L] int8, D_count [L] int32) with the property: given the
    per-node predicate vector s (1 = x<t goes left), the exit leaf is the
    unique l with  (s @ C)[l] == D_count[l].
    """
    I, L = num_internal(depth), num_leaves(depth)
    anc, left = _leaf_ancestry(depth)
    C = np.zeros((I, L), np.int8)
    for l in range(L):
        for d in range(depth):
            C[anc[l, d], l] = 1 if left[l, d] else -1
    D_count = left.sum(axis=1).astype(np.int32)
    return C, D_count


def qs_bitvectors(depth: int) -> np.ndarray:
    """QuickScorer leaf bitvectors, structure-only for the complete layout.

    bv [I, W] uint32, W = ceil(L/32); leaf l maps to word l//32, bit l%32
    (LSB-first).  bv[i] has zeros exactly on the leaves of i's *left*
    subtree: AND-ing the bitvectors of all FALSE nodes (x >= t, i.e. the
    sample goes right) leaves the exit leaf as the lowest surviving bit
    (Lucchese et al., SIGIR'15).
    """
    I, L = num_internal(depth), num_leaves(depth)
    W = (L + 31) // 32
    anc, left = _leaf_ancestry(depth)
    bv = np.full((I, W), 0xFFFFFFFF, np.uint32)
    for l in range(L):
        for d in range(depth):
            if left[l, d]:
                i = anc[l, d]
                bv[i, l // 32] &= ~np.uint32(1 << (l % 32))
    return bv


# ---------------------------------------------------------------------------
# Used-feature compaction (the wide-sparse data plane's model half).
# ---------------------------------------------------------------------------
#
# A depth-d tree tests at most 2^d - 1 distinct features, so a forest over
# criteo-scale F touches only a tiny slice of the feature space (Yggdrasil
# DF's per-tree "used feature" compaction is the same observation).  We
# compact at FOREST granularity: remap every split's feature id into the
# sorted union of features the forest actually tests, and publish that
# union as a gather index table.  The inference contract is then
#
#     predict(forest, x)  ==  predict(compact, x[:, gather_idx])
#
# for every backend, because node n reads x_compact[inv[f_n]] =
# x[gather_idx[inv[f_n]]] = x[f_n].  The feature-gather prepass
# (kernels/gather.py) produces x_compact directly from CSR pages, so the
# kernels' in-VMEM one-hot shrinks from [BT, I, F] to [BT, I, F_used] —
# the difference between criteo-scale F being modeled and being real.
#
# Invariants (asserted by tests/test_sparse.py):
#   * gather_idx is sorted and duplicate-free over its first F_used slots
#     (padding slots repeat gather_idx[0] and are never referenced by any
#     remapped split);
#   * completed pass-through nodes (threshold == +inf) are excluded from
#     the used set — their feature slot is never read;
#   * per-tree used counts never exceed num_internal(depth).


def used_feature_counts(forest: Forest) -> np.ndarray:
    """[T] number of DISTINCT features each tree really tests.

    Pass-through completion nodes (threshold +inf) don't count: their
    predicate is constant.  This is the honest ``used_features`` bound for
    ``kernels.common.block_heuristics`` and the per-tree compaction stat.
    """
    feat = np.asarray(jax.device_get(forest.feature))
    real = np.isfinite(np.asarray(jax.device_get(forest.threshold)))
    return np.array([np.unique(feat[t][real[t]]).size
                     for t in range(feat.shape[0])], np.int64)


def compact_forest(forest: Forest, *, pad_to: int = 8
                   ) -> tuple[Forest, np.ndarray]:
    """Remap split features into the forest's used-feature union.

    Returns (compact forest with n_features = F_used padded to ``pad_to``,
    gather_idx [F_used_padded] int32).  ``x[:, gather_idx]`` (or the CSR
    gather prepass) produces the matching compact sample block.  Padding
    slots repeat gather_idx[0] so the index table stays valid for a plain
    column gather; no remapped split ever points at them.
    """
    feat = np.asarray(jax.device_get(forest.feature))
    real = np.isfinite(np.asarray(jax.device_get(forest.threshold)))
    used = np.unique(feat[real])
    if used.size == 0:
        used = np.zeros(1, feat.dtype)          # degenerate all-pass forest
    f_used = used.size
    pad = (-f_used) % max(pad_to, 1)
    gather_idx = np.concatenate(
        [used, np.full(pad, used[0], used.dtype)]).astype(np.int32)
    inv = np.zeros(forest.n_features, np.int32)
    inv[used] = np.arange(f_used, dtype=np.int32)
    # pass-through nodes keep whatever slot their (ignored) feature maps to
    remapped = inv[np.clip(feat, 0, forest.n_features - 1)]
    compact = dataclasses.replace(
        forest,
        feature=jnp.asarray(remapped, jnp.int32),
        n_features=int(gather_idx.size),
    )
    return compact, gather_idx


# ---------------------------------------------------------------------------
# Tree-dimension utilities (model parallelism / padding).
# ---------------------------------------------------------------------------


def pad_trees(forest: Forest, multiple: int) -> tuple[Forest, int]:
    """Pad the tree dimension to a multiple (identity trees: value 0).

    Padding trees are pass-through with all-zero leaves so SUM aggregation is
    unaffected; MEAN aggregation must divide by the *original* count, which
    the caller keeps (returned here).
    """
    T = forest.num_trees
    pad = (-T) % multiple
    if pad == 0:
        return forest, T

    def _pad(x, fill):
        widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, widths, constant_values=fill)

    return (
        dataclasses.replace(
            forest,
            feature=_pad(forest.feature, 0),
            threshold=_pad(forest.threshold, jnp.inf),
            default_left=_pad(forest.default_left, True),
            leaf_value=_pad(forest.leaf_value, 0.0),
            node_is_leaf=_pad(forest.node_is_leaf, True),
            node_value=_pad(forest.node_value, 0.0),
        ),
        T,
    )


def tree_slice(forest: Forest, start: int, size: int) -> Forest:
    """A contiguous tree partition (the relation-centric model partitioner)."""
    changes = {
        k: jax.lax.dynamic_slice_in_dim(v, start, size, axis=0)
        for k, v in forest.arrays().items()
    }
    return dataclasses.replace(forest, **changes)
