"""In-JAX decision-forest TRAINING (the substrate the paper outsources).

The paper trains every model with scikit-learn / the XGBoost & LightGBM C
libraries (Sec. 4) and only benchmarks inference.  We build the trainer
in-framework so the system is self-contained: one histogram-based, depth-wise
tree grower drives all three model families through their gradient
definitions (the same unification XGBoost/LightGBM use internally):

  randomforest   g = y·w, h = w  (Poisson(1) bootstrap weights w, per-tree
                 feature subsampling);  leaf = G/H  (node mean);  trees are
                 independent — classic bagging.  Split gain = weighted
                 variance reduction (the g=y, h=w specialization of the
                 second-order gain formula).
  xgboost        logistic loss second-order boosting: p = sigmoid(margin),
                 g = p - y, h = p(1-p);  leaf = -eta * G/(H+lambda).
  lightgbm       xgboost + GOSS sampling (keep top-a fraction by |g|, sample
                 b fraction of the rest upweighted by (1-a)/b).  Depth-wise
                 growth with an equal node budget stands in for leaf-wise
                 growth (documented deviation, DESIGN.md Sec. 6.5).

Features are quantile-binned once (``num_bins`` histogram bins, the
LightGBM/XGBoost 'hist' strategy); NaNs occupy a dedicated MISSING slot and
the split search learns the default direction per node (XGBoost's sparsity-
aware split), which is what the paper's Bosch/Criteo workloads exercise.

The grower is factored so the SAME per-level math runs whether the binned
matrix is resident or streamed page-batch-by-page-batch from the tiered
store (``db/train.py``):

  * routing is a jit-compiled integer kernel over binned rows — exact, so
    per-batch and whole-array execution agree bitwise;
  * gradient/hessian histograms are accumulated HOST-side into float64 via
    ``np.add.at`` in global row order.  ``np.add.at`` is unbuffered and
    applies updates sequentially in element order, so accumulating
    consecutive row slices in order performs the exact same float-add
    sequence as one whole-array call — histograms are bit-identical for
    ANY batching of the rows (float addition is not associative; a
    partial-sums-per-batch scheme would not have this property);
  * split search / leaf values / gradients / sampling weights are single
    shared functions of those histograms and relations.

Consequently ``train_forest`` (resident) and the streamed trainer produce
bit-identical forests given identical bin edges, regardless of page or
batch geometry — the contract ``tests/test_train_streaming.py`` enforces.
The grower emits the dense complete-tree layout of ``core.forest`` directly
(terminal nodes become pass-through, threshold=+inf).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import Forest, make_forest, num_internal, num_leaves

__all__ = [
    "TrainConfig",
    "quantile_bin_edges",
    "edges_from_sample",
    "bin_features",
    "train_forest",
    "grow_forest_scanned",
    "route_level",
]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    model_type: str = "xgboost"          # randomforest | xgboost | lightgbm
    task: str = "classification"         # classification | regression
    num_trees: int = 10
    max_depth: int = 8
    learning_rate: float = 0.1           # GBDT shrinkage (ignored by RF)
    reg_lambda: float = 1.0              # L2 on leaf weights (0 for RF)
    min_child_weight: float = 1.0
    min_split_gain: float = 0.0
    num_bins: int = 64
    colsample: float = 1.0               # RF per-tree feature subsampling
    goss_top: float = 0.2                # LightGBM GOSS a
    goss_rest: float = 0.1               # LightGBM GOSS b
    seed: int = 0


# ---------------------------------------------------------------------------
# Quantile binning (host-side, once per dataset — the 'hist' preprocessing)
# ---------------------------------------------------------------------------


def _column_edges(col: np.ndarray, num_bins: int) -> np.ndarray:
    """Interior edges [num_bins - 1] for one feature column (NaNs removed).

    Strictly increasing; duplicate quantiles collapse to +inf (empty bins).
    Shared by the exact resident pass and the streamed sketch finalizer.
    """
    qs = np.linspace(0.0, 1.0, num_bins + 1)[1:-1]
    if col.size == 0:
        return np.full((num_bins - 1,), np.inf, np.float32)
    e = np.quantile(col, qs).astype(np.float32)
    e = np.where(np.diff(np.concatenate([[-np.inf], e])) > 0, e, np.inf)
    return np.sort(e)


def quantile_bin_edges(x: np.ndarray, num_bins: int) -> np.ndarray:
    """Per-feature interior bin boundaries [F, num_bins - 1].

    x falls in bin b iff edges[b-1] <= x < edges[b]; NaN -> MISSING slot.
    Constant features get +inf edges (every sample in bin 0, unsplittable).
    """
    F = x.shape[1]
    edges = np.empty((F, num_bins - 1), np.float32)
    for f in range(F):
        col = x[:, f]
        edges[f] = _column_edges(col[~np.isnan(col)], num_bins)
    return edges


def edges_from_sample(sample: np.ndarray, num_bins: int) -> np.ndarray:
    """Edges from a [S, F] row sample (the streamed sketch finalizer).

    Same per-column quantile + dedupe logic as :func:`quantile_bin_edges`,
    applied to whatever rows the sketch retained instead of the full matrix.
    """
    return quantile_bin_edges(np.asarray(sample, np.float32), num_bins)


def bin_features(x: np.ndarray | jax.Array, edges: np.ndarray) -> jax.Array:
    """[N, F] float -> [N, F] int32 bin index; NaN -> num_bins (MISSING)."""
    x = jnp.asarray(x)
    e = jnp.asarray(edges)  # [F, B-1]
    num_bins = e.shape[1] + 1
    # bin = number of edges strictly below-or-equal... x in bin b iff
    # e[b-1] <= x < e[b]  =>  bin = sum(x >= e).
    b = jnp.sum(x[:, :, None] >= e[None], axis=-1).astype(jnp.int32)
    return jnp.where(jnp.isnan(x), jnp.int32(num_bins), b)


# ---------------------------------------------------------------------------
# Shared per-level machinery: route kernel, histogram update, split search
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("level", "num_bins"))
def route_level(bins, node_of, feat, sbin, dleft, term, *, level, num_bins):
    """Route rows through level ``level``'s recorded splits (exact ints).

    bins [rows, F] int32; node_of [rows] dense positions at level ``level``;
    feat/sbin/dleft/term [2^level] that level's split params.  Terminal
    nodes pass every row left (the growth-time convention, so the whole
    terminal chain lands in one leaf).  Integer/boolean only — per-batch
    and whole-array execution are bitwise identical.
    """
    n_nodes = 1 << level
    first = n_nodes - 1
    local = jnp.clip(node_of - first, 0, n_nodes - 1)
    my_bin = jnp.take_along_axis(bins, feat[local][:, None], axis=1)[:, 0]
    is_missing = my_bin == num_bins
    go_left = jnp.where(is_missing, dleft[local], my_bin <= sbin[local])
    go_left = go_left | term[local]
    return 2 * node_of + 1 + (1 - go_left.astype(jnp.int32))


def hist_update(hg: np.ndarray, hh: np.ndarray, bins: np.ndarray,
                node_of: np.ndarray, g: np.ndarray, h: np.ndarray) -> None:
    """Accumulate one row slice into the level's float64 histograms.

    hg/hh [n_nodes, F, num_bins + 1] float64 (in place); bins [rows, F]
    integer; node_of [rows] dense positions; g/h [rows] float32.

    Canonical accumulation order: ``np.add.at`` applies its updates
    sequentially in element order (row-major here), so calling this on
    consecutive row slices in order is bit-identical to one whole-array
    call — the property the streamed trainer's parity contract rests on.
    Rows with g == h == 0 (store padding) add +0.0 everywhere, which never
    changes an accumulator bit (accumulators can never hold -0.0).
    """
    n_nodes, F, bp1 = hg.shape
    first = n_nodes - 1
    local = np.clip(node_of.astype(np.int64) - first, 0, n_nodes - 1)
    f_ix = np.arange(F, dtype=np.int64)[None, :]
    seg = ((local[:, None] * F + f_ix) * bp1 + bins.astype(np.int64)).reshape(-1)
    np.add.at(hg.reshape(-1), seg, np.repeat(g.astype(np.float64), F))
    np.add.at(hh.reshape(-1), seg, np.repeat(h.astype(np.float64), F))


def _segment_sum64(values: np.ndarray, seg: np.ndarray, n: int) -> np.ndarray:
    """Float64 sequential-order segment sum (np.add.at; see hist_update)."""
    acc = np.zeros((n,), np.float64)
    np.add.at(acc, seg.astype(np.int64), values.astype(np.float64))
    return acc


def _split_from_hist(hg64: np.ndarray, hh64: np.ndarray, feat_mask: np.ndarray,
                     *, num_bins: int, reg_lambda: float,
                     min_child_weight: float, min_split_gain: float):
    """Depth-wise split search over one level's histograms (host side).

    hg64/hh64 [n_nodes, F, B+1] float64 accumulators (cast f32 once, the
    same cast in both paths); feat_mask [F] bool.  Returns per-node
    (feature, split_bin, default_left, terminal, node_g, node_h) with the
    growth conventions: terminal nodes record feature 0 and pass through.
    """
    hg = hg64.astype(np.float32)
    hh = hh64.astype(np.float32)
    n_nodes, F, _ = hg.shape
    B = num_bins
    g_miss, h_miss = hg[..., B], hh[..., B]                # [n, F]
    cg = np.cumsum(hg[..., :B], axis=-1)                   # [n, F, B]
    ch = np.cumsum(hh[..., :B], axis=-1)
    g_tot = cg[..., -1] + g_miss
    h_tot = ch[..., -1] + h_miss

    lam = np.float32(reg_lambda)

    def score(G, H):
        return np.square(G) / (H + lam)

    # split at s (left = bins <= s), s in [0, B-2]; two missing dirs.
    s_cg, s_ch = cg[..., : B - 1], ch[..., : B - 1]        # [n, F, B-1]
    with np.errstate(divide="ignore", invalid="ignore"):
        parent = score(g_tot, h_tot)[..., None]            # [n, F, 1]
        gains = []
        for mdir in (0, 1):  # 0: missing right, 1: missing left
            GL = s_cg + (g_miss[..., None] if mdir else 0.0)
            HL = s_ch + (h_miss[..., None] if mdir else 0.0)
            GR = g_tot[..., None] - GL
            HR = h_tot[..., None] - HL
            gain = score(GL, HL) + score(GR, HR) - parent
            ok = (HL >= min_child_weight) & (HR >= min_child_weight)
            gains.append(np.where(ok, gain, -np.inf))
    gain_all = np.stack(gains, axis=-1)                    # [n, F, B-1, 2]
    gain_all = np.where(feat_mask[None, :, None, None], gain_all, -np.inf)

    flat = gain_all.reshape(n_nodes, -1)
    best = np.argmax(flat, axis=-1)                        # [n]
    best_gain = np.take_along_axis(flat, best[:, None], axis=-1)[:, 0]
    n_dirs = 2
    n_splits = (B - 1) * n_dirs
    feat = (best // n_splits).astype(np.int32)
    rem = best % n_splits
    split_bin = (rem // n_dirs).astype(np.int32)
    default_left = (rem % n_dirs) == 1

    with np.errstate(invalid="ignore"):
        terminal = ~(best_gain > min_split_gain)           # includes -inf/NaN
    feat = np.where(terminal, np.int32(0), feat)

    # Node stats from the histograms themselves: every feature column
    # partitions all of a node's rows, so feature 0 summed over bins IS the
    # node total (float64, deterministic np.sum — identical in both paths).
    node_g = hg64[:, 0, :].sum(axis=-1).astype(np.float32)
    node_h = hh64[:, 0, :].sum(axis=-1).astype(np.float32)
    return feat, split_bin, default_left, terminal, node_g, node_h


def _leaf_value_np(G: np.ndarray, H: np.ndarray, *, model_type: str,
                   learning_rate: float, reg_lambda: float) -> np.ndarray:
    if model_type == "randomforest":
        with np.errstate(divide="ignore", invalid="ignore"):
            return np.where(H > 0, G / np.maximum(H, np.float32(1e-12)),
                            np.float32(0.0)).astype(np.float32)
    return (np.float32(-learning_rate) * G
            / (H + np.float32(reg_lambda))).astype(np.float32)


def _tree_gradients(margin: np.ndarray, yj: jax.Array, cfg: TrainConfig,
                    tree_index: int, k_bag, k_goss):
    """Per-tree (g, h) over the REAL rows, as host float32 arrays.

    One implementation for both the resident and streamed paths: sigmoid,
    Poisson bagging, the GOSS quantile threshold and uniform draws all run
    in jax from the same key, so both paths see bit-identical gradients.
    """
    N = margin.shape[0]
    if cfg.model_type == "randomforest":
        w = jax.random.poisson(k_bag, 1.0, (N,)).astype(jnp.float32)
        g, h = yj * w, w
    else:
        m = jnp.asarray(margin)
        if cfg.task == "classification":
            p = jax.nn.sigmoid(m)
            g, h = p - yj, p * (1.0 - p)
        else:
            g, h = m - yj, jnp.ones((N,), jnp.float32)
        if cfg.model_type == "lightgbm" and tree_index > 0:
            # first tree sees all data (LightGBM GOSS convention)
            a, b = cfg.goss_top, cfg.goss_rest
            ag = jnp.abs(g)
            thr = jnp.quantile(ag, 1.0 - a)
            top = ag >= thr
            rest = (~top) & (jax.random.uniform(k_goss, (N,)) < b)
            w = top.astype(jnp.float32) + rest.astype(jnp.float32) * ((1 - a) / b)
            g, h = g * w, h * w
    return np.asarray(g), np.asarray(h)


def _tree_feature_mask(k_feat, F: int, cfg: TrainConfig) -> np.ndarray:
    if cfg.model_type == "randomforest" and cfg.colsample < 1.0:
        k_sel = max(1, int(round(cfg.colsample * F)))
        perm = jax.random.permutation(k_feat, F)[:k_sel]
        return np.asarray(jnp.zeros((F,), bool).at[perm].set(True))
    return np.ones((F,), bool)


# ---------------------------------------------------------------------------
# The grower: drives run_scan over the binned relation, level by level
# ---------------------------------------------------------------------------


def grow_forest_scanned(run_scan, *, y: np.ndarray, num_rows: int,
                        num_features: int, total_rows: int | None = None,
                        edges: np.ndarray, cfg: TrainConfig) -> Forest:
    """Grow a forest by scanning the binned relation once per level.

    ``run_scan(node_of, route=None, hist=None)`` is the scan provider:
    it must visit every row of the binned relation in global row order,
    (a) if ``route`` is ``(level, feat, sbin, dleft, term)``, route each
    row with :func:`route_level` (returning the updated node_of), then
    (b) if ``hist`` is ``(g, h, level)``, accumulate that level's
    histograms via :func:`hist_update`, returning ``(hg64, hh64)``.
    The resident provider does both on the whole array; the streamed
    provider (``db/train.py``) does both per executor batch — bitwise the
    same result by the canonical-accumulation argument in the module doc.

    ``total_rows`` is the relation length including any page padding
    (padded rows carry g = h = 0 and contribute nothing); ``num_rows`` is
    the real row count that gradients and margins are computed over.
    """
    if cfg.model_type not in ("randomforest", "xgboost", "lightgbm"):
        raise ValueError(f"unknown model_type {cfg.model_type!r}")
    N = int(num_rows)
    F = int(num_features)
    total = N if total_rows is None else int(total_rows)
    if total < N:
        raise ValueError(f"total_rows {total} < num_rows {N}")
    edges = np.asarray(edges, np.float32)
    y_np = np.asarray(y, np.float32)
    yj = jnp.asarray(y_np)
    I, L = num_internal(cfg.max_depth), num_leaves(cfg.max_depth)

    key = jax.random.PRNGKey(cfg.seed)
    is_rf = cfg.model_type == "randomforest"
    reg_lambda = 0.0 if is_rf else cfg.reg_lambda
    lr = 1.0 if is_rf else cfg.learning_rate

    feature_T = np.zeros((cfg.num_trees, I), np.int32)
    threshold_T = np.full((cfg.num_trees, I), np.inf, np.float32)
    default_left_T = np.ones((cfg.num_trees, I), bool)
    node_is_leaf_T = np.zeros((cfg.num_trees, I), bool)
    node_value_T = np.zeros((cfg.num_trees, I), np.float32)
    leaf_value_T = np.zeros((cfg.num_trees, L), np.float32)

    margin = np.zeros((N,), np.float32)

    for t in range(cfg.num_trees):
        key, k_bag, k_feat, k_goss = jax.random.split(key, 4)
        g, h = _tree_gradients(margin, yj, cfg, t, k_bag, k_goss)
        if total > N:  # store page padding: inert rows
            g = np.concatenate([g, np.zeros((total - N,), np.float32)])
            h = np.concatenate([h, np.zeros((total - N,), np.float32)])
        feat_mask = _tree_feature_mask(k_feat, F, cfg)

        node_of = np.zeros((total,), np.int32)
        route = None
        for level in range(cfg.max_depth):
            node_of, hists = run_scan(node_of, route=route,
                                      hist=(g, h, level))
            feat, sbin, dleft, term, ng, nh = _split_from_hist(
                hists[0], hists[1], feat_mask,
                num_bins=cfg.num_bins, reg_lambda=reg_lambda,
                min_child_weight=cfg.min_child_weight,
                min_split_gain=cfg.min_split_gain)
            first = (1 << level) - 1
            sl = slice(first, first + (1 << level))
            feature_T[t, sl] = feat
            # dense threshold in feature units: left iff bin <= s iff
            # x < edges[f, s]; terminal -> pass-through (+inf, left)
            thr = edges[feat, np.clip(sbin, 0, cfg.num_bins - 2)]
            threshold_T[t, sl] = np.where(term, np.float32(np.inf), thr)
            default_left_T[t, sl] = np.where(term, True, dleft)
            node_is_leaf_T[t, sl] = term
            node_value_T[t, sl] = _leaf_value_np(
                ng, nh, model_type=cfg.model_type, learning_rate=lr,
                reg_lambda=reg_lambda)
            route = (level, feat, sbin, dleft, term)

        # final scan: route through the last level to leaf positions
        node_of, _ = run_scan(node_of, route=route, hist=None)
        leaf_local = np.clip(node_of - I, 0, L - 1)
        leaf_g = _segment_sum64(g, leaf_local, L).astype(np.float32)
        leaf_h = _segment_sum64(h, leaf_local, L).astype(np.float32)
        lv = _leaf_value_np(leaf_g, leaf_h, model_type=cfg.model_type,
                            learning_rate=lr, reg_lambda=reg_lambda)
        leaf_value_T[t] = lv
        if not is_rf:
            # fit-consistent boosting: each row takes the value of the leaf
            # it was fitted into (growth routing, terminal chains forced
            # left) — the XGBoost/LightGBM update rule.
            margin = margin + lv[leaf_local[:N]]

    return make_forest(
        feature_T, threshold_T, leaf_value_T,
        default_left=default_left_T,
        node_is_leaf=node_is_leaf_T,
        node_value=node_value_T,
        n_features=F,
        model_type=cfg.model_type,
        task=cfg.task,
        base_score=0.0,
    )


# ---------------------------------------------------------------------------
# Resident driver (whole binned matrix in memory — the reference path)
# ---------------------------------------------------------------------------


def _resident_scan(bins_np: np.ndarray, num_bins: int):
    """Scan provider over a resident [N, F] int32 binned matrix."""
    bins_j = jnp.asarray(bins_np)

    def run_scan(node_of, *, route=None, hist=None):
        if route is not None:
            level, feat, sbin, dleft, term = route
            node_of = np.asarray(route_level(
                bins_j, jnp.asarray(node_of), jnp.asarray(feat),
                jnp.asarray(sbin), jnp.asarray(dleft), jnp.asarray(term),
                level=level, num_bins=num_bins))
        hists = None
        if hist is not None:
            g, h, level = hist
            n_nodes = 1 << level
            F = bins_np.shape[1]
            hg = np.zeros((n_nodes, F, num_bins + 1), np.float64)
            hh = np.zeros((n_nodes, F, num_bins + 1), np.float64)
            hist_update(hg, hh, bins_np, node_of, g, h)
            hists = (hg, hh)
        return node_of, hists

    return run_scan


def train_forest(x: np.ndarray, y: np.ndarray, cfg: TrainConfig,
                 *, edges: np.ndarray | None = None) -> Forest:
    """Train a decision forest on resident [N, F] features / [N] targets.

    ``edges`` overrides the exact-quantile binning (the streamed trainer
    passes its sketch edges here when asserting parity: the bit-identity
    contract is conditioned on identical bin edges).
    """
    x = np.asarray(x, np.float32)
    y_np = np.asarray(y, np.float32)
    N, F = x.shape
    if edges is None:
        edges = quantile_bin_edges(x, cfg.num_bins)
    bins_np = np.asarray(bin_features(x, edges))
    return grow_forest_scanned(
        _resident_scan(bins_np, cfg.num_bins),
        y=y_np, num_rows=N, num_features=F, edges=edges, cfg=cfg)
