"""In-JAX decision-forest TRAINING (the substrate the paper outsources).

The paper trains every model with scikit-learn / the XGBoost & LightGBM C
libraries (Sec. 4) and only benchmarks inference.  We build the trainer
in-framework so the system is self-contained: one histogram-based, depth-wise
tree grower drives all three model families through their gradient
definitions (the same unification XGBoost/LightGBM use internally):

  randomforest   g = y·w, h = w  (Poisson(1) bootstrap weights w, per-tree
                 feature subsampling);  leaf = G/H  (node mean);  trees are
                 independent — classic bagging.  Split gain = weighted
                 variance reduction (the g=y, h=w specialization of the
                 second-order gain formula).
  xgboost        logistic loss second-order boosting: p = sigmoid(margin),
                 g = p - y, h = p(1-p);  leaf = -eta * G/(H+lambda).
  lightgbm       xgboost + GOSS sampling (keep top-a fraction by |g|, sample
                 b fraction of the rest upweighted by (1-a)/b).  Depth-wise
                 growth with an equal node budget stands in for leaf-wise
                 growth (documented deviation, DESIGN.md Sec. 6.5).

Features are quantile-binned once (``num_bins`` histogram bins, the
LightGBM/XGBoost 'hist' strategy); NaNs occupy a dedicated MISSING slot and
the split search learns the default direction per node (XGBoost's sparsity-
aware split), which is what the paper's Bosch/Criteo workloads exercise.

Everything after binning is jit-compiled JAX: per-level histograms are
``segment_sum`` scatters, split search is a cumsum + argmax over
[nodes, features, bins, directions], and routing is integer compares on the
binned matrix.  The grower emits the dense complete-tree layout of
``core.forest`` directly (terminal nodes become pass-through, threshold=+inf).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import Forest, make_forest, num_internal, num_leaves

__all__ = [
    "TrainConfig",
    "quantile_bin_edges",
    "bin_features",
    "train_forest",
]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    model_type: str = "xgboost"          # randomforest | xgboost | lightgbm
    task: str = "classification"         # classification | regression
    num_trees: int = 10
    max_depth: int = 8
    learning_rate: float = 0.1           # GBDT shrinkage (ignored by RF)
    reg_lambda: float = 1.0              # L2 on leaf weights (0 for RF)
    min_child_weight: float = 1.0
    min_split_gain: float = 0.0
    num_bins: int = 64
    colsample: float = 1.0               # RF per-tree feature subsampling
    goss_top: float = 0.2                # LightGBM GOSS a
    goss_rest: float = 0.1               # LightGBM GOSS b
    seed: int = 0


# ---------------------------------------------------------------------------
# Quantile binning (host-side, once per dataset — the 'hist' preprocessing)
# ---------------------------------------------------------------------------


def quantile_bin_edges(x: np.ndarray, num_bins: int) -> np.ndarray:
    """Per-feature interior bin boundaries [F, num_bins - 1].

    x falls in bin b iff edges[b-1] <= x < edges[b]; NaN -> MISSING slot.
    Constant features get +inf edges (every sample in bin 0, unsplittable).
    """
    F = x.shape[1]
    qs = np.linspace(0.0, 1.0, num_bins + 1)[1:-1]
    edges = np.empty((F, num_bins - 1), np.float32)
    for f in range(F):
        col = x[:, f]
        col = col[~np.isnan(col)]
        if col.size == 0:
            edges[f] = np.inf
            continue
        e = np.quantile(col, qs).astype(np.float32)
        # strictly increasing edges; collapse duplicates to +inf (empty bins)
        e = np.where(np.diff(np.concatenate([[-np.inf], e])) > 0, e, np.inf)
        edges[f] = np.sort(e)
    return edges


def bin_features(x: np.ndarray | jax.Array, edges: np.ndarray) -> jax.Array:
    """[N, F] float -> [N, F] int32 bin index; NaN -> num_bins (MISSING)."""
    x = jnp.asarray(x)
    e = jnp.asarray(edges)  # [F, B-1]
    num_bins = e.shape[1] + 1
    # bin = number of edges strictly below-or-equal... x in bin b iff
    # e[b-1] <= x < e[b]  =>  bin = sum(x >= e).
    b = jnp.sum(x[:, :, None] >= e[None], axis=-1).astype(jnp.int32)
    return jnp.where(jnp.isnan(x), jnp.int32(num_bins), b)


# ---------------------------------------------------------------------------
# One depth-wise level: histogram -> split search -> routing
# ---------------------------------------------------------------------------


def _level_step(level: int, num_bins: int, reg_lambda: float,
                min_child_weight: float, min_split_gain: float):
    """Returns a function processing level ``level`` (2^level nodes)."""
    n_nodes = 1 << level
    first = (1 << level) - 1  # first dense position of this level

    def step(bins, g, h, node_of, feat_mask):
        """bins [N,F] int32; g,h [N]; node_of [N] dense positions;
        feat_mask [F] bool (allowed features).
        Returns (feature, split_bin, default_left, gain) each [n_nodes]
        and the updated node_of."""
        N, F = bins.shape
        B = num_bins
        local = node_of - first  # [N] in [0, n_nodes); stale samples clamped
        local = jnp.clip(local, 0, n_nodes - 1)

        # --- histograms: segment ids (local, f, bin) ----------------------
        f_ix = jnp.arange(F, dtype=jnp.int32)[None, :]
        seg = (local[:, None] * F + f_ix) * (B + 1) + bins  # [N, F]
        segs = seg.reshape(-1)
        nseg = n_nodes * F * (B + 1)
        hg = jax.ops.segment_sum(jnp.broadcast_to(g[:, None], (N, F)).reshape(-1),
                                 segs, nseg).reshape(n_nodes, F, B + 1)
        hh = jax.ops.segment_sum(jnp.broadcast_to(h[:, None], (N, F)).reshape(-1),
                                 segs, nseg).reshape(n_nodes, F, B + 1)

        g_miss, h_miss = hg[..., B], hh[..., B]            # [n, F]
        cg = jnp.cumsum(hg[..., :B], axis=-1)              # [n, F, B]
        ch = jnp.cumsum(hh[..., :B], axis=-1)
        g_tot = cg[..., -1] + g_miss                       # [n, F]
        h_tot = ch[..., -1] + h_miss

        lam = jnp.float32(reg_lambda)

        def score(G, H):
            return jnp.square(G) / (H + lam)

        # split at s (left = bins <= s), s in [0, B-2]; two missing dirs.
        s_cg, s_ch = cg[..., : B - 1], ch[..., : B - 1]    # [n, F, B-1]
        parent = score(g_tot, h_tot)[..., None]            # [n, F, 1]
        gains = []
        for mdir in (0, 1):  # 0: missing right, 1: missing left (default_left)
            GL = s_cg + (g_miss[..., None] if mdir else 0.0)
            HL = s_ch + (h_miss[..., None] if mdir else 0.0)
            GR = g_tot[..., None] - GL
            HR = h_tot[..., None] - HL
            gain = score(GL, HL) + score(GR, HR) - parent
            ok = (HL >= min_child_weight) & (HR >= min_child_weight)
            gains.append(jnp.where(ok, gain, -jnp.inf))
        gain_all = jnp.stack(gains, axis=-1)               # [n, F, B-1, 2]
        gain_all = jnp.where(feat_mask[None, :, None, None], gain_all, -jnp.inf)

        flat = gain_all.reshape(n_nodes, -1)
        best = jnp.argmax(flat, axis=-1)                   # [n]
        best_gain = jnp.take_along_axis(flat, best[:, None], axis=-1)[:, 0]
        n_dirs = 2
        n_splits = (B - 1) * n_dirs
        feat = (best // n_splits).astype(jnp.int32)
        rem = best % n_splits
        split_bin = (rem // n_dirs).astype(jnp.int32)
        default_left = (rem % n_dirs) == 1

        terminal = ~(best_gain > min_split_gain)           # includes -inf/NaN
        # terminal nodes: pass-through (everything left).
        feat = jnp.where(terminal, 0, feat)

        # node value (for premature-leaf bookkeeping): -G/(H+lam) flavor is
        # applied by the caller; here record raw G, H per node.
        node_g = jax.ops.segment_sum(g, local, n_nodes)
        node_h = jax.ops.segment_sum(h, local, n_nodes)

        # --- route ---------------------------------------------------------
        my_bin = jnp.take_along_axis(bins, feat[local][:, None], axis=1)[:, 0]
        my_split = split_bin[local]
        my_dl = default_left[local]
        is_missing = my_bin == B
        go_left = jnp.where(is_missing, my_dl, my_bin <= my_split)
        go_left = go_left | terminal[local]
        pos = node_of
        new_pos = 2 * pos + 1 + (1 - go_left.astype(jnp.int32))
        return (feat, split_bin, default_left, terminal, node_g, node_h,
                new_pos)

    return step


@partial(jax.jit, static_argnames=("max_depth", "num_bins", "reg_lambda",
                                   "min_child_weight", "min_split_gain"))
def _grow_tree(bins, g, h, feat_mask, *, max_depth, num_bins, reg_lambda,
               min_child_weight, min_split_gain):
    """Grow one dense depth-``max_depth`` tree. Returns dense arrays."""
    N, F = bins.shape
    I, L = num_internal(max_depth), num_leaves(max_depth)
    feature = jnp.zeros((I,), jnp.int32)
    split_bin = jnp.zeros((I,), jnp.int32)
    default_left = jnp.ones((I,), bool)
    terminal = jnp.zeros((I,), bool)
    node_g = jnp.zeros((I,), jnp.float32)
    node_h = jnp.zeros((I,), jnp.float32)

    node_of = jnp.zeros((N,), jnp.int32)
    for level in range(max_depth):
        step = _level_step(level, num_bins, reg_lambda, min_child_weight,
                           min_split_gain)
        f_, s_, dl_, t_, ng_, nh_, node_of = step(bins, g, h, node_of, feat_mask)
        first = (1 << level) - 1
        sl = slice(first, first + (1 << level))
        feature = feature.at[sl].set(f_)
        split_bin = split_bin.at[sl].set(s_)
        default_left = default_left.at[sl].set(dl_)
        terminal = terminal.at[sl].set(t_)
        node_g = node_g.at[sl].set(ng_)
        node_h = node_h.at[sl].set(nh_)

    # leaf stats
    leaf_local = jnp.clip(node_of - I, 0, L - 1)
    leaf_g = jax.ops.segment_sum(g, leaf_local, L)
    leaf_h = jax.ops.segment_sum(h, leaf_local, L)
    return feature, split_bin, default_left, terminal, node_g, node_h, leaf_g, leaf_h


def _leaf_value(G, H, *, model_type, learning_rate, reg_lambda):
    if model_type == "randomforest":
        return jnp.where(H > 0, G / jnp.maximum(H, 1e-12), 0.0)
    return -learning_rate * G / (H + reg_lambda)


@partial(jax.jit, static_argnames=("num_bins",))
def _route_margin(bins, feature, split_bin, default_left, leaf_value, depth_arr,
                  *, num_bins):
    """Margin contribution of one dense tree on binned features (exact)."""
    N = bins.shape[0]
    I = feature.shape[0]
    depth = depth_arr  # python int via closure; kept for clarity
    pos = jnp.zeros((N,), jnp.int32)
    d = 0
    while (1 << d) - 1 < I:
        f = feature[pos]
        b = jnp.take_along_axis(bins, f[:, None], axis=1)[:, 0]
        missing = b == num_bins
        left = jnp.where(missing, default_left[pos], b <= split_bin[pos])
        pos = 2 * pos + 1 + (1 - left.astype(jnp.int32))
        d += 1
    return leaf_value[pos - I]


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def train_forest(x: np.ndarray, y: np.ndarray, cfg: TrainConfig) -> Forest:
    """Train a decision forest on [N, F] features / [N] targets."""
    if cfg.model_type not in ("randomforest", "xgboost", "lightgbm"):
        raise ValueError(f"unknown model_type {cfg.model_type!r}")
    x = np.asarray(x, np.float32)
    y_np = np.asarray(y, np.float32)
    N, F = x.shape
    edges = quantile_bin_edges(x, cfg.num_bins)
    bins = bin_features(x, edges)
    yj = jnp.asarray(y_np)
    I, L = num_internal(cfg.max_depth), num_leaves(cfg.max_depth)

    key = jax.random.PRNGKey(cfg.seed)
    is_rf = cfg.model_type == "randomforest"
    is_goss = cfg.model_type == "lightgbm"
    reg_lambda = 0.0 if is_rf else cfg.reg_lambda

    feature_T = np.zeros((cfg.num_trees, I), np.int32)
    threshold_T = np.full((cfg.num_trees, I), np.inf, np.float32)
    default_left_T = np.ones((cfg.num_trees, I), bool)
    node_is_leaf_T = np.zeros((cfg.num_trees, I), bool)
    node_value_T = np.zeros((cfg.num_trees, I), np.float32)
    leaf_value_T = np.zeros((cfg.num_trees, L), np.float32)

    edges_j = jnp.asarray(edges)
    margin = jnp.zeros((N,), jnp.float32)

    for t in range(cfg.num_trees):
        key, k_bag, k_feat, k_goss = jax.random.split(key, 4)
        # --- per-family gradients -------------------------------------
        if is_rf:
            w = jax.random.poisson(k_bag, 1.0, (N,)).astype(jnp.float32)
            g, h = yj * w, w
        else:
            if cfg.task == "classification":
                p = jax.nn.sigmoid(margin)
                g, h = p - yj, p * (1.0 - p)
            else:
                g, h = margin - yj, jnp.ones((N,), jnp.float32)
            if is_goss and t > 0:  # first tree sees all data (LightGBM)
                a, b = cfg.goss_top, cfg.goss_rest
                ag = jnp.abs(g)
                thr = jnp.quantile(ag, 1.0 - a)
                top = ag >= thr
                rest = (~top) & (jax.random.uniform(k_goss, (N,)) < b)
                w = top.astype(jnp.float32) + rest.astype(jnp.float32) * ((1 - a) / b)
                g, h = g * w, h * w
        # --- feature subsampling (RF) ----------------------------------
        if is_rf and cfg.colsample < 1.0:
            k_sel = max(1, int(round(cfg.colsample * F)))
            perm = jax.random.permutation(k_feat, F)[:k_sel]
            feat_mask = jnp.zeros((F,), bool).at[perm].set(True)
        else:
            feat_mask = jnp.ones((F,), bool)

        out = _grow_tree(
            bins, g, h, feat_mask,
            max_depth=cfg.max_depth, num_bins=cfg.num_bins,
            reg_lambda=reg_lambda, min_child_weight=cfg.min_child_weight,
            min_split_gain=cfg.min_split_gain,
        )
        feat, sbin, dleft, term, ng, nh, lg, lh = out
        lv = _leaf_value(lg, lh, model_type=cfg.model_type,
                         learning_rate=(1.0 if is_rf else cfg.learning_rate),
                         reg_lambda=reg_lambda)
        nv = _leaf_value(ng, nh, model_type=cfg.model_type,
                         learning_rate=(1.0 if is_rf else cfg.learning_rate),
                         reg_lambda=reg_lambda)

        # dense threshold in feature units: left iff bin <= s iff x < edges[f, s]
        thr = edges_j[feat, jnp.clip(sbin, 0, cfg.num_bins - 2)]
        thr = jnp.where(term, jnp.inf, thr)
        dleft = jnp.where(term, True, dleft)

        # terminal-node value propagation to unreachable dense leaves is not
        # needed (pass-through sends every sample left; the reachable dense
        # leaf under a terminal chain accumulates that node's samples).
        feature_T[t] = np.asarray(feat)
        threshold_T[t] = np.asarray(thr)
        default_left_T[t] = np.asarray(dleft)
        node_is_leaf_T[t] = np.asarray(term)
        node_value_T[t] = np.asarray(nv)
        leaf_value_T[t] = np.asarray(lv)

        if not is_rf:
            margin = margin + _route_margin(
                bins, feat, sbin, dleft, jnp.asarray(leaf_value_T[t]),
                cfg.max_depth, num_bins=cfg.num_bins)

    return make_forest(
        feature_T, threshold_T, leaf_value_T,
        default_left=default_left_T,
        node_is_leaf=node_is_leaf_T,
        node_value=node_value_T,
        n_features=F,
        model_type=cfg.model_type,
        task=cfg.task,
        base_score=0.0,
    )
