"""The paper's F1 axis: decision-forest inference algorithms, in JAX.

Four backends over the same dense complete-tree ``Forest``:

  naive       per (sample, tree) ``lax.while_loop`` with early exit at
              premature leaves — the faithful "naive tree traversal"
              (paper Fig. 2a).  Data-dependent loop; the TPU-hostile
              baseline every platform in the paper starts from.
  predicated  fixed-depth branch-free descent ``idx = 2*idx + 1 + cond``
              (paper Fig. 2c / Nvidia FIL).  ``unroll=True`` is the
              "compiled" variant (paper Fig. 2b): XLA sees straight-line
              select chains, playing the role of lleaves/TreeLite codegen.
  hummingbird GEMM formulation (paper Fig. 1b): predicate vector S, shared
              path matrix C, leaf one-hot by count match.
  quickscorer bit-vector AND of FALSE-node masks (paper Fig. 1c), TPU-dense
              adaptation: ALL node predicates evaluated vectorially, AND
              reduced over uint32 words, exit leaf = lowest surviving bit.

All are vectorized over a [B, F] sample block (the paper's F4 axis) and
return per-tree raw scores [B, T]; ``postprocess`` aggregates them (phase 2).
Missing values: NaN features follow ``default_left``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import Forest, hb_path_matrix, qs_bitvectors

__all__ = [
    "naive_predict",
    "predicated_predict",
    "hummingbird_predict",
    "quickscorer_predict",
    "predict_raw",
    "ALGORITHMS",
]


def _go_left(x_f: jax.Array, thr: jax.Array, default_left: jax.Array) -> jax.Array:
    """Branch direction including NaN handling. True = left child."""
    return jnp.where(jnp.isnan(x_f), default_left, x_f < thr)


# ---------------------------------------------------------------------------
# 1. Naive traversal
# ---------------------------------------------------------------------------


def naive_predict(forest: Forest, x: jax.Array) -> jax.Array:
    """[B, F] -> [B, T] via per-(sample, tree) while_loop with early exit."""
    I = forest.num_internal

    def one(x_row, feature, threshold, default_left, node_is_leaf, node_value, leaf_value):
        def cond(state):
            pos, _ = state
            at_internal = pos < I
            premature = jnp.where(at_internal, node_is_leaf[jnp.minimum(pos, I - 1)], False)
            return at_internal & ~premature

        def body(state):
            pos, _ = state
            f = feature[pos]
            left = _go_left(x_row[f], threshold[pos], default_left[pos])
            nxt = 2 * pos + 1 + (1 - left.astype(jnp.int32))
            return nxt, nxt

        pos, _ = jax.lax.while_loop(cond, body, (jnp.int32(0), jnp.int32(0)))
        return jnp.where(
            pos < I, node_value[jnp.minimum(pos, I - 1)], leaf_value[jnp.maximum(pos - I, 0)]
        )

    per_tree = jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0, 0))  # over trees
    per_sample = jax.vmap(per_tree, in_axes=(0, None, None, None, None, None, None))
    return per_sample(
        x,
        forest.feature,
        forest.threshold,
        forest.default_left,
        forest.node_is_leaf,
        forest.node_value,
        forest.leaf_value,
    )


# ---------------------------------------------------------------------------
# 2. Predicated traversal (and its unrolled / "compiled" variant)
# ---------------------------------------------------------------------------


def predicated_predict(forest: Forest, x: jax.Array, *, unroll: bool = False) -> jax.Array:
    """[B, F] -> [B, T]; fixed-depth branch-free descent.

    Pass-through completion makes early leaves behave identically, so no
    early-exit test is needed — exactly the FIL trick, adapted so that a
    whole (sample-block × tree-block) advances one level per step on the VPU.
    """
    B = x.shape[0]
    T, I = forest.feature.shape
    t_ix = jnp.arange(T)[None, :]  # broadcast against idx [B, T]

    def step(idx):
        f = forest.feature[t_ix, idx]  # [B, T]
        thr = forest.threshold[t_ix, idx]
        dl = forest.default_left[t_ix, idx]
        xv = jnp.take_along_axis(x, f, axis=1)  # [B, T]
        left = _go_left(xv, thr, dl)
        return 2 * idx + 1 + (1 - left.astype(jnp.int32))

    idx = jnp.zeros((B, T), jnp.int32)
    if unroll:
        for _ in range(forest.depth):
            idx = step(idx)
    else:
        idx = jax.lax.fori_loop(0, forest.depth, lambda _, i: step(i), idx)
    leaf = idx - I  # [B, T]
    return forest.leaf_value[t_ix, leaf]


# ---------------------------------------------------------------------------
# 3. HummingBird (GEMM) formulation
# ---------------------------------------------------------------------------


def hummingbird_predict(
    forest: Forest,
    x: jax.Array,
    *,
    gemm_features: bool = False,
) -> jax.Array:
    """[B, F] -> [B, T] via the tensor formulation.

    S[b,t,i] = predicate of node i of tree t on sample b (1 = go left).
    P = S @ C (shared structure-only path matrix), exit leaf where
    P == D_count, prediction = onehot(P==D) @ leaf_value.

    ``gemm_features=True`` additionally computes the feature-select step as
    a one-hot GEMM (X @ A), HummingBird's pure-GEMM mode — only sensible for
    narrow features; default uses a gather (HB's "tree traversal" feature
    fetch) which is what its TVM backend also lowers to.
    """
    C_np, D_np = hb_path_matrix(forest.depth)
    C = jnp.asarray(C_np, jnp.float32)  # [I, L]
    D = jnp.asarray(D_np, jnp.float32)  # [L]

    if gemm_features:
        A = jax.nn.one_hot(forest.feature, x.shape[1], dtype=x.dtype)  # [T, I, F]
        xv = jnp.einsum("bf,tif->bti", x, A)
    else:
        xv = x[:, forest.feature]  # [B, T, I]
    s = _go_left(xv, forest.threshold[None], forest.default_left[None])
    P = jnp.einsum("bti,il->btl", s.astype(jnp.float32), C)  # [B, T, L]
    onehot = (P == D[None, None, :]).astype(jnp.float32)
    return jnp.einsum("btl,tl->bt", onehot, forest.leaf_value)


# ---------------------------------------------------------------------------
# 4. QuickScorer, dense-TPU adaptation
# ---------------------------------------------------------------------------


def quickscorer_predict(forest: Forest, x: jax.Array) -> jax.Array:
    """[B, F] -> [B, T] via bitvector AND of FALSE nodes.

    CPU QuickScorer finds FALSE nodes by per-feature binary search; on the
    VPU it is cheaper to evaluate *every* node predicate densely and select
    the bitvector or all-ones.  AND-reduction over the I axis runs as a
    log-depth tree on uint32 words; exit leaf = count-trailing-zeros of the
    first non-zero word.
    """
    T, I = forest.feature.shape
    L = forest.num_leaves
    W = (L + 31) // 32
    bv = jnp.asarray(qs_bitvectors(forest.depth))  # [I, W] uint32 (structure-only)

    xv = x[:, forest.feature]  # [B, T, I]
    is_false = ~_go_left(xv, forest.threshold[None], forest.default_left[None])
    masks = jnp.where(is_false[..., None], bv[None, None], jnp.uint32(0xFFFFFFFF))  # [B,T,I,W]

    # log-tree AND reduction over I (pad to power of two with all-ones).
    n = 1 << int(np.ceil(np.log2(max(I, 1))))
    pad = n - I
    if pad:
        masks = jnp.pad(
            masks, ((0, 0), (0, 0), (0, pad), (0, 0)),
            constant_values=np.uint32(0xFFFFFFFF),
        )
    while masks.shape[2] > 1:
        h = masks.shape[2] // 2
        masks = jnp.bitwise_and(masks[:, :, :h], masks[:, :, h:])
    surviving = masks[:, :, 0]  # [B, T, W] — ≥1 bit set by construction

    # lowest set bit over W LSB-first words.
    nonzero = surviving != 0
    first_word = jnp.argmax(nonzero, axis=-1)  # [B, T]
    word = jnp.take_along_axis(surviving, first_word[..., None], axis=-1)[..., 0]
    low = word & (~word + jnp.uint32(1))  # isolate lowest set bit
    ctz = jnp.bitwise_count(low - jnp.uint32(1)).astype(jnp.int32)
    leaf = first_word.astype(jnp.int32) * 32 + ctz  # [B, T]
    return forest.leaf_value[jnp.arange(T)[None, :], leaf]


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

ALGORITHMS = {
    "naive": naive_predict,
    "predicated": predicated_predict,
    "compiled": partial(predicated_predict, unroll=True),
    "hummingbird": hummingbird_predict,
    "quickscorer": quickscorer_predict,
}


def predict_raw(forest: Forest, x: jax.Array, algorithm: str = "predicated") -> jax.Array:
    """Per-tree raw scores [B, T] with the chosen backend."""
    try:
        fn = ALGORITHMS[algorithm]
    except KeyError:
        raise ValueError(f"unknown algorithm {algorithm!r}; options {sorted(ALGORITHMS)}")
    return fn(forest, x)
