"""Model-reuse: materialize the model-partitioning stage once, reuse forever.

Paper Sec. 3.3: the relation-centric plan needs a *model-partitioning* job
stage (split the forest into per-thread tree partitions and lay them out for
the cross-product).  Its output depends only on (model, partitioning), not on
the inference dataset, so netsDB materializes it and reuses it across queries
— netsDB-OPT in the tables, the difference between netsDB-Rel and netsDB-OPT
being exactly this stage's scheduling + materialization cost.

TPU mapping: "partition + lay out" = shard the tree-major forest arrays onto
the mesh's ``model`` axis (+ algorithm-specific side tensors: the HummingBird
path matrix, QuickScorer bitvectors, padded tree counts) and *keep the device
buffers alive*.  The cache key is (model fingerprint, mesh, plan signature);
a hit skips jnp.pad + device_put + auxiliary-tensor construction — the same
first-query vs steady-state distinction the paper measures.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any, Callable

import jax
import numpy as np

__all__ = ["MaterializedModel", "ModelReuseCache", "fingerprint_forest",
           "mesh_signature", "GLOBAL_CACHE", "GLOBAL_PLAN_CACHE"]


def mesh_signature(mesh) -> tuple | int:
    """Content-based mesh identity for cache keys (id() can be reused
    after GC — the global caches outlive engines and their meshes)."""
    if mesh is None:
        return 0
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(d.id for d in mesh.devices.flat))


def fingerprint_forest(forest) -> str:
    """Content hash of the forest's arrays + static metadata."""
    h = hashlib.sha1()
    for name, arr in sorted(forest.arrays().items()):
        h.update(name.encode())
        h.update(np.asarray(jax.device_get(arr)).tobytes())
    h.update(f"{forest.depth}|{forest.n_features}|{forest.model_type}|"
             f"{forest.task}|{forest.base_score}".encode())
    return h.hexdigest()


@dataclasses.dataclass
class MaterializedModel:
    """The output of the model-partitioning stage, device-resident."""

    forest: Any                      # padded, device-laid-out Forest
    true_num_trees: int              # pre-padding count (MEAN aggregation)
    aux: dict[str, Any]              # algorithm side tensors (C/D, bitvectors)
    partition_spec: Any              # how the tree axis is sharded
    build_time_s: float              # the cost model-reuse amortizes away


@dataclasses.dataclass
class _Stats:
    hits: int = 0
    misses: int = 0
    build_time_s: float = 0.0
    saved_time_s: float = 0.0


class ModelReuseCache:
    """Keyed materialization cache (paper's netsDB-OPT mechanism).

    Generic over the entry type: anything with a mutable ``build_time_s``
    attribute can be cached (``MaterializedModel`` for the partition stage,
    ``db.query.CompiledQueryPlan`` for jitted end-to-end stage functions —
    the paper's model-reuse optimization lifted to plan reuse).  Eviction is
    LRU: a hit refreshes the key's recency.
    """

    def __init__(self, max_entries: int = 32):
        self._entries: dict[tuple, Any] = {}
        self._order: list[tuple] = []
        self._max = max_entries
        self.stats = _Stats()

    # -- key --------------------------------------------------------------
    @staticmethod
    def make_key(model_id: str, mesh, plan_signature: str) -> tuple:
        return (model_id, mesh_signature(mesh), plan_signature)

    # -- api ----------------------------------------------------------------
    def get_or_build(
        self,
        key: tuple,
        build: Callable[[], Any],
    ) -> Any:
        entry = self._entries.get(key)
        if entry is not None:
            self.stats.hits += 1
            self.stats.saved_time_s += entry.build_time_s
            # LRU refresh: without this the cache degrades to FIFO and can
            # evict the hottest model while cold ones survive
            self._order.remove(key)
            self._order.append(key)
            return entry
        self.stats.misses += 1
        t0 = time.perf_counter()
        entry = build()
        entry.build_time_s = time.perf_counter() - t0
        self.stats.build_time_s += entry.build_time_s
        self._entries[key] = entry
        self._order.append(key)
        while len(self._order) > self._max:
            evict = self._order.pop(0)
            self._entries.pop(evict, None)
        return entry

    def invalidate(self, model_id: str | None = None, *,
                   key_index: int = 0) -> int:
        """Drop entries (all, or those for one model). Returns count.

        ``key_index`` is where the model id sits in this cache's keys:
        0 for the model cache's ``(model_id, ...)`` keys, 1 for the plan
        cache's ``(kind_tag, model_id, ...)`` keys.  Matching only
        ``key[0]`` against plan keys silently misses every entry (the
        kind tag never equals a model id) — which is why the engine-level
        sweep (``db.query.ForestQueryEngine.invalidate``) exists.
        """
        if model_id is None:
            n = len(self._entries)
            self._entries.clear()
            self._order.clear()
            return n
        victims = [k for k in self._order
                   if len(k) > key_index and k[key_index] == model_id]
        for k in victims:
            self._entries.pop(k, None)
            self._order.remove(k)
        return len(victims)

    def __len__(self) -> int:
        return len(self._entries)


# process-global default caches (one per pod; pods share nothing — DESIGN §8)
GLOBAL_CACHE = ModelReuseCache()
# compiled query plans are host objects holding jitted callables, but they
# pin device memory too: rel-plan entries hold their MaterializedModel and
# udf-plan entries their own padded forest copy — so the plan cache gets
# the same slot budget as the model cache, not more
GLOBAL_PLAN_CACHE = ModelReuseCache(max_entries=32)
