"""Phase 2 of decision-forest inference: per-tree score aggregation.

The paper (Sec. 2) splits inference into phase 1 (find the exit leaf of every
tree — ``algorithms.predict_raw``) and phase 2, which differs per family:

  RandomForest  averages all trees' exit values, then applies a sigmoid to
                produce a probability score (paper Sec. 2, describing the
                sklearn binary-classification path).
  XGBoost /     sums the exit-leaf weights (plus the base score margin) and
  LightGBM      applies a sigmoid.

Regression drops the sigmoid.  Padded identity trees (``pad_trees``) carry
zero leaves so SUM is unaffected; MEAN divides by the *true* tree count that
the padder returns.

This module is also where the relation-centric AGGREGATE operator's merge
semantics live: partial per-tree-partition results combine with ``+`` (sum of
raw scores) for every family, and only the *final* step applies mean/sigmoid
— which is what makes the paper's model-parallel psum-tree legal.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "aggregate_raw",
    "postprocess",
    "predict_proba",
    "predict_label",
]


def aggregate_raw(raw: jax.Array) -> jax.Array:
    """[B, T] per-tree scores -> [B] summed raw margin (merge-combinable)."""
    return jnp.sum(raw, axis=-1)


def postprocess(
    summed: jax.Array,
    *,
    model_type: str,
    task: str = "classification",
    num_trees: int,
    base_score: float = 0.0,
) -> jax.Array:
    """[B] summed raw scores -> [B] final prediction.

    ``num_trees`` must be the TRUE (pre-padding) tree count.
    """
    if model_type == "randomforest":
        mean = summed / jnp.asarray(num_trees, summed.dtype)
        if task == "classification":
            # Leaf values are class-1 probabilities; their mean already IS a
            # probability (sklearn semantics). The paper's prose describes an
            # extra sigmoid; applying one would push every score above 0.5,
            # so we keep the sklearn behaviour (clipped mean).
            return jnp.clip(mean, 0.0, 1.0)
        if task == "regression":
            return mean
    elif model_type in ("xgboost", "lightgbm"):
        margin = summed + jnp.asarray(base_score, summed.dtype)
        if task == "classification":
            return jax.nn.sigmoid(margin)
        if task == "regression":
            return margin
    else:
        raise ValueError(f"unknown model_type {model_type!r}")
    raise ValueError(f"unknown task {task!r}")


@partial(jax.jit, static_argnames=("model_type", "task", "num_trees", "base_score"))
def _post_jit(summed, *, model_type, task, num_trees, base_score):
    return postprocess(
        summed,
        model_type=model_type,
        task=task,
        num_trees=num_trees,
        base_score=base_score,
    )


def predict_proba(forest, x: jax.Array, *, algorithm: str = "predicated",
                  num_trees: int | None = None) -> jax.Array:
    """Convenience single-device end-to-end predict (phase 1 + phase 2)."""
    from repro.core.algorithms import predict_raw

    raw = predict_raw(forest, x, algorithm)
    return _post_jit(
        aggregate_raw(raw),
        model_type=forest.model_type,
        task=forest.task,
        num_trees=int(num_trees if num_trees is not None else forest.num_trees),
        base_score=forest.base_score,
    )


def predict_label(forest, x: jax.Array, *, algorithm: str = "predicated",
                  num_trees: int | None = None) -> jax.Array:
    p = predict_proba(forest, x, algorithm=algorithm, num_trees=num_trees)
    if forest.task == "classification":
        return (p >= 0.5).astype(jnp.int32)
    return p
