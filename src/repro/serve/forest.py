"""Online forest serving plane: micro-batch coalescing onto the
compiled-plan cache.

The paper's motivating deployments (fraud gating, ranking, admission
control) are REQUEST-serving workloads: single rows (or tiny batches)
arriving continuously, with per-request latency under concurrent
traffic as the metric — not the batch scans the in-database side of the
paper measures.  Served naively, every single-row request pays the full
``ForestQueryEngine.infer`` overhead per request: a store round-trip, a
plan-cache lookup against a one-row batch signature, possibly a fresh
trace.  This module closes that gap with the standard serving-systems
move, applied to the repo's own machinery:

  * **Micro-batch coalescing** — requests for the same registered model
    are queued and flushed together as ONE padded row batch whose size
    is drawn from a small fixed BUCKET LADDER (default 8/32/128 rows).
    Because every flushed batch has one of ``len(buckets)`` shapes, the
    steady state hits an existing ``CompiledQueryPlan`` in the
    ``ModelReuseCache`` every tick — ZERO re-tracing, verified against
    the ``plan.cache_hits`` / ``plan.cache_misses`` / ``plan.traces``
    counters of the observability plane.  Padding rows are masked and
    their predictions forced to NaN (``ForestQueryEngine.infer_rows``),
    so they can never leak into a caller's results.
  * **Latency tiers with deadline flush** — a dedicated TICKER thread
    flushes each model's queue when a bucket fills, when the oldest
    ``TIER_INTERACTIVE`` request has waited ``interactive_deadline_s``,
    or (batch-only queues, which otherwise wait for full buckets) when
    the oldest request has waited ``batch_deadline_s``.  The
    ``ForestRouter`` — the paper's technique serving the stack — gates
    the serve plane's OWN traffic: an unprioritized submit is routed
    into a tier from live request features, with the arrival-load
    feature read from the process-global ``serve.queue_depth`` metric.
    The PR 6 admission-timeout contract carries over: an interactive
    request queued past its ``timeout_s`` is SHED to the batch tier
    (``shed=True``, counted) instead of forcing a premature flush.
  * **Multi-model tenancy** — ``register_model`` pins the forest in the
    ``TensorBlockStore`` model catalog (the system of record for what
    is served); compiled plans live in the query engine's
    ``ModelReuseCache`` with plain LRU as the eviction policy, so a
    cold model's executables age out under pressure while the pin keeps
    it re-compilable — an evicted model re-serves bit-identically after
    a warmup miss.  Per-model ``stats()`` report queue-wait /
    coalesce-width / e2e p50+p99 from histogram-backed
    ``MetricsRegistry`` instruments (docs/observability.md).

``benchmarks/bench_serve.py`` drives this plane with open-loop
synthetic traffic (BENCH_serve.json); design notes in
docs/serving.md.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any

import numpy as np

from repro.core.reuse import ModelReuseCache, fingerprint_forest
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore
from repro.obs import METRICS, MetricsRegistry, TRACER
from repro.serve.router import (QUEUE_DEPTH_METRIC, TIER_BATCH,
                                TIER_INTERACTIVE, ForestRouter,
                                request_features)

__all__ = ["ForestRequest", "ServedModel", "ForestServeEngine",
           "DEFAULT_BUCKETS"]

#: the default bucket ladder: every coalesced batch is padded to the
#: smallest bucket that fits, so the compiled-plan cache sees at most
#: ``len(DEFAULT_BUCKETS)`` batch signatures per (model, plan)
DEFAULT_BUCKETS = (8, 32, 128)


@dataclasses.dataclass
class ForestRequest:
    """One in-flight serving request (a single row or a small batch)."""

    uid: int
    model: str
    rows: np.ndarray                   # [k, F] f32, k >= 1
    priority: int = TIER_BATCH         # router tier (named constants)
    timeout_s: float | None = None     # admission timeout: an interactive
    #                                    request still queued past this
    #                                    SHEDS to the batch tier (PR 6
    #                                    contract; docs/reliability.md)
    shed: bool = False
    submitted_at: float = 0.0
    admitted_at: float = 0.0           # coalesced into a tick
    finished_at: float = 0.0
    predictions: np.ndarray | None = None   # [k] on completion
    error: BaseException | None = None
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)

    @property
    def num_rows(self) -> int:
        return int(self.rows.shape[0])

    def wait(self, timeout: float | None = None) -> np.ndarray:
        """Block until served; returns the [k] predictions (raises the
        tick's error if the flush that carried this request failed)."""
        if not self.done.wait(timeout):
            raise TimeoutError(
                f"request {self.uid} not served within {timeout}s")
        if self.error is not None:
            raise self.error
        return self.predictions


@dataclasses.dataclass
class ServedModel:
    """A registered tenant: the pinned forest + its serving config and
    per-model telemetry (one ``MetricsRegistry`` per model — tenancy
    means stats never conflate tenants)."""

    name: str
    forest: Any
    model_id: str                      # content fingerprint (cache keys)
    algorithm: str
    plan: str
    metrics: MetricsRegistry = dataclasses.field(
        default_factory=MetricsRegistry)
    pending: deque = dataclasses.field(default_factory=deque)
    registered_at: float = dataclasses.field(default_factory=time.time)


class ForestServeEngine:
    """Serves registered forest models behind a micro-batch coalescer.

    Construction wires (or accepts) a ``TensorBlockStore`` +
    ``ForestQueryEngine`` pair; the engine's compiled plans live in the
    query engine's ``plan_cache`` (``ModelReuseCache``, LRU), which is
    the multi-model eviction policy.  Use as a context manager (or
    ``start()``/``stop()``) to run the ticker thread; tests and
    synchronous callers can drive ``tick()`` / ``drain()`` directly.
    """

    def __init__(self, store: TensorBlockStore | None = None, *,
                 query_engine: ForestQueryEngine | None = None,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 interactive_deadline_s: float = 0.002,
                 batch_deadline_s: float = 0.02,
                 router: ForestRouter | None = None,
                 max_plans: int = 32,
                 tick_interval_s: float = 0.0005,
                 algorithm: str = "predicated",
                 plan: str = "udf"):
        self.store = store if store is not None else TensorBlockStore()
        self.qe = query_engine if query_engine is not None else \
            ForestQueryEngine(self.store,
                              reuse_cache=ModelReuseCache(max_plans),
                              plan_cache=ModelReuseCache(max_plans))
        # bucket sizes must divide the mesh data axis (infer_rows places
        # batches under the store's data sharding) — round each rung up
        nd = max(1, self.qe.fplan.n_data)
        self.buckets = tuple(sorted({-(-int(b) // nd) * nd
                                     for b in buckets if b > 0}))
        if not self.buckets:
            raise ValueError("bucket ladder must not be empty")
        self.interactive_deadline_s = interactive_deadline_s
        self.batch_deadline_s = batch_deadline_s
        self.router = router
        self.tick_interval_s = tick_interval_s
        self.default_algorithm = algorithm
        self.default_plan = plan
        self._models: dict[str, ServedModel] = {}
        self._lock = threading.Lock()
        self._uid = 0
        self._ticker: threading.Thread | None = None
        self._running = threading.Event()
        self.last_error: BaseException | None = None
        # engine-level telemetry aggregated across tenants (per-model
        # registries hold the per-tenant view); queue depth itself is
        # the PROCESS-global serve.queue_depth counter shared with the
        # LM ServeEngine, which is what the router's arrival-load
        # feature reads
        self.metrics = MetricsRegistry()
        self._width_h = self.metrics.histogram(
            "serve.coalesce_width", bounds=tuple(
                float(b) for b in self.buckets))

    # ------------------------------------------------------------------
    # tenancy
    # ------------------------------------------------------------------
    def register_model(self, name: str, forest, *,
                       algorithm: str | None = None,
                       plan: str | None = None,
                       warmup: bool = True) -> ServedModel:
        """Register (or replace) a served model.

        Pins the forest in the store's model catalog, and — with
        ``warmup`` (default) — compiles one plan per bucket rung so the
        first real tick already hits the cache (the benchmarks' zero-
        retrace-after-warmup assertion starts here).  Replacing a name
        sweeps the old model's compiled plans first.

        ``algorithm="auto"`` / ``plan="auto"`` resolve HERE, once per
        tenant, through the cost-based optimizer's row-batch decision
        (``db/optimizer.py``) at the largest bucket signature — the
        per-request hot path then always runs a concrete, persisted
        choice."""
        algorithm = algorithm or self.default_algorithm
        plan = plan or self.default_plan
        if algorithm == "auto" or plan == "auto":
            dec = self.qe.optimizer.decide_rows(
                forest, max(self.buckets),
                algorithms=None if algorithm == "auto" else (algorithm,),
                plans=None if plan == "auto" else (plan,))
            algorithm, plan = dec.algorithm, dec.plan
        old = self._models.get(name)
        if old is not None and old.pending:
            raise RuntimeError(
                f"model {name!r} has {len(old.pending)} pending requests")
        if old is not None:
            self.qe.invalidate(old.model_id)
        mid = fingerprint_forest(forest)
        self.store.put_model(name, forest, fingerprint=mid,
                             algorithm=algorithm, plan=plan)
        m = ServedModel(name=name, forest=forest, model_id=mid,
                        algorithm=algorithm, plan=plan)
        with self._lock:
            self._models[name] = m
        if warmup:
            self.warmup(name)
        return m

    def register_from_catalog(self, name: str, *,
                              algorithm: str | None = None,
                              plan: str | None = None,
                              warmup: bool = True) -> ServedModel:
        """Serve a model already pinned in the store's model catalog —
        the in-database trainer's handoff (``ForestQueryEngine.train``
        lands its forest via ``store.put_model``; this picks it up
        without the forest ever leaving the database).  Catalog metadata
        supplies the algorithm/plan defaults when the trainer (or a
        previous registration) recorded them; explicit arguments win."""
        forest = self.store.get_model(name)
        meta = self.store.model_catalog().get(name, {})
        return self.register_model(
            name, forest,
            algorithm=algorithm or meta.get("algorithm"),
            plan=plan or meta.get("plan"),
            warmup=warmup)

    def warmup(self, name: str) -> int:
        """Compile (or re-touch) one plan per bucket rung for ``name``.
        Returns the number of plan-cache MISSES the warmup paid — 0
        means every rung was already resident."""
        m = self._get(name)
        misses = 0
        for b in self.buckets:
            x = np.zeros((b, m.forest.n_features), np.float32)
            res = self.qe.infer_rows(m.forest, x, algorithm=m.algorithm,
                                     plan=m.plan, model_id=m.model_id)
            misses += int(not res.plan_reuse_hit)
        return misses

    def unregister_model(self, name: str) -> int:
        """Drop a tenant: unpin from the store catalog and sweep its
        compiled plans + materializations.  Returns entries swept.
        Refuses while requests are pending."""
        m = self._get(name)
        if m.pending:
            raise RuntimeError(
                f"model {name!r} has {len(m.pending)} pending requests")
        with self._lock:
            self._models.pop(name, None)
        self.store.drop_model(name)
        return self.qe.invalidate(m.model_id)

    def models(self) -> dict[str, dict[str, Any]]:
        """Tenant catalog view (mirrors ``store.model_catalog()``)."""
        return {n: dict(algorithm=m.algorithm, plan=m.plan,
                        fingerprint=m.model_id, pending=len(m.pending))
                for n, m in self._models.items()}

    def _get(self, name: str) -> ServedModel:
        try:
            return self._models[name]
        except KeyError:
            raise KeyError(f"model {name!r} not registered; "
                           f"have {sorted(self._models)}")

    # ------------------------------------------------------------------
    # request path
    # ------------------------------------------------------------------
    def submit(self, model: str, rows, *, priority: int | None = None,
               timeout_s: float | None = None) -> ForestRequest:
        """Queue a request ([F] single row or [k, F] small batch) for
        ``model``.  ``priority=None`` lets the ``ForestRouter`` (when
        configured) gate the serve plane's own traffic — request
        features with the arrival-load read from the LIVE
        ``serve.queue_depth`` counter; without a router, unprioritized
        requests default to ``TIER_INTERACTIVE``.  Returns the request
        handle; ``req.wait()`` blocks for the predictions."""
        m = self._get(model)
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        if rows.shape[1] != m.forest.n_features:
            raise ValueError(
                f"request has {rows.shape[1]} features, model {model!r} "
                f"expects {m.forest.n_features}")
        if rows.shape[0] > self.buckets[-1]:
            raise ValueError(
                f"request of {rows.shape[0]} rows exceeds the largest "
                f"bucket ({self.buckets[-1]}); use infer() for scans")
        if priority is None:
            if self.router is not None:
                feats = request_features(
                    rows.shape[0], 1, None, len(self._models),
                    self._width_h.mean if self._width_h.count else 0.0)
                priority = int(self.router.route(feats))
            else:
                priority = TIER_INTERACTIVE
        with self._lock:
            self._uid += 1
            req = ForestRequest(uid=self._uid, model=model, rows=rows,
                                priority=priority, timeout_s=timeout_s,
                                submitted_at=time.perf_counter())
            # interactive requests coalesce at the queue FRONT so the
            # next flush carries them (same admission rule as the LM
            # engine's priority queue)
            if priority == TIER_INTERACTIVE:
                m.pending.appendleft(req)
            else:
                m.pending.append(req)
        m.metrics.counter("serve.requests").inc()
        METRICS.counter(QUEUE_DEPTH_METRIC).inc()
        return req

    def predict(self, model: str, rows, *,
                timeout: float | None = 30.0, **kw) -> np.ndarray:
        """Blocking convenience: submit + wait.  Without a running
        ticker the queue is drained synchronously."""
        req = self.submit(model, rows, **kw)
        if not self._running.is_set():
            self.drain()
        return req.wait(timeout)

    # ------------------------------------------------------------------
    # coalescer
    # ------------------------------------------------------------------
    def _shed_timed_out(self, m: ServedModel, now: float) -> None:
        """PR 6 admission-timeout ladder, coalescer edition: demote
        interactive requests whose wait exceeded ``timeout_s`` to the
        batch tier (queue BACK, ``shed`` flagged) — they stop pulling
        the short interactive deadline and wait for a full bucket like
        any batch-tier work."""
        with self._lock:
            kept, shed = [], []
            for req in m.pending:
                if (req.timeout_s is not None
                        and req.priority == TIER_INTERACTIVE
                        and now - req.submitted_at >= req.timeout_s):
                    req.priority = TIER_BATCH
                    req.shed = True
                    shed.append(req)
                else:
                    kept.append(req)
            if shed:
                m.pending.clear()
                m.pending.extend(kept + shed)
        for req in shed:
            m.metrics.counter("serve.shed").inc()
            TRACER.event("serve.shed", uid=req.uid)

    def _due(self, m: ServedModel, now: float) -> bool:
        """Flush policy: a full largest bucket flushes any queue;
        otherwise the oldest INTERACTIVE request flushes at the short
        deadline, and a batch-only queue — which by contract waits for
        full buckets — is bounded by the long batch deadline so a lone
        request can never starve."""
        if not m.pending:
            return False
        if sum(r.num_rows for r in m.pending) >= self.buckets[-1]:
            return True
        interactive = [r for r in m.pending
                       if r.priority == TIER_INTERACTIVE]
        if interactive:
            oldest = min(r.submitted_at for r in interactive)
            return now - oldest >= self.interactive_deadline_s
        oldest = min(r.submitted_at for r in m.pending)
        return now - oldest >= self.batch_deadline_s

    def _select(self, m: ServedModel) -> list[ForestRequest]:
        """Pop a FIFO prefix of the queue that fits the largest bucket
        (requests are never split across ticks — row order within a
        request, and across requests within a tick, is preserved)."""
        batch: list[ForestRequest] = []
        total = 0
        with self._lock:
            while m.pending and \
                    total + m.pending[0].num_rows <= self.buckets[-1]:
                req = m.pending.popleft()
                batch.append(req)
                total += req.num_rows
        return batch

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _flush(self, m: ServedModel, now: float) -> int:
        """Coalesce one padded batch for ``m`` and serve it through
        ``infer_rows``.  Returns rows served (0 if the queue was
        empty)."""
        batch = self._select(m)
        if not batch:
            return 0
        n = sum(r.num_rows for r in batch)
        bucket = self._bucket(n)
        with TRACER.span("serve.tick", model=m.name, requests=len(batch),
                         rows=n, bucket=bucket) as sp:
            with TRACER.span("serve.coalesce", model=m.name):
                F = int(m.forest.n_features)
                x = np.zeros((bucket, F), np.float32)
                mask = np.zeros(bucket, bool)
                off = 0
                for req in batch:
                    x[off:off + req.num_rows] = req.rows
                    mask[off:off + req.num_rows] = True
                    off += req.num_rows
                    req.admitted_at = now
                    m.metrics.histogram("serve.queue_wait_s").record(
                        now - req.submitted_at)
            for reg in (m.metrics, self.metrics):
                reg.counter("serve.ticks").inc()
                reg.counter("serve.padding_rows").inc(bucket - n)
                reg.histogram("serve.coalesce_width",
                              bounds=tuple(float(b) for b in self.buckets)
                              ).record(n)
            METRICS.counter(QUEUE_DEPTH_METRIC).inc(-len(batch))
            try:
                res = self.qe.infer_rows(
                    m.forest, x, row_mask=mask, algorithm=m.algorithm,
                    plan=m.plan, model_id=m.model_id)
            except BaseException as e:      # noqa: BLE001 — re-raised by
                self.last_error = e         # every waiter's .wait()
                for req in batch:
                    req.error = e
                    req.done.set()
                raise
            m.metrics.counter("serve.plan_hits" if res.plan_reuse_hit
                              else "serve.plan_misses").inc()
            sp.set(plan_hit=res.plan_reuse_hit)
            out = np.asarray(res.predictions)
            done_at = time.perf_counter()
            off = 0
            for req in batch:
                req.predictions = out[off:off + req.num_rows].copy()
                off += req.num_rows
                req.finished_at = done_at
                m.metrics.histogram("serve.e2e_latency_s").record(
                    done_at - req.submitted_at)
                req.done.set()
        return n

    def tick(self, now: float | None = None, force: bool = False) -> int:
        """One coalescer pass over every model: shed lapsed admission
        timeouts, then flush every due queue (every non-empty queue,
        with ``force``).  Returns total rows served.  The ticker thread
        calls this in a loop; tests and synchronous callers can drive
        it directly."""
        now = time.perf_counter() if now is None else now
        served = 0
        with self._lock:
            models = list(self._models.values())
        for m in models:
            self._shed_timed_out(m, now)
            while m.pending and (force or self._due(m, now)):
                served += self._flush(m, now)
        return served

    def drain(self, max_ticks: int = 10_000) -> int:
        """Force-flush until every queue is empty (synchronous callers
        / tests).  Returns total rows served."""
        served = 0
        for _ in range(max_ticks):
            if not any(m.pending for m in self._models.values()):
                break
            served += self.tick(force=True)
        return served

    # ------------------------------------------------------------------
    # ticker thread
    # ------------------------------------------------------------------
    def start(self) -> None:
        """Start the dedicated ticker thread (idempotent)."""
        if self._running.is_set():
            return
        self._running.set()

        def loop():
            while self._running.is_set():
                try:
                    if self.tick() == 0:
                        time.sleep(self.tick_interval_s)
                except BaseException:       # noqa: BLE001 — recorded on
                    # last_error + the affected requests by _flush; the
                    # ticker keeps serving other tenants
                    time.sleep(self.tick_interval_s)

        self._ticker = threading.Thread(target=loop, daemon=True,
                                        name="forest-serve-tick")
        self._ticker.start()

    def stop(self) -> None:
        """Stop the ticker thread and join it (queued work stays queued
        — call ``drain()`` to finish it synchronously)."""
        if not self._running.is_set():
            return
        self._running.clear()
        if self._ticker is not None:
            self._ticker.join(timeout=5.0)
            self._ticker = None

    def __enter__(self) -> "ForestServeEngine":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------
    def stats(self, model: str | None = None) -> dict[str, Any]:
        """Per-model serving stats (or, with ``model=None``, the
        engine-level rollup plus every tenant's row).  Percentiles come
        from the per-model histogram-backed registries."""
        if model is not None:
            m = self._get(model)
            qw = m.metrics.histogram("serve.queue_wait_s")
            e2e = m.metrics.histogram("serve.e2e_latency_s")
            cw = m.metrics.histogram(
                "serve.coalesce_width",
                bounds=tuple(float(b) for b in self.buckets))
            return {
                "requests": m.metrics.counter("serve.requests").value,
                "ticks": m.metrics.counter("serve.ticks").value,
                "shed": m.metrics.counter("serve.shed").value,
                "plan_hits": m.metrics.counter("serve.plan_hits").value,
                "plan_misses":
                    m.metrics.counter("serve.plan_misses").value,
                "padding_rows":
                    m.metrics.counter("serve.padding_rows").value,
                "pending": len(m.pending),
                "mean_coalesce_width": cw.mean if cw.count else 0.0,
                "p50_queue_wait_s": qw.percentile(50),
                "p99_queue_wait_s": qw.percentile(99),
                "p50_latency_s": e2e.percentile(50),
                "p99_latency_s": e2e.percentile(99),
            }
        return {
            "models": len(self._models),
            "queue_depth":
                METRICS.counter(QUEUE_DEPTH_METRIC).value,
            "ticks": self.metrics.counter("serve.ticks").value,
            "padding_rows":
                self.metrics.counter("serve.padding_rows").value,
            "mean_coalesce_width":
                self._width_h.mean if self._width_h.count else 0.0,
            "per_model": {n: self.stats(n) for n in self._models},
        }
