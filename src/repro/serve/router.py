"""Forest-based request router — the paper's technique serving the stack.

The paper's motivating deployments put decision forests in the serving
path (ranking, fraud gating, admission).  Here the forest routes LLM
requests into latency tiers BEFORE admission: a RandomForest over request
features (prompt length, requested tokens, arrival load, prompt entropy
proxy) predicts whether the request is 'interactive' (short — jump the
queue) or 'batch'.  The forest runs IN-PROCESS over device-resident
features via the in-database engine (``core``/``db``) — the same
data-locality argument the paper makes: no feature round-trip to an
external scorer.

The router's model is trained in-framework (core/train.py) on synthetic
traces; ``examples/rank_fusion.py`` shows the full LM→forest fusion.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.postprocess import predict_proba
from repro.core.train import TrainConfig, train_forest

__all__ = ["RouterConfig", "ForestRouter", "synth_router_trace",
           "TIER_INTERACTIVE", "TIER_BATCH"]

#: the router's latency tiers.  The serve engine admits TIER_INTERACTIVE
#: requests at the queue front and — the reliability contract — SHEDS an
#: interactive request that has waited past its admission timeout down to
#: TIER_BATCH instead of letting it camp the front of the queue forever
#: (``ServeEngine.submit(timeout_s=...)``, docs/reliability.md).
TIER_INTERACTIVE = 0
TIER_BATCH = 1


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    num_trees: int = 32
    max_depth: int = 6
    threshold: float = 0.5            # P(expensive) above => batch tier
    algorithm: str = "predicated"


FEATURES = ("prompt_len", "max_new_tokens", "queue_depth",
            "active_slots", "mean_prompt_len_recent")


def request_features(prompt_len: int, max_new_tokens: int,
                     queue_depth: int, active_slots: int,
                     mean_recent: float) -> np.ndarray:
    return np.array([prompt_len, max_new_tokens, queue_depth,
                     active_slots, mean_recent], np.float32)


def synth_router_trace(n: int = 4096, seed: int = 0):
    """Synthetic request trace with a ground-truth cost rule: a request is
    'expensive' when its token budget dominates the current load."""
    rng = np.random.default_rng(seed)
    x = np.stack([
        rng.integers(1, 512, n),          # prompt_len
        rng.integers(1, 256, n),          # max_new_tokens
        rng.integers(0, 64, n),           # queue_depth
        rng.integers(0, 8, n),            # active_slots
        rng.uniform(8, 256, n),           # mean_prompt_len_recent
    ], axis=1).astype(np.float32)
    cost = x[:, 0] * 0.5 + x[:, 1] * 2.0 + x[:, 2] * 1.5
    y = (cost > np.median(cost)).astype(np.float32)
    return x, y


class ForestRouter:
    def __init__(self, cfg: RouterConfig = RouterConfig(), *,
                 forest=None, seed: int = 0):
        self.cfg = cfg
        if forest is None:
            x, y = synth_router_trace(seed=seed)
            forest = train_forest(x, y, TrainConfig(
                model_type="randomforest", num_trees=cfg.num_trees,
                max_depth=cfg.max_depth, seed=seed))
        self.forest = forest

    def route(self, feats: np.ndarray) -> int:
        """[F] or [N, F] features -> tier(s): ``TIER_INTERACTIVE`` (0)
        or ``TIER_BATCH`` (1)."""
        x = jnp.asarray(np.atleast_2d(feats))
        p = predict_proba(self.forest, x, algorithm=self.cfg.algorithm)
        tiers = (np.asarray(p) > self.cfg.threshold).astype(int)
        return int(tiers[0]) if feats.ndim == 1 else tiers
