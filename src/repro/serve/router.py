"""Forest-based request router — the paper's technique serving the stack.

The paper's motivating deployments put decision forests in the serving
path (ranking, fraud gating, admission).  Here the forest routes LLM
requests into latency tiers BEFORE admission: a RandomForest over request
features (prompt length, requested tokens, arrival load, prompt entropy
proxy) predicts whether the request is 'interactive' (short — jump the
queue) or 'batch'.  The forest runs IN-PROCESS over device-resident
features via the in-database engine (``core``/``db``) — the same
data-locality argument the paper makes: no feature round-trip to an
external scorer.

The router's model is trained in-framework (core/train.py) on synthetic
traces; ``examples/rank_fusion.py`` shows the full LM→forest fusion.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.postprocess import predict_proba
from repro.core.train import TrainConfig, train_forest
from repro.obs import METRICS

__all__ = ["RouterConfig", "ForestRouter", "synth_router_trace",
           "TIER_INTERACTIVE", "TIER_BATCH", "QUEUE_DEPTH_METRIC",
           "live_queue_depth"]

#: the router's latency tiers.  The serve engine admits TIER_INTERACTIVE
#: requests at the queue front and — the reliability contract — SHEDS an
#: interactive request that has waited past its admission timeout down to
#: TIER_BATCH instead of letting it camp the front of the queue forever
#: (``ServeEngine.submit(timeout_s=...)``, docs/reliability.md).
TIER_INTERACTIVE = 0
TIER_BATCH = 1


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    num_trees: int = 32
    max_depth: int = 6
    threshold: float = 0.5            # P(expensive) above => batch tier
    algorithm: str = "predicated"


FEATURES = ("prompt_len", "max_new_tokens", "queue_depth",
            "active_slots", "mean_prompt_len_recent")

#: the live arrival-load instrument: every serving engine (LM
#: ``ServeEngine`` and forest ``ForestServeEngine``) increments this
#: process-global counter on submit and decrements on admission, so the
#: router's ``queue_depth`` feature reflects ACTUAL instantaneous load
#: rather than whatever a caller chose to report (docs/observability.md).
QUEUE_DEPTH_METRIC = "serve.queue_depth"


def live_queue_depth() -> float:
    """Current process-wide queued-request count (never negative: the
    counter is inc/dec'd from multiple engines and a reset mid-flight
    could otherwise expose a transient negative to the forest)."""
    return float(max(METRICS.counter(QUEUE_DEPTH_METRIC).value, 0))


def request_features(prompt_len: int, max_new_tokens: int,
                     queue_depth: float | None = None,
                     active_slots: int = 0,
                     mean_recent: float = 0.0) -> np.ndarray:
    """Feature vector for one request.  ``queue_depth=None`` (the
    default) reads the LIVE ``serve.queue_depth`` metric, so routing
    decisions shift with actual load; passing a number keeps the old
    caller-supplied behaviour (tests, offline traces)."""
    if queue_depth is None:
        queue_depth = live_queue_depth()
    return np.array([prompt_len, max_new_tokens, queue_depth,
                     active_slots, mean_recent], np.float32)


def synth_router_trace(n: int = 4096, seed: int = 0):
    """Synthetic request trace with a ground-truth cost rule: a request is
    'expensive' when its token budget dominates the current load."""
    rng = np.random.default_rng(seed)
    x = np.stack([
        rng.integers(1, 512, n),          # prompt_len
        rng.integers(1, 256, n),          # max_new_tokens
        rng.integers(0, 64, n),           # queue_depth
        rng.integers(0, 8, n),            # active_slots
        rng.uniform(8, 256, n),           # mean_prompt_len_recent
    ], axis=1).astype(np.float32)
    cost = x[:, 0] * 0.5 + x[:, 1] * 2.0 + x[:, 2] * 1.5
    y = (cost > np.median(cost)).astype(np.float32)
    return x, y


class ForestRouter:
    def __init__(self, cfg: RouterConfig = RouterConfig(), *,
                 forest=None, seed: int = 0):
        self.cfg = cfg
        if forest is None:
            x, y = synth_router_trace(seed=seed)
            forest = train_forest(x, y, TrainConfig(
                model_type="randomforest", num_trees=cfg.num_trees,
                max_depth=cfg.max_depth, seed=seed))
        self.forest = forest

    def route(self, feats: np.ndarray) -> int:
        """[F] or [N, F] features -> tier(s): ``TIER_INTERACTIVE``
        or ``TIER_BATCH`` (the named router constants — P(expensive)
        above the threshold lands in the batch tier)."""
        x = jnp.asarray(np.atleast_2d(feats))
        p = predict_proba(self.forest, x, algorithm=self.cfg.algorithm)
        tiers = np.where(np.asarray(p) > self.cfg.threshold,
                         TIER_BATCH, TIER_INTERACTIVE).astype(int)
        return int(tiers[0]) if feats.ndim == 1 else tiers
