"""Serving layer: continuous-batching engine + forest request router."""
