"""Serving layer: continuous-batching LM engine, forest request router,
and the online forest serving plane (micro-batch coalescing onto the
compiled-plan cache — serve/forest.py, docs/serving.md)."""
