"""Continuous-batching serving engine.

Slot model: the decode step runs a FIXED [B_slots] batch every tick (one
jitted program, fixed shapes — no recompilation in the steady state); each
slot carries its own cache position (per-slot ``index`` vector, see
layers.attention_decode).  New requests are prefetched into free slots
between ticks via a jitted insert (dynamic_update_slice on the batch
axis), so admission never stalls running streams — continuous batching in
the vLLM sense, with bucketed prompt lengths bounding the number of
prefill program shapes.

The engine is per-pod and shares nothing across pods (DESIGN.md §8); the
forest ROUTER (serve/router.py — the paper's technique, serving the
serving stack) classifies incoming requests into latency tiers before
admission.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.sharding import ShardingPlan, make_plan
from repro.models.registry import get_bundle
from repro.obs import METRICS, MetricsRegistry, TRACER
from repro.serve.router import (QUEUE_DEPTH_METRIC, TIER_BATCH,
                                TIER_INTERACTIVE)

Params = dict[str, Any]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [P] int32
    max_new_tokens: int = 16
    eos_token: int = -1                # -1: never stop early
    priority: int = TIER_BATCH         # router tier (TIER_INTERACTIVE
    #                                    jumps the queue; the default
    #                                    matches submit()'s)
    submitted_at: float = 0.0
    timeout_s: float | None = None     # admission timeout: an interactive
    #                                    request still queued past this
    #                                    SHEDS to the batch tier instead
    #                                    of camping the queue front
    shed: bool = False                 # it happened (docs/reliability.md)
    # filled at completion:
    tokens: list[int] = dataclasses.field(default_factory=list)
    first_token_at: float = 0.0
    finished_at: float = 0.0


def _bucket(n: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Params, *,
                 slots: int = 4, max_ctx: int = 256,
                 prompt_buckets: tuple[int, ...] = (32, 64, 128),
                 splan: ShardingPlan | None = None,
                 dtype=jnp.bfloat16):
        assert not cfg.encoder_layers, "engine serves decoder-only LMs"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_ctx = max_ctx
        self.buckets = tuple(b for b in prompt_buckets if b < max_ctx)
        self.splan = splan or make_plan(cfg, None)
        self.bundle = get_bundle(cfg)
        from repro.models import lm as LM
        self.caches = LM.init_caches(cfg, slots, max_ctx, dtype=dtype)
        self.caches["index"] = jnp.zeros((slots,), jnp.int32)
        self._free = list(range(slots))
        self._active: dict[int, Request] = {}
        self._queue: deque[Request] = deque()
        self._done: list[Request] = []
        self._remaining = np.zeros(slots, np.int64)
        self._cur_tokens = jnp.zeros((slots, 1), jnp.int32)
        self._uid = 0
        self.ticks = 0
        self.shed_count = 0            # admission timeouts shed to batch
        # per-engine observability (one ServeEngine per pod shares
        # nothing — so its metrics registry is its own, not the process
        # global): fixed-bucket latency histograms back the p50/p99
        # fields of stats(), counters mirror the scalar telemetry
        self.metrics = MetricsRegistry()
        self._queue_wait_h = self.metrics.histogram("serve.queue_wait_s")
        self._e2e_h = self.metrics.histogram("serve.e2e_latency_s")

        self._decode = jax.jit(
            lambda p, c, t: self.bundle.decode(cfg, p, c, t, self.splan))
        self._prefill = {}
        for b in self.buckets:
            self._prefill[b] = jax.jit(
                partial(self._prefill_fn, prompt_len=b))
        self._insert = jax.jit(self._insert_fn, donate_argnums=(0,))

    # ------------------------------------------------------------------
    def _prefill_fn(self, params, tokens, *, prompt_len):
        from repro.models import lm as LM
        logits, caches = LM.lm_prefill(self.cfg, params, tokens,
                                       splan=self.splan, ctx=self.max_ctx)
        return logits, caches

    def _insert_fn(self, caches, cur_tokens, cache1, slot, length,
                   first_token):
        """Copy a batch-1 prefill cache into slot ``slot``."""
        def one(path, big, small):
            name = str(getattr(path[-1], "key", ""))
            if not hasattr(big, "ndim") or big.ndim == 0 or name == "index":
                return big
            # batch axis is 0 for unstacked, 1 for stacked [nB, B, ...]
            ax = 1 if big.ndim >= 3 and big.shape[1] == self.slots else 0
            start = [0] * big.ndim
            start[ax] = slot
            return jax.lax.dynamic_update_slice(big, small.astype(big.dtype),
                                                tuple(start))
        new = jax.tree_util.tree_map_with_path(
            one, {k: v for k, v in caches.items() if k != "index"},
            {k: v for k, v in cache1.items() if k != "index"})
        new["index"] = caches["index"].at[slot].set(length)
        cur = cur_tokens.at[slot, 0].set(first_token)
        return new, cur

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, *, max_new_tokens: int = 16,
               eos_token: int = -1, priority: int = TIER_BATCH,
               timeout_s: float | None = None) -> int:
        """Queue a request.  ``timeout_s`` is the per-request admission
        timeout: an interactive (``TIER_INTERACTIVE``) request still
        waiting past it is SHED to the batch tier — demoted to the
        queue back with ``shed=True`` — rather than holding the queue
        front forever (the serve plane's degradation ladder;
        docs/reliability.md)."""
        self._uid += 1
        req = Request(self._uid, np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_token=eos_token,
                      priority=priority, submitted_at=time.perf_counter(),
                      timeout_s=timeout_s)
        # priority admission: interactive requests jump the queue
        if priority == TIER_INTERACTIVE:
            self._queue.appendleft(req)
        else:
            self._queue.append(req)
        self.metrics.counter("serve.requests").inc()
        # the process-global arrival-load gauge the forest router reads
        # (serve/router.live_queue_depth): inc on submit, dec on admit
        METRICS.counter(QUEUE_DEPTH_METRIC).inc()
        return req.uid

    def _shed_timed_out(self) -> None:
        """Admission-timeout ladder: demote interactive requests whose
        wait exceeded their ``timeout_s`` to the batch tier (queue back,
        ``shed`` flagged) so a saturated engine degrades the latecomer's
        tier instead of queueing it at the front forever."""
        now = time.perf_counter()
        kept, shed = [], []
        for req in self._queue:
            if (req.timeout_s is not None
                    and req.priority == TIER_INTERACTIVE
                    and now - req.submitted_at >= req.timeout_s):
                req.priority = TIER_BATCH
                req.shed = True
                shed.append(req)
            else:
                kept.append(req)
        if shed:
            self._queue = deque(kept + shed)
            self.shed_count += len(shed)
            self.metrics.counter("serve.shed").inc(len(shed))
            for req in shed:
                TRACER.event("serve.shed", uid=req.uid)

    def _admit_one(self, req: Request, slot: int) -> None:
        # admission ends the queue wait — recorded whether or not the
        # request was shed on the way in
        self._queue_wait_h.record(time.perf_counter() - req.submitted_at)
        METRICS.counter(QUEUE_DEPTH_METRIC).inc(-1)
        with TRACER.span("serve.prefill", uid=req.uid, slot=slot,
                         shed=req.shed):
            P = len(req.prompt)
            b = _bucket(P, self.buckets) if self.buckets else P
            if b not in self._prefill:
                self._prefill[b] = jax.jit(partial(self._prefill_fn,
                                                   prompt_len=b))
            toks = np.zeros((1, b), np.int32)
            toks[0, b - P:] = req.prompt       # left-pad into the bucket
            logits, cache1 = self._prefill[b](self.params,
                                              jnp.asarray(toks))
            first = int(jnp.argmax(logits[0]))
            self.caches, self._cur_tokens = self._insert(
                self.caches, self._cur_tokens, cache1, slot, b, first)
        req.tokens.append(first)
        req.first_token_at = time.perf_counter()
        self._active[slot] = req
        self._remaining[slot] = req.max_new_tokens - 1

    def step(self) -> list[Request]:
        """One engine tick: admit into free slots, one decode step, collect
        finished requests.  Returns newly finished requests."""
        self._shed_timed_out()
        while self._free and self._queue:
            self._admit_one(self._queue.popleft(), self._free.pop())
        if not self._active:
            return []
        with TRACER.span("serve.execute", tick=self.ticks,
                         active=len(self._active)):
            logits, self.caches = self._decode(self.params, self.caches,
                                               self._cur_tokens)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self._cur_tokens = nxt[:, None]
            nxt_np = np.asarray(jax.device_get(nxt))
        self.ticks += 1
        finished = []
        for slot, req in list(self._active.items()):
            if self._remaining[slot] <= 0:
                continue
            tok = int(nxt_np[slot])
            req.tokens.append(tok)
            self._remaining[slot] -= 1
            idx = int(jax.device_get(self.caches["index"][slot]))
            if self._remaining[slot] <= 0 or tok == req.eos_token \
                    or idx >= self.max_ctx - 1:
                req.finished_at = time.perf_counter()
                self._e2e_h.record(req.finished_at - req.submitted_at)
                finished.append(req)
                self._done.append(req)
                del self._active[slot]
                self._free.append(slot)
        return finished

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        t = 0
        while (self._queue or self._active) and t < max_ticks:
            self.step()
            t += 1
        return self._done

    # ------------------------------------------------------------------
    def stats(self) -> dict[str, float]:
        if not self._done:
            return {}
        lat = [r.finished_at - r.submitted_at for r in self._done]
        ttft = [r.first_token_at - r.submitted_at for r in self._done]
        toks = sum(len(r.tokens) for r in self._done)
        span = max(r.finished_at for r in self._done) - \
            min(r.submitted_at for r in self._done)
        return {
            "requests": len(self._done),
            "mean_latency_s": float(np.mean(lat)),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "mean_ttft_s": float(np.mean(ttft)),
            "tokens": toks,
            "tokens_per_s": toks / max(span, 1e-9),
            "ticks": self.ticks,
            "shed": self.shed_count,
            # bucket-interpolated tails from the per-engine histograms
            # (obs.Histogram; docs/observability.md) — queue wait is
            # submit -> admission, e2e is submit -> last token
            "p50_queue_wait_s": self._queue_wait_h.percentile(50),
            "p99_queue_wait_s": self._queue_wait_h.percentile(99),
            "p50_latency_s": self._e2e_h.percentile(50),
            "p99_latency_s": self._e2e_h.percentile(99),
        }
