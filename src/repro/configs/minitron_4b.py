"""Minitron-4B: width/depth-pruned Nemotron; squared-ReLU (non-gated) MLP
[arXiv:2407.14679]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    mlp_type="sq_relu",
    norm_type="ln",
    pos_type="rope",
    source="arXiv:2407.14679; hf:nvidia/Minitron-4B-Base",
)
