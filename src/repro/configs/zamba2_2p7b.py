"""Zamba2-2.7B: hybrid Mamba2 backbone + ONE SHARED attention block invoked
every 6 layers with per-invocation LoRA deltas [arXiv:2411.15242].

The shared block attends over the concat(hidden, initial-embedding) stream
(2*d_model input), the Zamba trick that lets one attention block serve the
whole depth.  54 Mamba2 layers, 9 shared-attention call sites."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_layers=True,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_width=4,
    shared_attn_every=6,
    shared_attn_lora_rank=128,
    mlp_type="gelu",
    norm_type="rmsnorm",
    pos_type="rope",
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B",
)
