"""Config registry: one module per assigned architecture (+ forest grid).

``get_config(arch_id)`` returns the exact published configuration;
``repro.configs.base.reduced`` shrinks it for CPU smoke tests.
"""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, reduced

ARCH_IDS = [
    "yi-34b",
    "olmo-1b",
    "qwen2-7b",
    "minitron-4b",
    "mamba2-2.7b",
    "llama4-scout-17b-a16e",
    "llama4-maverick-400b-a17b",
    "seamless-m4t-large-v2",
    "zamba2-2.7b",
    "chameleon-34b",
]

_MODULES = {
    "yi-34b": "yi_34b",
    "olmo-1b": "olmo_1b",
    "qwen2-7b": "qwen2_7b",
    "minitron-4b": "minitron_4b",
    "mamba2-2.7b": "mamba2_2p7b",
    "llama4-scout-17b-a16e": "llama4_scout",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "seamless-m4t-large-v2": "seamless_m4t",
    "zamba2-2.7b": "zamba2_2p7b",
    "chameleon-34b": "chameleon_34b",
}


def get_config(arch_id: str) -> ModelConfig:
    try:
        mod_name = _MODULES[arch_id]
    except KeyError:
        raise ValueError(f"unknown arch {arch_id!r}; options {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


__all__ = ["ARCH_IDS", "get_config", "ModelConfig", "ShapeConfig", "SHAPES",
           "reduced"]
