"""Qwen2-7B: dense GQA transformer with QKV BIAS [arXiv:2407.10671]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    pos_type="rope",
    rope_theta=1_000_000.0,
    source="arXiv:2407.10671; hf:Qwen/Qwen2-7B",
)
