"""Chameleon-34B: early-fusion VLM; decoder-only backbone over a mixed
VQ-image + text token vocabulary with QK-norm [arXiv:2405.09818].

The image tokenizer (VQ-VAE) is a STUB per the assignment: ``input_specs()``
feeds already-quantized token ids drawn from the unified 65536 vocab."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    pos_type="rope",
    frontend="vq_tokens",
    source="arXiv:2405.09818; hf:facebook/chameleon-30b",
)
