"""Model / shape configuration schema for the assigned architecture pool.

One ``ModelConfig`` fully determines an architecture; one ``ShapeConfig``
determines an input-shape cell; the dry-run grid is their cross product.
``reduced()`` shrinks any config to a CPU-smoke-test size without changing
its family-specific structure (same block pattern, same norm/MoE/SSM
choices) — the smoke tests exercise STRUCTURE, the dry-run exercises SCALE.
"""

from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | moe | audio | hybrid | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # ---- attention ----
    qkv_bias: bool = False
    qk_norm: bool = False
    pos_type: str = "rope"           # rope | nope | irope
    rope_theta: float = 10_000.0
    attn_window: int = 0             # >0: chunked-local attention window
    global_every: int = 0            # iRoPE: every Nth layer global (NoPE)
    causal: bool = True
    # ---- mlp ----
    mlp_type: str = "swiglu"         # swiglu | sq_relu | gelu
    # ---- norm ----
    norm_type: str = "rmsnorm"       # rmsnorm | nonparam_ln | ln
    # ---- embeddings ----
    tie_embeddings: bool = False
    # ---- MoE ----
    num_experts: int = 0             # 0 = dense
    top_k: int = 1
    shared_expert: bool = False
    moe_every: int = 1               # 1 = every layer MoE; 2 = alternating
    capacity_factor: float = 1.25
    # ---- SSM (mamba2 / hybrid) ----
    ssm_layers: bool = False         # True: backbone layers are Mamba2 blocks
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # ---- hybrid (zamba2) ----
    shared_attn_every: int = 0       # >0: shared attn block every N layers
    shared_attn_lora_rank: int = 0
    # ---- enc-dec (seamless) ----
    encoder_layers: int = 0          # >0: encoder-decoder model
    dec_len_ratio: int = 4           # encoder length / decoder length
    # ---- modality frontend stub ----
    frontend: str = "none"           # none | audio_frames | vq_tokens
    # ---- numerics / schedule ----
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"       # full | dots (save dot outputs) | none
    logit_chunk: int = 1024          # chunked cross-entropy block
    moe_decode_ep: bool = False      # EP psum decode-MoE (hillclimb knob)
    attn_kv_chunk: int = 1024        # blockwise-attention KV chunk length
    source: str = ""                 # provenance note

    # ------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Vocab padded so the 'model' axis (16) divides it."""
        return -(-self.vocab_size // 256) * 256

    @property
    def block_period(self) -> int:
        """Layer-pattern period for scan-over-blocks."""
        p = 1
        if self.global_every:
            p = _lcm(p, self.global_every)
        if self.moe_every > 1:
            p = _lcm(p, self.moe_every)
        if self.shared_attn_every:
            p = _lcm(p, self.shared_attn_every)
        return p

    @property
    def num_blocks(self) -> int:
        assert self.num_layers % self.block_period == 0, (
            f"{self.name}: layers {self.num_layers} not divisible by "
            f"period {self.block_period}")
        return self.num_layers // self.block_period

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS = 6·N·D)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top_k experts)."""
        return _param_count(self, active_only=True)


def _lcm(a: int, b: int) -> int:
    from math import gcd
    return a * b // gcd(a, b)


def _param_count(cfg: ModelConfig, *, active_only: bool) -> int:
    D, Fh = cfg.d_model, cfg.d_ff
    H, KV, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    total = cfg.vocab_padded * D * (1 if cfg.tie_embeddings else 2)

    def attn_params():
        p = D * (H * dh) + 2 * D * (KV * dh) + (H * dh) * D
        if cfg.qkv_bias:
            p += (H + 2 * KV) * dh
        return p

    def mlp_params(ff):
        mats = 3 if cfg.mlp_type == "swiglu" else 2
        return mats * D * ff

    def ssm_params():
        di = cfg.d_inner
        nh = cfg.ssm_heads
        # in_proj -> [z, x, B, C, dt], conv over (x,B,C), out_proj
        proj_in = D * (2 * di + 2 * cfg.ssm_state + nh)
        conv = cfg.conv_width * (di + 2 * cfg.ssm_state)
        return proj_in + conv + di * D + 2 * nh

    n_layers = cfg.num_layers
    # hybrid (zamba2): the per-layer MLP belongs to the SHARED block, not to
    # each Mamba layer.
    layer_has_mlp = Fh > 0 and not (cfg.ssm_layers and cfg.shared_attn_every)
    for i in range(n_layers):
        is_moe = (cfg.num_experts > 0 and (i % cfg.moe_every) == 0)
        if cfg.ssm_layers:
            total += ssm_params()
        else:
            total += attn_params()
        if is_moe:
            e = cfg.top_k if active_only else cfg.num_experts
            total += e * mlp_params(Fh) + D * cfg.num_experts  # + router
            if cfg.shared_expert:
                total += mlp_params(Fh)
        elif layer_has_mlp:
            total += mlp_params(Fh)
    if cfg.shared_attn_every:
        n_slots = n_layers // cfg.shared_attn_every
        shared_d = 2 * cfg.d_model   # zamba2 concatenates embeds
        p = (shared_d * (H * dh) + 2 * shared_d * (KV * dh) + (H * dh) * D)
        p += 2 * shared_d * Fh       # the shared block's (gelu) MLP
        total += p + n_slots * cfg.shared_attn_lora_rank * 2 * shared_d
    if cfg.encoder_layers:
        for _ in range(cfg.encoder_layers):
            total += attn_params() + mlp_params(Fh)
        # decoder cross-attention
        total += n_layers * attn_params()
    return total


# ---------------------------------------------------------------------------
# Input shapes (assigned): every LM arch pairs with these four cells.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, *, layers: int | None = None,
            d_model: int = 64, vocab: int = 512) -> ModelConfig:
    """Shrink to smoke-test size, preserving the structural pattern."""
    period = cfg.block_period
    n_layers = layers or max(period, 2 if period == 1 else period)
    n_layers = -(-n_layers // period) * period
    head_dim = 16
    n_heads = max(2, d_model // head_dim)
    n_kv = max(1, min(cfg.num_kv_heads, n_heads) //
               max(1, cfg.num_heads // max(n_heads, 1)) or 1)
    n_kv = max(1, n_heads // max(1, cfg.num_heads // max(1, cfg.num_kv_heads)))
    while n_heads % n_kv:
        n_kv -= 1
    return dataclasses.replace(
        cfg,
        num_layers=n_layers,
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=0 if cfg.d_ff == 0 else d_model * 4,
        vocab_size=vocab,
        num_experts=min(cfg.num_experts, 4),
        encoder_layers=0 if cfg.encoder_layers == 0 else 2,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_layers else cfg.ssm_headdim,
        ssm_chunk=32,
        shared_attn_lora_rank=min(cfg.shared_attn_lora_rank, 4),
        attn_window=min(cfg.attn_window, 64) if cfg.attn_window else 0,
        logit_chunk=64,
        remat=False,
    )
