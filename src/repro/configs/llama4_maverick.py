"""Llama-4-Maverick-400B-A17B: MoE with 128 experts top-1 + shared expert,
ALTERNATING dense/MoE layers, iRoPE like Scout
[hf:meta-llama/Llama-4-Maverick-17B-128E]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    top_k=1,
    shared_expert=True,
    moe_every=2,            # alternating dense / MoE
    attn_window=8192,
    global_every=4,
    pos_type="irope",
    mlp_type="swiglu",
    norm_type="rmsnorm",
    qk_norm=True,
    moe_decode_ep=True,   # §Perf: EP-local+psum decode beats weight gathers 6.5x
    source="hf:meta-llama/Llama-4-Maverick-17B-128E",
)
