"""OLMo-1B: dense transformer with NON-PARAMETRIC LayerNorm (no scale/bias),
tied embeddings, SwiGLU [arXiv:2402.00838]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,      # MHA (kv == heads)
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    mlp_type="swiglu",
    norm_type="nonparam_ln",
    tie_embeddings=True,
    pos_type="rope",
    source="arXiv:2402.00838; hf:allenai/OLMo-1B",
)
