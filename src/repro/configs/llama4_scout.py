"""Llama-4-Scout-17B-16E: MoE (16 experts, top-1, + shared expert) with
iRoPE: 3 of every 4 layers use chunked-local RoPE attention (window 8192),
every 4th layer is global NoPE [hf:meta-llama/Llama-4-Scout-17B-16E]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    top_k=1,
    shared_expert=True,
    moe_every=1,            # every layer MoE
    attn_window=8192,
    global_every=4,         # every 4th layer: global attention, NoPE
    pos_type="irope",
    mlp_type="swiglu",
    norm_type="rmsnorm",
    qk_norm=True,
    moe_decode_ep=True,   # §Perf: EP-local+psum decode beats weight gathers 6.5x
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
