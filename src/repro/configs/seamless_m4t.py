"""SeamlessM4T-large-v2: encoder-decoder multimodal translator
[arXiv:2308.11596].  The speech frontend is a STUB per the assignment:
``input_specs()`` feeds precomputed frame embeddings to the encoder; the
text decoder is a standard causal transformer with cross-attention.
Decoder length = encoder length / 4 (speech-to-text ratio, DESIGN.md §4).
vocab 256206 pads to 256256."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,           # decoder layers
    encoder_layers=24,
    dec_len_ratio=4,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    mlp_type="gelu",
    norm_type="ln",
    pos_type="rope",
    frontend="audio_frames",
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large",
)
