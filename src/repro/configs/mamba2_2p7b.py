"""Mamba2-2.7B: attention-free SSM stack using the SSD (state-space duality)
chunked algorithm [arXiv:2405.21060].

d_ff=0: Mamba2 blocks have no separate MLP; the block IS the mixer
(in_proj -> conv -> SSD -> gated out_proj with expand factor 2).
vocab 50280 pads to 50432 for the model-axis sharding (DESIGN.md Sec. 5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,          # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_layers=True,
    ssm_state=128,
    ssm_headdim=64,       # 80 heads = 2*2560 / 64
    ssm_expand=2,
    ssm_chunk=256,
    conv_width=4,
    norm_type="rmsnorm",
    pos_type="nope",      # SSM needs no positional encoding
    source="arXiv:2405.21060; hf:state-spaces/mamba2-2.7b",
)
