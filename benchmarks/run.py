"""Benchmark aggregator: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--fast]`` runs the reduced
grid (CPU-minutes); ``--full`` runs the paper tree grid {10, 500, 1600}
everywhere.  Output: CSV blocks per section plus a final
``name,us_per_call,derived`` summary (one line per table, total seconds
of the netsDB-best platform vs the standalone baseline)."""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import common as C


def _summary(rows, table):
    """Best in-DB total vs best standalone total per (dataset, trees)."""
    out = []
    bykey = {}
    for r in rows:
        key = (r.get("dataset"), r.get("trees"))
        bykey.setdefault(key, []).append(r)
    for (ds, T), rs in bykey.items():
        indb = [r for r in rs if str(r["platform"]).startswith("netsdb")]
        ext = [r for r in rs if str(r["platform"]).startswith("standalone")]
        if not indb or not ext:
            continue
        b_in = min(indb, key=lambda r: r["total_s"])
        b_ex = min(ext, key=lambda r: r["total_s"])
        speedup = b_ex["total_s"] / max(b_in["total_s"], 1e-9)
        out.append(C.csv_line(
            f"{table}/{ds}/trees{T}", b_in["total_s"],
            f"best_indb={b_in['platform']} speedup_vs_standalone="
            f"{speedup:.2f}x"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--scale", type=float, default=None)
    args = ap.parse_args()
    trees = C.TREE_GRID if args.full else ((10, 100) if args.fast
                                           else (10, 500))
    scale = args.scale if args.scale is not None else \
        (0.25 if args.fast else 1.0)

    summary = []
    t_start = time.time()

    from benchmarks import bench_small
    print("## Tab2-3: small dense datasets (fraud, year)")
    rows = bench_small.run(trees=trees, scale=scale)
    C.print_rows(rows)
    summary += _summary(rows, "tab2-3")

    from benchmarks import bench_large
    print("\n## Tab4-6: medium/large dense datasets (higgs scaled)")
    rows = bench_large.run(datasets=("higgs",) if not args.full else
                           ("higgs", "airline", "tpcxai"),
                           trees=trees, scale=scale)
    C.print_rows(rows)
    summary += _summary(rows, "tab4-6")

    from benchmarks import bench_wide_sparse
    print("\n## Tab7-9: wide/sparse datasets (bosch, epsilon, criteo)")
    rows = bench_wide_sparse.run(trees=trees, scale=scale)
    C.print_rows(rows, extra_cols=("file_kind",))
    summary += _summary(rows, "tab7-9")

    from benchmarks import bench_algorithms
    print("\n## Tab10: single-device inference-only algorithm comparison")
    rows = bench_algorithms.run(trees=trees, batch=1024)
    C.print_rows(rows)
    for r in rows:
        summary.append(C.csv_line(
            f"tab10/{r['platform']}/trees{r['trees']}", r["infer_s"]))

    from benchmarks import bench_conversion
    print("\n## Fig8: model conversion + loading overheads")
    rows = bench_conversion.run(trees_grid=trees)
    C.print_rows(rows)
    for r in rows:
        summary.append(C.csv_line(
            f"fig8/{r['platform']}/trees{r['trees']}", r["total_s"],
            "compile+convert"))

    from benchmarks import bench_batching
    print("\n## Sec7: batching / vectorization granularity")
    rows = bench_batching.run(trees=trees[-1], scale=scale)
    C.print_rows(rows)
    for r in rows:
        summary.append(C.csv_line(
            f"sec7/{r['platform']}", r["total_s"]))

    print(f"\n## summary (name,us_per_call,derived) "
          f"[total bench wall: {time.time() - t_start:.0f}s]")
    for line in summary:
        print(line)


if __name__ == "__main__":
    main()
