"""Benchmark aggregator: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--fast]`` runs the reduced
grid (CPU-minutes); ``--full`` runs the paper tree grid {10, 500, 1600}
everywhere.  Output: CSV blocks per section plus a final
``name,us_per_call,derived`` summary (one line per table, total seconds
of the netsDB-best platform vs the standalone baseline)."""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import common as C


def _summary(rows, table):
    """Best in-DB total vs best standalone total per (dataset, trees)."""
    out = []
    bykey = {}
    for r in rows:
        key = (r.get("dataset"), r.get("trees"))
        bykey.setdefault(key, []).append(r)
    for (ds, T), rs in bykey.items():
        indb = [r for r in rs if str(r["platform"]).startswith("netsdb")]
        ext = [r for r in rs if str(r["platform"]).startswith("standalone")]
        if not indb or not ext:
            continue
        b_in = min(indb, key=lambda r: r["total_s"])
        b_ex = min(ext, key=lambda r: r["total_s"])
        speedup = b_ex["total_s"] / max(b_in["total_s"], 1e-9)
        out.append(C.csv_line(
            f"{table}/{ds}/trees{T}", b_in["total_s"],
            f"best_indb={b_in['platform']} speedup_vs_standalone="
            f"{speedup:.2f}x"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--scale", type=float, default=None)
    args = ap.parse_args()
    trees = C.TREE_GRID if args.full else ((10, 100) if args.fast
                                           else (10, 500))
    scale = args.scale if args.scale is not None else \
        (0.25 if args.fast else 1.0)

    summary = []
    t_start = time.time()

    from benchmarks import bench_small
    print("## Tab2-3: small dense datasets (fraud, year)")
    rows = bench_small.run(trees=trees, scale=scale)
    C.print_rows(rows)
    summary += _summary(rows, "tab2-3")

    from benchmarks import bench_large
    print("\n## Tab4-6: medium/large dense datasets (higgs scaled)")
    rows = bench_large.run(datasets=("higgs",) if not args.full else
                           ("higgs", "airline", "tpcxai"),
                           trees=trees, scale=scale)
    C.print_rows(rows)
    summary += _summary(rows, "tab4-6")

    print("\n## Out-of-core streaming scan: host tier + double-buffered DMA")
    srows, stream_records = bench_large.run_stream(
        trees=(trees[0],) if args.fast else trees[:2], scale=scale)
    C.print_rows(srows)
    stream_path = bench_large.write_stream_json(stream_records)
    for r in stream_records:
        summary.append(C.csv_line(
            f"stream/{r['dataset']}/{r['plan']}/trees{r['trees']}",
            r["stream_wall_s"],
            f"overlap={r['overlap_fraction']} batches={r['batches']} "
            f"budget={r['device_budget_bytes']}B"))
    print(f"# streaming trajectory -> {stream_path}")

    from benchmarks import bench_faults
    print("\n## Fault plane: zero-fault overhead + recovery latency")
    frows, fault_records = bench_faults.run(
        trees=trees[0] if args.fast else trees[-1],
        scale=min(scale, 0.25), iters=3 if args.fast else 5)
    C.print_rows(frows)
    fault_path = bench_faults.write_faults_json(fault_records)
    for r in fault_records:
        wall = r["instrumented_wall_s"] if r["recovery_wall_s"] is None \
            else r["recovery_wall_s"]
        summary.append(C.csv_line(
            f"faults/{r['scenario']}", wall,
            f"overhead={r['overhead_fraction']:+.1%} "
            f"vs_baseline={r['baseline_wall_s']}s"))
    print(f"# fault trajectory -> {fault_path}")

    from benchmarks import bench_obs
    print("\n## Observability plane: armed-tracing overhead")
    orows, obs_records = bench_obs.run(
        trees=trees[0] if args.fast else trees[-1],
        scale=min(scale, 0.25), iters=3 if args.fast else 5)
    C.print_rows(orows)
    obs_path = bench_obs.write_obs_json(obs_records)
    for r in obs_records:
        summary.append(C.csv_line(
            f"obs/{r['scenario']}", r["traced_wall_s"],
            f"overhead={r['overhead_fraction']:+.1%} "
            f"spans={r['spans_recorded']} "
            f"cross_thread={r['cross_thread_spans']}"))
    print(f"# obs trajectory -> {obs_path}")

    from benchmarks import bench_wide_sparse
    print("\n## Tab7-9: wide/sparse datasets (bosch, epsilon, criteo)")
    rows = bench_wide_sparse.run(trees=trees, scale=scale)
    C.print_rows(rows, extra_cols=("file_kind",))
    summary += _summary(rows, "tab7-9")

    print("\n## Sparse plane: CSR pages + gather prepass vs dense fallback")
    rows, sparse_records = bench_wide_sparse.run_sparse(
        trees=(trees[0],) if args.fast else trees[:2], scale=scale)
    C.print_rows(rows, extra_cols=("file_kind",))
    sparse_path = bench_wide_sparse.write_sparse_json(sparse_records)
    for r in sparse_records:
        summary.append(C.csv_line(
            f"sparse/{r['dataset']}/trees{r['trees']}", r["csr_total_s"],
            f"csr_vs_dense={r['csr_vs_dense']}x density={r['density']}"))
    print(f"# sparse trajectory -> {sparse_path}")

    from benchmarks import bench_algorithms
    print("\n## Tab10: single-device inference-only algorithm comparison")
    rows = bench_algorithms.run(trees=trees, batch=1024)
    C.print_rows(rows)
    for r in rows:
        summary.append(C.csv_line(
            f"tab10/{r['platform']}/trees{r['trees']}", r["infer_s"]))

    print("\n## Fused-vs-unfused kernel trajectory (BENCH_fused.json)")
    rows, fused_records = bench_algorithms.run_fused(
        trees=(trees[-1],) if args.fast else bench_algorithms.FUSED_TREE_GRID,
        batch=256 if args.fast else 512, iters=3 if args.fast else 5)
    C.print_rows(rows)
    print("\n## Mesh-size rows: shard_map tree-parallel fused kernel stage")
    mrows, mesh_records = bench_algorithms.run_fused_mesh(
        trees=(trees[-1],) if args.fast
        else (bench_algorithms.FUSED_TREE_GRID[0],),
        batch=128 if args.fast else 256, iters=2 if args.fast else 3)
    C.print_rows(mrows)
    fused_path = bench_algorithms.write_fused_json(
        fused_records + mesh_records)
    for r in fused_records:
        summary.append(C.csv_line(
            f"fused/{r['algorithm']}/trees{r['trees']}", r["fused_s"],
            f"speedup={r['speedup']}x bf16_speedup={r['bf16_speedup']}x"))
    for r in mesh_records:
        summary.append(C.csv_line(
            f"fused-mesh/{r['algorithm']}/trees{r['trees']}", r["mesh_s"],
            f"devices={r['mesh_devices']} mesh={r['mesh']}"))
    print(f"# fused trajectory -> {fused_path}")

    from benchmarks import bench_serve
    print("\n## Online serving: open-loop coalesced vs per-request")
    srows, serve_records = bench_serve.run(
        trees_grid=(bench_serve.MODEL_TREES[0],) if args.fast
        else bench_serve.MODEL_TREES,
        duration_s=0.4 if args.fast else 1.0,
        max_requests=400 if args.fast else 1200)
    C.print_rows(srows, extra_cols=("rate_hz",))
    serve_path = bench_serve.write_serve_json(serve_records)
    for r in serve_records:
        summary.append(C.csv_line(
            f"serve/{r['model']}/rate{r['rate_hz']}", r["p50_ms"] / 1e3,
            f"speedup_p50={r['speedup_p50']}x "
            f"width={r['mean_coalesce_width']} "
            f"retrace={0 if r['zero_retrace'] else 1}"))
    print(f"# serve trajectory -> {serve_path}")

    from benchmarks import bench_optimizer
    print("\n## Cost-based optimizer: auto vs static regret grid")
    opt_records = bench_optimizer.run_grid(
        bench_optimizer.SMOKE_TREES if args.fast
        else bench_optimizer.GRID_TREES,
        bench_optimizer.SMOKE_ROWS if args.fast
        else bench_optimizer.GRID_ROWS,
        iters=2 if args.fast else 3)
    bench_optimizer.print_records(opt_records)
    bench_optimizer.check(opt_records, context="run.py optimizer")
    opt_path = bench_optimizer.write_optimizer_json(opt_records)
    for r in opt_records:
        summary.append(C.csv_line(
            f"optimizer/trees{r['trees']}/rows{r['rows']}", r["auto_s"],
            f"auto={r['auto_algorithm']}+{r['auto_plan']} "
            f"regret={r['regret_vs_best']}x "
            f"win={r['win_vs_worst']}x"))
    print(f"# optimizer trajectory -> {opt_path}")

    from benchmarks import bench_conversion
    print("\n## Fig8: model conversion + loading overheads")
    rows = bench_conversion.run(trees_grid=trees)
    C.print_rows(rows)
    for r in rows:
        summary.append(C.csv_line(
            f"fig8/{r['platform']}/trees{r['trees']}", r["total_s"],
            "compile+convert"))

    from benchmarks import bench_batching
    print("\n## Sec7: batching / vectorization granularity")
    rows = bench_batching.run(trees=trees[-1], scale=scale)
    C.print_rows(rows)
    for r in rows:
        summary.append(C.csv_line(
            f"sec7/{r['platform']}", r["total_s"]))

    print(f"\n## summary (name,us_per_call,derived) "
          f"[total bench wall: {time.time() - t_start:.0f}s]")
    for line in summary:
        print(line)


if __name__ == "__main__":
    main()
