"""Paper Tab. 10: inference-ONLY algorithm comparison (single 'thread' —
one CPU device here), across tree counts.  Claim: QuickScorer has the
best single-thread latency; the naive traversal is the slowest; the
tensorized (HummingBird) form pays for its dense path tensors.

Also benchmarks the Pallas kernels in interpret mode — NOT a wall-clock
claim (interpret mode is a Python emulator; the compiled-TPU story lives
in §Roofline) but a per-call overhead record, so the kernel path is
exercised by the same harness.

FUSED section (``run_fused`` / BENCH_fused.json): jitted fused
(in-kernel SUM aggregation, no [B, T] round-trip) vs jitted unfused
(predict + aggregate_raw) for every Pallas backend on the 500/1600-tree
grid.  Off-TPU both run through the compiled interpreter path, so the
comparison isolates exactly the materialization the fusion removes; the
JSON is the perf trajectory record for this optimization from this PR
onward."""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.algorithms import ALGORITHMS, predict_raw
from repro.core.postprocess import aggregate_raw

ALGOS = ("naive", "predicated", "compiled", "hummingbird", "quickscorer")
FUSED_TREE_GRID = (500, 1600)
BENCH_FUSED_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fused.json")


def _time(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(dataset="higgs", trees=(10, 500, 1600), batch=2048,
        include_naive_upto=100, include_pallas=False):
    rows = []
    x, _ = C.bench_data(dataset, scale=1.0)
    x = jnp.asarray(x[:batch])
    for T in trees:
        forest = C.get_forest(dataset, "xgboost", T)
        for algo in ALGOS:
            if algo == "naive" and T > include_naive_upto:
                continue  # per-(sample,tree) while_loop: prohibitive
            fn = jax.jit(lambda xx, a=algo: predict_raw(forest, xx, a))
            dt = _time(fn, x)
            rows.append(dict(dataset=dataset, model="xgboost", trees=T,
                             platform=f"algo-{algo}", load_s=0.0,
                             infer_s=round(dt, 5), write_s=0.0,
                             total_s=round(dt, 5),
                             checksum=float(jnp.sum(fn(x)))))
        if include_pallas and T <= 100:
            from repro.kernels.ops import KERNEL_ALGORITHMS
            xs = x[:64]
            for name, kfn in KERNEL_ALGORITHMS.items():
                dt = _time(lambda xx: kfn(forest, xx, interpret=True), xs,
                           warmup=0, iters=1)
                rows.append(dict(dataset=dataset, model="xgboost", trees=T,
                                 platform=f"pallas-{name}(interp)",
                                 load_s=0.0, infer_s=round(dt, 5),
                                 write_s=0.0, total_s=round(dt, 5),
                                 checksum=0.0))
    return rows


def run_fused(dataset="higgs", trees=FUSED_TREE_GRID, batch=512, iters=3):
    """Fused vs unfused Pallas backends, jitted end to end.

    Returns (rows, records): rows in the common CSV schema, records as the
    BENCH_fused.json trajectory entries {trees, algorithm, unfused_s,
    fused_s, bf16_s, speedup, bf16_speedup, batch, backend}.  The bf16
    row stages the tree tiles (thresholds/leaves) at half width with f32
    accumulation — off-TPU the timing mostly tracks the cast overhead;
    on TPU it is the tree-tile VMEM/bandwidth shrink record.
    """
    from repro.kernels.ops import FUSED_KERNEL_ALGORITHMS, KERNEL_ALGORITHMS

    x, _ = C.bench_data(dataset, scale=1.0)
    x = jnp.asarray(x[:batch])
    rows, records = [], []
    for T in trees:
        forest = C.get_forest(dataset, "xgboost", T)
        for name, kfn in KERNEL_ALGORITHMS.items():
            fname = name + "_fused"
            ffn = FUSED_KERNEL_ALGORITHMS[fname]
            unfused = jax.jit(lambda xx, f=kfn: aggregate_raw(f(forest, xx)))
            fused = jax.jit(lambda xx, f=ffn: f(forest, xx))
            fused_bf16 = jax.jit(
                lambda xx, f=ffn: f(forest, xx, tree_dtype=jnp.bfloat16))

            t_un = C.time_best(unfused, x, iters=iters)
            t_fu = C.time_best(fused, x, iters=iters)
            t_bf = C.time_best(fused_bf16, x, iters=iters)
            for plat, dt, fn in ((f"pallas-{name}+agg", t_un, unfused),
                                 (f"pallas-{fname}", t_fu, fused),
                                 (f"pallas-{fname}-bf16", t_bf, fused_bf16)):
                rows.append(dict(dataset=dataset, model="xgboost", trees=T,
                                 platform=plat, load_s=0.0,
                                 infer_s=round(dt, 5), write_s=0.0,
                                 total_s=round(dt, 5),
                                 checksum=float(jnp.sum(fn(x)))))
            records.append(dict(trees=T, algorithm=name, batch=batch,
                                unfused_s=round(t_un, 5),
                                fused_s=round(t_fu, 5),
                                bf16_s=round(t_bf, 5),
                                speedup=round(t_un / max(t_fu, 1e-9), 3),
                                bf16_speedup=round(t_un / max(t_bf, 1e-9),
                                                   3),
                                **C.env_info()))
    return rows, records


def run_fused_mesh(dataset="higgs", trees=(500,), batch=256, iters=3,
                   algorithm="predicated"):
    """Mesh-size trajectory rows for BENCH_fused.json.

    Measures the rel plan's kernel stage in isolation: the single-device
    fused call (all trees, one launch) vs the shard_map form — the tree
    axis sharded over the mesh ``model`` axis, ONE local fused launch per
    device, one psum.  With a single host device the mesh row degenerates
    to the single-device call (recorded with mesh_devices=1), so the
    trajectory file always carries a mesh-size row; the CI multi-device
    smoke and TPU runs fill in the >1 points.  Off-TPU both paths run the
    interpret-mode kernel, so treat multi-device CPU numbers as overhead
    records, not speedup claims.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.core.forest import pad_trees
    from repro.kernels.ops import FUSED_KERNEL_ALGORITHMS

    fn = FUSED_KERNEL_ALGORITHMS[algorithm + "_pallas_fused"]
    devs = jax.devices()
    D = len(devs)
    mesh = (Mesh(np.array(devs).reshape(1, D), ("data", "model"))
            if D > 1 else None)
    x, _ = C.bench_data(dataset, scale=1.0)
    x = jnp.asarray(x[:batch])
    rows, records = [], []

    for T in trees:
        forest = C.get_forest(dataset, "xgboost", T)
        single = jax.jit(lambda xx: fn(forest, xx))
        t_single = C.time_best(single, x, iters=iters)
        if mesh is not None:
            fp, _ = pad_trees(forest, D)
            shardings = jax.tree_util.tree_map(
                lambda _: NamedSharding(mesh, P("model")), fp)
            fp = jax.device_put(fp, shardings)

            def body(xl, fl):
                return jax.lax.psum(fn(fl, xl), "model")

            sm = jax.jit(shard_map(
                body, mesh=mesh, in_specs=(P("data", None), P("model")),
                out_specs=P("data"), check_rep=False))
            t_mesh = C.time_best(sm, x, fp, iters=iters)
        else:
            t_mesh = t_single
        rows.append(dict(dataset=dataset, model="xgboost", trees=T,
                         platform=f"pallas-{algorithm}_fused@mesh{D}",
                         load_s=0.0, infer_s=round(t_mesh, 5), write_s=0.0,
                         total_s=round(t_mesh, 5), checksum=0.0))
        records.append(dict(kind="mesh", trees=T, algorithm=algorithm,
                            batch=batch,
                            single_device_s=round(t_single, 5),
                            mesh_s=round(t_mesh, 5),
                            mesh_speedup=round(t_single / max(t_mesh, 1e-9),
                                               3),
                            **C.env_info(mesh)))
    return rows, records


def write_fused_json(records, path=BENCH_FUSED_JSON):
    payload = {"bench": "fused_vs_unfused", "created_at": time.time(),
               "env": C.env_info(), "records": records}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trees", default="10,500,1600")
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="reduced batch/iters; the fused section keeps the "
                         "500/1600 grid (its claim lives there)")
    ap.add_argument("--no-fused", action="store_true")
    ap.add_argument("--fused-out", default=BENCH_FUSED_JSON)
    args = ap.parse_args()
    trees = tuple(int(t) for t in args.trees.split(","))
    if args.fast:
        trees = tuple(t for t in trees if t <= 100) or (10, 100)
    C.print_rows(run(trees=trees, batch=min(args.batch, 512) if args.fast
                     else args.batch, include_pallas=args.pallas))
    if not args.no_fused:
        rows, records = run_fused(
            batch=256 if args.fast else 512,
            iters=3 if args.fast else 5)
        C.print_rows(rows)
        mrows, mrecords = run_fused_mesh(
            trees=(trees[-1],) if args.fast else (FUSED_TREE_GRID[0],),
            batch=128 if args.fast else 256,
            iters=2 if args.fast else 3)
        C.print_rows(mrows, header=False)
        path = write_fused_json(records + mrecords, args.fused_out)
        ok = all(r["speedup"] > 1.0 for r in records)
        ndev = mrecords[-1]["mesh_devices"] if mrecords else 1
        print(f"# fused trajectory -> {path}  "
              f"(all fused faster: {ok}; mesh rows at {ndev} device(s))")


if __name__ == "__main__":
    main()
