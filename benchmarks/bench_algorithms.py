"""Paper Tab. 10: inference-ONLY algorithm comparison (single 'thread' —
one CPU device here), across tree counts.  Claim: QuickScorer has the
best single-thread latency; the naive traversal is the slowest; the
tensorized (HummingBird) form pays for its dense path tensors.

Also benchmarks the Pallas kernels in interpret mode — NOT a wall-clock
claim (interpret mode is a Python emulator; the compiled-TPU story lives
in §Roofline) but a per-call overhead record, so the kernel path is
exercised by the same harness."""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.algorithms import ALGORITHMS, predict_raw

ALGOS = ("naive", "predicated", "compiled", "hummingbird", "quickscorer")


def _time(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(dataset="higgs", trees=(10, 500, 1600), batch=2048,
        include_naive_upto=100, include_pallas=False):
    rows = []
    x, _ = C.bench_data(dataset, scale=1.0)
    x = jnp.asarray(x[:batch])
    for T in trees:
        forest = C.get_forest(dataset, "xgboost", T)
        for algo in ALGOS:
            if algo == "naive" and T > include_naive_upto:
                continue  # per-(sample,tree) while_loop: prohibitive
            fn = jax.jit(lambda xx, a=algo: predict_raw(forest, xx, a))
            dt = _time(fn, x)
            rows.append(dict(dataset=dataset, model="xgboost", trees=T,
                             platform=f"algo-{algo}", load_s=0.0,
                             infer_s=round(dt, 5), write_s=0.0,
                             total_s=round(dt, 5),
                             checksum=float(jnp.sum(fn(x)))))
        if include_pallas and T <= 100:
            from repro.kernels.ops import KERNEL_ALGORITHMS
            xs = x[:64]
            for name, kfn in KERNEL_ALGORITHMS.items():
                dt = _time(lambda xx: kfn(forest, xx, interpret=True), xs,
                           warmup=0, iters=1)
                rows.append(dict(dataset=dataset, model="xgboost", trees=T,
                                 platform=f"pallas-{name}(interp)",
                                 load_s=0.0, infer_s=round(dt, 5),
                                 write_s=0.0, total_s=round(dt, 5),
                                 checksum=0.0))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trees", default="10,500,1600")
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--pallas", action="store_true")
    args = ap.parse_args()
    trees = tuple(int(t) for t in args.trees.split(","))
    C.print_rows(run(trees=trees, batch=args.batch,
                     include_pallas=args.pallas))


if __name__ == "__main__":
    main()
