"""Observability-plane trajectory (BENCH_obs.json): what tracing costs.

Two jobs, same gate discipline as ``bench_faults.py``:

  * ARMED-TRACING OVERHEAD — the fused streamed scan with ``obs.TRACER``
    fully enabled (every span and event of docs/observability.md
    recording) vs the same scan with the tracer disabled (the default:
    every trace point returns the shared ``NULL_SPAN``, allocating
    nothing).  Spans live in Python driver code strictly off the jitted
    hot path, so the measured overhead must stay within
    ``OVERHEAD_BOUND`` (5%) — ``run`` RAISES past it, making the bench
    double as the regression smoke for the whole instrumentation layer.
  * TRACE VALIDITY (``--smoke``, the CI obs-smoke job) — a streamed
    scan under a forced tiny tier ladder with tracing enabled, whose
    exported Chrome trace is validated structurally: JSON round-trip,
    non-empty, every span event carrying ``ph``/``ts``/``dur``/``tid``,
    spans NESTED (parent_id chains resolve, the cross-thread
    ``scan.drain_write`` -> ``scan.batch`` edge included).

Timing protocol: warm once (compile), then min-of-``iters`` of the
scan's own ``wall_s`` — the shared trajectory protocol.  The traced
iterations re-arm (enable + reset) the tracer each pass so every
measured scan records a full span tree, not an amortized tail.

Every record field and exported name is documented in
``docs/observability.md`` (enforced by ``benchmarks/check_docs.py``).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks import common as C
from repro.core.reuse import ModelReuseCache
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore
from repro.obs import TRACER

ALGO = "predicated_pallas_fused"
OVERHEAD_BOUND = 0.05
BENCH_OBS_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_obs.json")


def validate_chrome_trace(payload: dict) -> dict:
    """Structural validation of an exported Chrome trace (used by the
    CI smoke and tests/test_obs.py).  Round-trips through json, checks
    the trace-event contract on every row, and resolves parent chains.
    Returns summary counts; raises on any violation."""
    data = json.loads(json.dumps(payload))        # serializability
    events = data["traceEvents"]
    if not events:
        raise RuntimeError("exported trace is empty")
    spans = {}
    for ev in events:
        if not isinstance(ev.get("name"), str) or "ph" not in ev:
            raise RuntimeError(f"malformed trace event: {ev}")
        if ev["ph"] == "X":
            for field in ("ts", "dur", "tid", "pid"):
                if not isinstance(ev.get(field), (int, float)):
                    raise RuntimeError(
                        f"span {ev['name']!r} missing numeric {field}")
            spans[ev["args"]["span_id"]] = ev
    nested = cross_thread = 0
    for ev in spans.values():
        pid = ev["args"].get("parent_id")
        if pid is None:
            continue
        parent = spans.get(pid)
        if parent is None:
            raise RuntimeError(
                f"span {ev['name']!r} parent_id {pid} unresolved")
        nested += 1
        if parent["tid"] != ev["tid"]:
            cross_thread += 1
    if nested == 0:
        raise RuntimeError("no nested spans in exported trace")
    threads = {ev["tid"] for ev in events if ev["ph"] == "X"}
    return {"events": len(events), "spans": len(spans), "nested": nested,
            "cross_thread": cross_thread, "threads": len(threads)}


def run(dataset="higgs", trees=100, scale=0.25, iters=5, plan="udf",
        batch_pages=4, page_rows=512, strict=True):
    """Returns (rows, records).  Raises (``strict``) if the armed-tracing
    overhead breaches ``OVERHEAD_BOUND``, tracing changes predictions,
    or the traced run's exported trace fails structural validation."""
    x, _ = C.bench_data(dataset, scale=scale)
    budget = max(x.nbytes // 4, 1)          # host tier by construction
    store = TensorBlockStore(default_page_rows=page_rows,
                             device_budget_bytes=budget)
    stored = store.put(dataset, x)
    assert stored.tier == "host", stored.tier
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                               plan_cache=ModelReuseCache())
    forest = C.get_forest(dataset, "xgboost", trees)
    kw = dict(algorithm=ALGO, plan=plan, batch_pages=batch_pages)
    base = dict(dataset=dataset, model="xgboost", trees=trees,
                algorithm=ALGO, plan=plan, tier=stored.tier,
                rows=x.shape[0], features=x.shape[1],
                batch_pages=batch_pages, iters=iters)

    def one(traced: bool):
        if traced:
            TRACER.reset()
            TRACER.enable()
        try:
            return engine.infer(dataset, forest, **kw)
        finally:
            TRACER.disable()

    engine.infer(dataset, forest, **kw)      # warm: compile lands here
    # INTERLEAVED pairs, not two separately-timed groups: machine drift
    # (thermal, co-tenant load) between group A and group B otherwise
    # reads as tracing overhead — on shared CI runners the drift alone
    # exceeds the 5% bound.  Alternating per round exposes both sides
    # to the same drift; min-of-iters then compares best-case to
    # best-case as usual.
    base_walls, traced_walls = [], []
    clean = traced = None
    for _ in range(iters):
        clean = one(False)
        base_walls.append(clean.scan.wall_s)
        traced = one(True)
        traced_walls.append(traced.scan.wall_s)
    base_s, traced_s = min(base_walls), min(traced_walls)
    ref = np.asarray(clean.predictions)
    overhead = traced_s / max(base_s, 1e-9) - 1.0
    if not np.array_equal(np.asarray(traced.predictions), ref):
        raise RuntimeError("enabling the tracer changed predictions")
    if traced.trace is None or not traced.trace.num_spans:
        raise RuntimeError("traced run produced no TraceSummary spans")
    shape = validate_chrome_trace(TRACER.export_chrome())
    if strict and overhead > OVERHEAD_BOUND:
        raise RuntimeError(
            f"armed-tracing overhead {overhead:.1%} breaches the "
            f"{OVERHEAD_BOUND:.0%} bound — span bookkeeping leaked onto "
            f"the hot path")
    records = [dict(scenario="tracing_overhead",
                    baseline_wall_s=round(base_s, 5),
                    traced_wall_s=round(traced_s, 5),
                    overhead_fraction=round(overhead, 4),
                    overhead_bound=OVERHEAD_BOUND,
                    within_bound=bool(overhead <= OVERHEAD_BOUND),
                    spans_recorded=traced.trace.num_spans,
                    batches=traced.scan.batches,
                    trace_events=shape["events"],
                    nested_spans=shape["nested"],
                    cross_thread_spans=shape["cross_thread"],
                    threads=shape["threads"],
                    parity=True, **base, **C.env_info(engine.mesh))]
    rows = [{**base, "platform": "obs-disabled", "load_s": 0.0,
             "infer_s": round(base_s, 4), "write_s": 0.0,
             "total_s": round(base_s, 4)},
            {**base, "platform": "obs-traced", "load_s": 0.0,
             "infer_s": round(traced_s, 4), "write_s": 0.0,
             "total_s": round(traced_s, 4)}]
    return rows, records


def smoke(device_budget_bytes=262144, host_budget_bytes=262144,
          out=None, page_rows=64):
    """The CI obs-smoke job: stream a scan down the forced tier ladder
    (budgets default to 256 KiB, so the dataset lands on DISK) with
    tracing enabled, then validate the exported Chrome trace.  Raises
    on any structural violation; prints the trace shape on success."""
    x, _ = C.bench_data("fraud", scale=0.5)   # [6000, 28] f32 ≈ 656 KiB
    store = TensorBlockStore(default_page_rows=page_rows,
                             device_budget_bytes=device_budget_bytes,
                             host_budget_bytes=host_budget_bytes)
    stored = store.put("obs-smoke", x)
    if x.nbytes > host_budget_bytes:
        assert stored.tier == "disk", stored.tier
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                               plan_cache=ModelReuseCache())
    forest = C.get_forest("fraud", "xgboost", 10, depth=4)
    TRACER.reset()
    TRACER.enable()
    try:
        res = engine.infer("obs-smoke", forest, algorithm=ALGO)
    finally:
        TRACER.disable()
    if res.trace is None or not res.trace.span_counts.get("scan.batch"):
        raise RuntimeError("smoke scan recorded no batch spans")
    payload = TRACER.export_chrome(out)
    shape = validate_chrome_trace(payload)
    print(f"# obs-smoke ok: tier={stored.tier} "
          f"batches={res.scan.batches} spans={shape['spans']} "
          f"nested={shape['nested']} cross_thread={shape['cross_thread']} "
          f"threads={shape['threads']}"
          + (f" -> {out}" if out else ""))
    return shape


def write_obs_json(records, path=BENCH_OBS_JSON):
    payload = {"bench": "observability", "created_at": time.time(),
               "env": C.env_info(), "records": records}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: traced streamed scan + trace validation"
                         " only (no BENCH_obs.json)")
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--trees", type=int, default=None)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--device-budget-bytes", type=int, default=262144)
    ap.add_argument("--host-budget-bytes", type=int, default=262144)
    ap.add_argument("--trace-out", default=None,
                    help="--smoke: also write the exported trace here")
    ap.add_argument("--out", default=BENCH_OBS_JSON)
    args = ap.parse_args()
    if args.smoke:
        smoke(device_budget_bytes=args.device_budget_bytes,
              host_budget_bytes=args.host_budget_bytes,
              out=args.trace_out)
        return
    rows, records = run(
        trees=args.trees or (10 if args.fast else 100),
        scale=args.scale or (0.1 if args.fast else 0.25),
        iters=args.iters or (3 if args.fast else 5))
    C.print_rows(rows)
    path = write_obs_json(records, args.out)
    ov = records[0]
    print(f"# obs trajectory -> {path}  (armed-tracing overhead "
          f"{ov['overhead_fraction']:+.1%}, bound {OVERHEAD_BOUND:.0%})")


if __name__ == "__main__":
    main()
