"""Shared benchmark harness.

Reproduces the paper's end-to-end methodology at CPU scale: every
workload measures data loading, inference, and result writing separately
(paper Sec. 4 'Target Scenarios').  'Platform' mapping (DESIGN.md §3):

  standalone-<algo>   external store (CSV / LIBSVM / array-rows file) →
                      host parse → convert → device transfer → inference →
                      host write.  Stands in for the decoupled platforms
                      (sklearn / ONNX / TreeLite / lleaves / HB classes —
                      <algo> picks the F1 algorithm they implement).
  netsdb-udf          tensor-block-store-resident data, UDF-centric plan
                      (data parallelism, 1 pipeline stage).
  netsdb-rel          relation-centric plan (model parallelism,
                      partition + cross-product + aggregate stages).
  netsdb-opt          relation-centric + model reuse (steady state).

Row counts are scaled from Tab. 1 by --scale (default fits CPU minutes);
tree counts keep the paper grid {10, 500, 1600} unless --fast.
Trained models are cached on disk so repeated benches don't retrain.
"""

from __future__ import annotations

import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.forest import Forest, make_forest
from repro.core.postprocess import predict_proba
from repro.core.reuse import ModelReuseCache
from repro.core.train import TrainConfig, train_forest
from repro.db import loader as ld
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_cache")


def env_info(mesh=None) -> dict:
    """Execution-environment fields stamped on every BENCH_*.json record,
    so single- and multi-device trajectory rows never get conflated.

    ``mesh`` is the Mesh the measured path actually ran under (None =
    single device): ``mesh_devices`` is the device count it spanned,
    ``mesh`` its axis signature, ``host_devices`` what the process had
    available (e.g. 8 under XLA_FLAGS=--xla_force_host_platform_
    device_count=8).
    """
    sig = None
    if mesh is not None:
        sig = "x".join(f"{a}={int(mesh.shape[a])}" for a in mesh.axis_names)
    return {
        "backend": jax.default_backend(),
        "host_devices": len(jax.devices()),
        "mesh_devices": int(mesh.size) if mesh is not None else 1,
        "mesh": sig,
    }

# CPU-scale replicas of the paper's datasets (rows after test-split)
BENCH_ROWS = {
    "fraud": 12_000, "year": 16_000, "higgs": 40_000, "airline": 80_000,
    "tpcxai": 100_000, "bosch": 6_000, "epsilon": 2_000, "criteo": 8_000,
}
TREE_GRID = (10, 500, 1600)
FAST_TREE_GRID = (10, 100)


def time_best(fn, *args, iters: int = 3) -> float:
    """Warm (compile) once, then min-of-``iters`` wall time — the shared
    timing protocol for the kernel-level trajectory benches."""
    jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return min(times)


def get_forest(dataset: str, model_type: str, n_trees: int,
               *, depth: int = 8, train_rows: int = 4000) -> Forest:
    import dataclasses as _dc
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR,
                        f"{dataset}_{model_type}_{n_trees}_{depth}.npz")
    rows, F, task, nan_frac, kind = ld.DATASETS[dataset]
    F = int(F if dataset != "criteo" else 10_000)
    if os.path.exists(path):
        z = np.load(path)
        return make_forest(z["feature"], z["threshold"], z["leaf_value"],
                           default_left=z["default_left"],
                           node_is_leaf=z["node_is_leaf"],
                           node_value=z["node_value"], n_features=F,
                           model_type=model_type, task=task)
    x, y = ld.synth_dataset(dataset, max_rows=train_rows, seed=1)
    # wide datasets: train on a feature prefix (histogram cost ~ N·F·bins;
    # bench claims are about DATA-PATH latency, not forest quality — the
    # trained split indices stay valid against the full-width data)
    num_bins = 32
    if x.shape[1] > 512:
        x = x[:, :512]
        num_bins = 16
    cfg = TrainConfig(model_type=model_type, task=task, num_trees=n_trees,
                      max_depth=depth, learning_rate=0.1,
                      num_bins=num_bins)
    forest = train_forest(x, y, cfg)
    forest = _dc.replace(forest, n_features=F)
    np.savez(path, **{k: np.asarray(v) for k, v in forest.arrays().items()})
    return forest


def bench_data(dataset: str, *, scale: float = 1.0, seed: int = 0):
    n = max(int(BENCH_ROWS[dataset] * scale), 256)
    return ld.synth_dataset(dataset, max_rows=n, seed=seed)


# ---------------------------------------------------------------------------
# platform runners — all return a dict of timings + predictions checksum
# ---------------------------------------------------------------------------


def _finish(name, load_s, infer_s, write_s, preds):
    return {
        "platform": name, "load_s": round(load_s, 4),
        "infer_s": round(infer_s, 4), "write_s": round(write_s, 4),
        "total_s": round(load_s + infer_s + write_s, 4),
        "checksum": float(jnp.sum(preds)),
    }


def run_standalone(forest: Forest, file_path: str, file_kind: str,
                   algorithm: str, *, n_features: int,
                   batch_rows: int = 2048, out_dir: str = "/tmp"):
    # batch_rows 2048 keeps the HummingBird path's [B, T, I(, L)]
    # intermediates ~1 GB at 1600 trees (paper F3: batch size trades
    # utilization against working set — here against host RAM)
    """External path: parse + convert + transfer, batched inference, write."""
    if file_kind == "csv":
        dev, timing = ld.load_csv_external(file_path)
    elif file_kind == "libsvm":
        dev, _, timing = ld.load_libsvm_external(file_path, n_features)
    elif file_kind == "array":
        dev, timing = ld.load_array_rows_external(file_path)
    else:
        raise ValueError(file_kind)
    t0 = time.perf_counter()
    preds = []
    for lo in range(0, dev.shape[0], batch_rows):
        preds.append(predict_proba(forest, dev[lo:lo + batch_rows],
                                   algorithm=algorithm))
    preds = jnp.concatenate(preds)
    preds.block_until_ready()
    infer_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = os.path.join(out_dir, "preds_standalone.npy")
    np.save(out, np.asarray(preds))
    write_s = time.perf_counter() - t0
    return _finish(f"standalone-{algorithm}", timing.total_s, infer_s,
                   write_s, preds)


def run_netsdb(forest: Forest, store: TensorBlockStore, dataset: str,
               plan: str, algorithm: str = "predicated",
               *, engine: ForestQueryEngine | None = None,
               batch_pages: int | None = None):
    """In-database path: data already resident; run the query plan."""
    engine = engine or ForestQueryEngine(store,
                                         reuse_cache=ModelReuseCache())
    res = engine.infer(dataset, forest, algorithm=algorithm, plan=plan,
                       batch_pages=batch_pages, write_as="preds_out")
    name = {"udf": "netsdb-udf", "rel": "netsdb-rel",
            "rel+reuse": "netsdb-opt"}[plan]
    return {
        "platform": name, "load_s": 0.0,
        "infer_s": round(res.infer_s + res.partition_s, 4),
        "write_s": round(res.write_s + res.aggregate_s, 4),
        "total_s": round(res.total_s, 4),
        "checksum": float(jnp.sum(res.predictions)),
    }


def print_rows(rows, *, header=True, extra_cols=()):
    cols = ["dataset", "model", "trees", "platform", "load_s", "infer_s",
            "write_s", "total_s", *extra_cols]
    if header:
        print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))


def csv_line(name: str, seconds: float, derived: str = "") -> str:
    """run.py contract: ``name,us_per_call,derived``."""
    return f"{name},{seconds * 1e6:.1f},{derived}"
