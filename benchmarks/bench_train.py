"""In-database streamed training through the tier ladder (BENCH_train.json).

The lifecycle's other half, measured honestly: train a 100-tree model ON
a stored dataset whose float32 source is >= 4x the host budget — so the
source relation lives on the DISK tier and every pass (quantile sketch,
uint8 bin ingest, per-level histogram scans) must stream page batches
through the same ``StreamingScanExecutor`` the inference plans use.  No
pass may ever hold the full matrix: every scan's peak single-batch bytes
are asserted below the source size and ``TrainResult.materialized_full_x``
must stay ``False``.

Gates (raise on violation — smoke AND full run, so a published
BENCH_train.json can never show a broken contract):

  parity            the streamed forest must be BIT-IDENTICAL to the
                    resident ``core.train.train_forest`` reference given
                    the streamed run's own sketch edges (the reference
                    reads the matrix resident — it is the checker, not
                    the streamed path);
  tiering           source tier must resolve to ``disk`` (the 256 KiB
                    ladder actually engaged);
  streaming         every executor pass: ``batches > 1``,
                    ``max_in_flight <= 2`` (double-buffer bound),
                    ``bytes_streamed > 0``, peak batch < source bytes;
  no densify        ``materialized_full_x`` is ``False`` — a silent
                    full-X fallback fails the run;
  scan count        ``num_scans == 2 + trees * (depth + 1)`` (sketch +
                    bin ingest + per-level/per-tree histogram passes).

``--smoke`` is the CI train-smoke job: 20 trees, same dataset geometry,
same gates, no JSON.  The full run trains 100 trees and writes
``BENCH_train.json`` (field contract: ``docs/training.md``).

    PYTHONPATH=src python -m benchmarks.bench_train [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks import common as C
from repro.core.train import TrainConfig, train_forest
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore

BENCH_TRAIN_JSON = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_train.json")

ROWS = 8_192
FEATURES = 32            # 8192 x 32 f32 = 1 MiB = 4x the 256 KiB budget
PAGE_ROWS = 256
TREES = 100
SMOKE_TREES = 20
DEPTH = 3
NUM_BINS = 32
SKETCH_ROWS = 2_048      # < ROWS so the sketch actually samples
NAN_FRAC = 0.05          # exercise the MISSING bin end to end


def _dataset(seed: int = 0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(ROWS, FEATURES)).astype(np.float32)
    x[rng.random((ROWS, FEATURES)) < NAN_FRAC] = np.nan
    margin = np.where(np.isnan(x[:, 0]), 0.3, np.nan_to_num(x[:, 0])) \
        + 0.5 * np.nan_to_num(x[:, 3])
    y = (margin > 0).astype(np.float32)
    return x, y


def run(trees: int, *, device_budget: int, host_budget: int):
    x, y = _dataset()
    cfg = TrainConfig(model_type="xgboost", num_trees=trees,
                      max_depth=DEPTH, num_bins=NUM_BINS, seed=0)
    store = TensorBlockStore(default_page_rows=PAGE_ROWS,
                             device_budget_bytes=device_budget,
                             host_budget_bytes=host_budget)
    src = store.put("train-src", x, labels=y)
    engine = ForestQueryEngine(store)

    t0 = time.perf_counter()
    res = engine.train("train-src", cfg, sketch_rows=SKETCH_ROWS)
    streamed_s = time.perf_counter() - t0

    # resident reference on the SAME edges — the checker, not the path
    t0 = time.perf_counter()
    ref = train_forest(x, y, cfg, edges=res.edges)
    resident_s = time.perf_counter() - t0

    import jax
    parity = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(res.forest),
                        jax.tree_util.tree_leaves(ref)))

    bins_ds = store.get(res.bins_dataset)
    peak_batch = max(
        (s.bytes_streamed + max(s.batches - 1, 1) - 1)
        // max(s.batches, 1) for s in res.scan_stats)
    record = dict(
        rows=ROWS, features=FEATURES, page_rows=PAGE_ROWS,
        nan_frac=NAN_FRAC,
        device_budget_bytes=device_budget,
        host_budget_bytes=host_budget,
        source_nbytes=int(src.nbytes),
        source_tier=res.tier,
        storage_format=res.storage_format,
        bins_tier=bins_ds.tier,
        bins_nbytes=int(bins_ds.nbytes),
        num_trees=trees, max_depth=DEPTH, num_bins=NUM_BINS,
        sketch_rows=SKETCH_ROWS,
        sketch_rows_used=res.sketch_rows_used,
        num_scans=res.num_scans,
        batches_total=sum(s.batches for s in res.scan_stats),
        bytes_streamed_total=sum(s.bytes_streamed
                                 for s in res.scan_stats),
        peak_batch_bytes=int(peak_batch),
        max_in_flight=max(s.max_in_flight for s in res.scan_stats),
        streamed_s=round(streamed_s, 4),
        resident_s=round(resident_s, 4),
        streamed_over_resident=round(
            streamed_s / max(resident_s, 1e-9), 4),
        parity_bitwise=bool(parity),
        materialized_full_x=bool(res.materialized_full_x),
        fingerprint=res.fingerprint,
        model_name=res.model_name,
    )
    return record


def check(r, *, context: str) -> None:
    """The gates — raise on any violation."""
    if r["source_tier"] != "disk":
        raise RuntimeError(
            f"{context}: source landed on tier {r['source_tier']!r}, "
            f"not 'disk' — the {r['host_budget_bytes']}-byte ladder "
            f"never engaged ({r['source_nbytes']} source bytes)")
    if r["source_nbytes"] < 4 * r["host_budget_bytes"]:
        raise RuntimeError(
            f"{context}: dataset is only {r['source_nbytes']} bytes, "
            f"< 4x the {r['host_budget_bytes']}-byte host budget")
    if not r["parity_bitwise"]:
        raise RuntimeError(
            f"{context}: streamed forest is NOT bit-identical to the "
            f"resident reference on identical edges")
    if r["materialized_full_x"]:
        raise RuntimeError(
            f"{context}: training fell back to materializing the full "
            f"matrix (materialized_full_x=True)")
    want = 2 + r["num_trees"] * (r["max_depth"] + 1)
    if r["num_scans"] != want:
        raise RuntimeError(
            f"{context}: {r['num_scans']} executor passes, expected "
            f"{want} (sketch + bin ingest + trees*(depth+1))")
    if r["batches_total"] <= r["num_scans"]:
        raise RuntimeError(
            f"{context}: {r['batches_total']} batches over "
            f"{r['num_scans']} scans — some pass ran single-batch, "
            f"nothing streamed")
    if r["max_in_flight"] > 2:
        raise RuntimeError(
            f"{context}: {r['max_in_flight']} device page buffers in "
            f"flight — double-buffer bound broken")
    if r["bytes_streamed_total"] <= 0:
        raise RuntimeError(f"{context}: no bytes streamed")
    if r["peak_batch_bytes"] >= r["source_nbytes"]:
        raise RuntimeError(
            f"{context}: a single batch moved {r['peak_batch_bytes']} "
            f"bytes >= the {r['source_nbytes']}-byte source — that is "
            f"a full materialization, not streaming")


def write_train_json(record, path=BENCH_TRAIN_JSON):
    payload = {
        "bench": "train",
        "created_at": time.time(),
        "protocol": {
            "parity": "streamed forest bitwise == resident reference "
                      "given the streamed run's sketch edges",
            "tier_ladder": "f32 source >= 4x host budget -> disk",
        },
        "env": C.env_info(),
        "record": record,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return os.path.normpath(path)


def print_record(r) -> None:
    print(f"  trees={r['num_trees']} depth={r['max_depth']} "
          f"rows={r['rows']} features={r['features']} "
          f"tier={r['source_tier']}")
    print(f"  scans={r['num_scans']} batches={r['batches_total']} "
          f"streamed={r['bytes_streamed_total'] / 1e6:.1f}MB "
          f"peak_batch={r['peak_batch_bytes'] / 1e3:.0f}KB "
          f"in_flight<={r['max_in_flight']}")
    print(f"  streamed={r['streamed_s']:.2f}s "
          f"resident={r['resident_s']:.2f}s "
          f"({r['streamed_over_resident']:.2f}x)  "
          f"parity={'BITWISE' if r['parity_bitwise'] else 'BROKEN'}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: 20 trees, full tier ladder + parity "
                         "assertions; no JSON")
    ap.add_argument("--device-budget-bytes", type=int, default=262_144)
    ap.add_argument("--host-budget-bytes", type=int, default=262_144)
    args = ap.parse_args()
    trees = SMOKE_TREES if args.smoke else TREES
    record = run(trees, device_budget=args.device_budget_bytes,
                 host_budget=args.host_budget_bytes)
    print_record(record)
    check(record, context="train-smoke" if args.smoke else "bench_train")
    if args.smoke:
        print(f"# train-smoke ok: {trees} trees streamed off "
              f"{record['source_tier']} bit-identical to resident, "
              f"{record['num_scans']} scans, no full-X materialization")
        return
    path = write_train_json(record)
    print(f"# train trajectory -> {path}")


if __name__ == "__main__":
    main()
