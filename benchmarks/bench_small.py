"""Paper Tab. 2–3: end-to-end latency on SMALL dense datasets (Fraud,
Year).  Claim under test: data loading dominates, so in-database
inference wins at every model size; netsdb-udf best for small models,
netsdb-opt best for large (reuse repairs rel's fixed stage overheads)."""

from __future__ import annotations

import argparse
import os
import tempfile

import numpy as np

from benchmarks import common as C
from repro.db import loader as ld
from repro.db.query import ForestQueryEngine
from repro.core.reuse import ModelReuseCache
from repro.db.store import TensorBlockStore

ALGO = "predicated"
STANDALONE_ALGOS = ("predicated", "hummingbird", "quickscorer")


def run(datasets=("fraud", "year"), trees=C.TREE_GRID,
        model_types=("xgboost",), scale=1.0):
    rows = []
    for ds in datasets:
        x, y = C.bench_data(ds, scale=scale)
        with tempfile.TemporaryDirectory() as td:
            csv = os.path.join(td, f"{ds}.csv")
            ld.write_csv(csv, x)
            store = TensorBlockStore(default_page_rows=1024)
            store.put(ds, x)
            engine = ForestQueryEngine(store,
                                       reuse_cache=ModelReuseCache())
            for mt in model_types:
                for T in trees:
                    forest = C.get_forest(ds, mt, T)
                    base = dict(dataset=ds, model=mt, trees=T)
                    for algo in STANDALONE_ALGOS:
                        r = C.run_standalone(forest, csv, "csv", algo,
                                             n_features=x.shape[1])
                        rows.append({**base, **r})
                    for plan in ("udf", "rel"):
                        r = C.run_netsdb(forest, store, ds, plan,
                                         ALGO, engine=engine)
                        rows.append({**base, **r})
                    # netsdb-opt: steady state = 2nd query on same model
                    C.run_netsdb(forest, store, ds, "rel+reuse", ALGO,
                                 engine=engine)
                    r = C.run_netsdb(forest, store, ds, "rel+reuse", ALGO,
                                     engine=engine)
                    rows.append({**base, **r})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    trees = C.FAST_TREE_GRID if args.fast else C.TREE_GRID
    C.print_rows(run(trees=trees, scale=args.scale))


if __name__ == "__main__":
    main()
