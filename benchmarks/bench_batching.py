"""Paper Sec. 7 'Batching'/'Vectorization': latency vs batch size (pages
per batch) and vs vectorization granularity (rows per page / per block).

Claims: latency improves with batch size until the working set exceeds
memory-level resources; the rows-per-block granularity (vectorizing the
UDF itself) matters more than the blocks-per-batch granularity."""

from __future__ import annotations

import argparse

from benchmarks import common as C
from repro.core.reuse import ModelReuseCache
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore

ALGO = "predicated"


def run(dataset="higgs", trees=500, scale=1.0,
        page_rows_grid=(128, 512, 2048, 8192),
        batch_pages_grid=(1, 4, 16, 64)):
    rows = []
    x, _ = C.bench_data(dataset, scale=scale)
    forest = C.get_forest(dataset, "xgboost", trees)
    # vectorization granularity: rows per page (block height)
    for pr in page_rows_grid:
        store = TensorBlockStore(default_page_rows=pr)
        store.put(dataset, x)
        engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
        r = C.run_netsdb(forest, store, dataset, "udf", ALGO,
                         engine=engine)
        rows.append(dict(dataset=dataset, model="xgboost", trees=trees,
                         platform=f"udf-pagerows-{pr}", **{
                             k: r[k] for k in ("load_s", "infer_s",
                                               "write_s", "total_s")}))
    # batching granularity: pages per batch at fixed page size
    store = TensorBlockStore(default_page_rows=512)
    store.put(dataset, x)
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache())
    for bp in batch_pages_grid:
        r = C.run_netsdb(forest, store, dataset, "udf", ALGO,
                         engine=engine, batch_pages=bp)
        rows.append(dict(dataset=dataset, model="xgboost", trees=trees,
                         platform=f"udf-batchpages-{bp}", **{
                             k: r[k] for k in ("load_s", "infer_s",
                                               "write_s", "total_s")}))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--trees", type=int, default=500)
    args = ap.parse_args()
    C.print_rows(run(trees=args.trees, scale=args.scale))


if __name__ == "__main__":
    main()
