"""Paper Tab. 7–9: WIDE and/or SPARSE datasets (Bosch NaN-dense-wide,
Epsilon array-typed-wide, Criteo LIBSVM-sparse).  Claims: the expensive
load/convert path (array-column parse, LIBSVM densify) makes in-database
inference win by the largest factors; sparse storage (criteo) shrinks the
transfer bottleneck and with it the in-DB advantage.

SPARSE section (``run_sparse`` / BENCH_sparse.json): the CSR data plane
vs the dense fallback, end to end — same model, same rows, one dataset
stored ``[N, F]`` dense and once as CSR pages.  The CSR run goes through
used-feature compaction + the feature-gather prepass (no ``[BT, I, F]``
one-hot at full F), and the record includes the external-load comparison
(LIBSVM -> densify -> transfer vs LIBSVM -> CSR pages -> transfer).  Each
run asserts the query really executed on the CSR plane
(``QueryResult.storage_format``) and that predictions match the dense
plane — the smoke job in CI runs this with ``--fast`` on synthetic
criteo (F=10k) so the sparse plane cannot silently regress to the dense
fallback.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from benchmarks import common as C
from repro.core.reuse import ModelReuseCache
from repro.db import loader as ld
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore

ALGO = "predicated"
SPARSE_ALGO = "hummingbird_pallas_fused"
FILE_KIND = {"bosch": "csv", "epsilon": "array", "criteo": "libsvm"}
BENCH_SPARSE_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_sparse.json")


def run(datasets=("bosch", "epsilon", "criteo"), trees=C.TREE_GRID,
        scale=1.0):
    rows = []
    for ds in datasets:
        x, y = C.bench_data(ds, scale=scale)
        kind = FILE_KIND[ds]
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, f"{ds}.dat")
            if kind == "csv":
                ld.write_csv(path, x)
            elif kind == "array":
                ld.write_array_rows(path, x)
            else:
                ld.write_libsvm(path, x, y)
            store = TensorBlockStore(default_page_rows=512)
            store.put(ds, x)
            engine = ForestQueryEngine(store,
                                       reuse_cache=ModelReuseCache())
            for T in trees:
                forest = C.get_forest(ds, "xgboost", T)
                base = dict(dataset=ds, model="xgboost", trees=T,
                            file_kind=kind)
                rows.append({**base,
                             **C.run_standalone(forest, path, kind, ALGO,
                                                n_features=x.shape[1])})
                for plan in ("udf", "rel"):
                    rows.append({**base,
                                 **C.run_netsdb(forest, store, ds, plan,
                                                ALGO, engine=engine)})
                C.run_netsdb(forest, store, ds, "rel+reuse", ALGO,
                             engine=engine)
                rows.append({**base,
                             **C.run_netsdb(forest, store, ds, "rel+reuse",
                                            ALGO, engine=engine)})
    return rows


def run_sparse(datasets=("bosch", "criteo"), trees=C.FAST_TREE_GRID,
               scale=1.0, algo=SPARSE_ALGO, page_rows=512):
    """CSR data plane vs dense fallback, end to end.

    Returns (rows, records).  Raises if the CSR run fell back to the
    dense plane or disagrees with it — this doubles as the CI smoke.
    """
    rows, records = [], []
    for ds in datasets:
        x, y = C.bench_data(ds, scale=scale)
        n, F = x.shape
        store = TensorBlockStore(default_page_rows=page_rows)
        store.put(ds, x)
        sp = store.put_sparse(ds + "@csr", x)
        density = sp.nnz / float(n * F)
        # external-load comparison on the same LIBSVM file: densify path
        # vs CSR-pages path (the transfer-shrink claim)
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, f"{ds}.svm")
            ld.write_libsvm(path, x, y)
            _, _, t_dense = ld.load_libsvm_external(path, F)
            _, _, t_csr = ld.load_libsvm_csr_external(path, F,
                                                      page_rows=page_rows)
        engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                                   plan_cache=ModelReuseCache())
        for T in trees:
            forest = C.get_forest(ds, "xgboost", T)
            base = dict(dataset=ds, model="xgboost", trees=T)
            res_d = engine.infer(ds, forest, algorithm=algo, plan="udf",
                                 write_as="preds_dense")
            res_s = engine.infer(ds + "@csr", forest, algorithm=algo,
                                 plan="udf", write_as="preds_csr")
            # regression guards: the sparse plane must actually execute,
            # and must agree with the dense plane
            if res_s.storage_format != "csr":
                raise RuntimeError(
                    f"{ds}: sparse query fell back to "
                    f"{res_s.storage_format!r} — CSR plane regressed")
            if not np.allclose(np.asarray(res_s.predictions),
                               np.asarray(res_d.predictions),
                               rtol=1e-5, atol=1e-6):
                raise RuntimeError(f"{ds}: CSR/dense prediction mismatch")
            for fmt, res in (("dense", res_d), ("csr", res_s)):
                rows.append({**base, "platform": f"netsdb-udf-{fmt}",
                             "load_s": 0.0,
                             "infer_s": round(res.infer_s, 4),
                             "write_s": round(res.write_s
                                              + res.aggregate_s, 4),
                             "total_s": round(res.total_s, 4),
                             "checksum": float(np.sum(np.asarray(
                                 res.predictions))),
                             "file_kind": fmt})
            records.append(dict(
                dataset=ds, trees=T, algorithm=algo, rows=n, features=F,
                density=round(density, 5),
                stored_dense_bytes=store.get(ds).nbytes,
                stored_csr_bytes=sp.nbytes,
                load_libsvm_densify_s=round(t_dense.total_s, 5),
                load_libsvm_csr_s=round(t_csr.total_s, 5),
                dense_total_s=round(res_d.total_s, 5),
                csr_total_s=round(res_s.total_s, 5),
                csr_vs_dense=round(res_d.total_s
                                   / max(res_s.total_s, 1e-9), 3),
                **C.env_info(engine.mesh)))
    return rows, records


def write_sparse_json(records, path=BENCH_SPARSE_JSON):
    payload = {"bench": "csr_vs_dense", "created_at": time.time(),
               "env": C.env_info(), "records": records}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--fast", action="store_true",
                    help="wide-sparse smoke: criteo-scale synthetic "
                         "(F=10k) through the CSR plane only, small grid")
    ap.add_argument("--sparse-out", default=BENCH_SPARSE_JSON)
    args = ap.parse_args()
    if args.fast:
        # the CI smoke: F=10k criteo synthetic end to end through the CSR
        # store + gather prepass; raises inside run_sparse on any dense
        # fallback or parity break
        rows, records = run_sparse(datasets=("criteo",), trees=(10, 50),
                                   scale=min(args.scale, 0.25))
        C.print_rows(rows, extra_cols=("file_kind",))
        path = write_sparse_json(records, args.sparse_out)
        print(f"# sparse trajectory -> {path}  (smoke OK: CSR plane "
              f"executed, parity held)")
        return
    trees = C.TREE_GRID
    rows = run(trees=trees, scale=args.scale)
    C.print_rows(rows, extra_cols=("file_kind",))
    srows, records = run_sparse(trees=C.FAST_TREE_GRID, scale=args.scale)
    C.print_rows(srows, header=False, extra_cols=("file_kind",))
    path = write_sparse_json(records, args.sparse_out)
    print(f"# sparse trajectory -> {path}")


if __name__ == "__main__":
    main()
