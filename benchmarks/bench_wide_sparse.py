"""Paper Tab. 7–9: WIDE and/or SPARSE datasets (Bosch NaN-dense-wide,
Epsilon array-typed-wide, Criteo LIBSVM-sparse).  Claims: the expensive
load/convert path (array-column parse, LIBSVM densify) makes in-database
inference win by the largest factors; sparse storage (criteo) shrinks the
transfer bottleneck and with it the in-DB advantage."""

from __future__ import annotations

import argparse
import os
import tempfile

from benchmarks import common as C
from repro.core.reuse import ModelReuseCache
from repro.db import loader as ld
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore

ALGO = "predicated"
FILE_KIND = {"bosch": "csv", "epsilon": "array", "criteo": "libsvm"}


def run(datasets=("bosch", "epsilon", "criteo"), trees=C.TREE_GRID,
        scale=1.0):
    rows = []
    for ds in datasets:
        x, y = C.bench_data(ds, scale=scale)
        kind = FILE_KIND[ds]
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, f"{ds}.dat")
            if kind == "csv":
                ld.write_csv(path, x)
            elif kind == "array":
                ld.write_array_rows(path, x)
            else:
                ld.write_libsvm(path, x, y)
            store = TensorBlockStore(default_page_rows=512)
            store.put(ds, x)
            engine = ForestQueryEngine(store,
                                       reuse_cache=ModelReuseCache())
            for T in trees:
                forest = C.get_forest(ds, "xgboost", T)
                base = dict(dataset=ds, model="xgboost", trees=T,
                            file_kind=kind)
                rows.append({**base,
                             **C.run_standalone(forest, path, kind, ALGO,
                                                n_features=x.shape[1])})
                for plan in ("udf", "rel"):
                    rows.append({**base,
                                 **C.run_netsdb(forest, store, ds, plan,
                                                ALGO, engine=engine)})
                C.run_netsdb(forest, store, ds, "rel+reuse", ALGO,
                             engine=engine)
                rows.append({**base,
                             **C.run_netsdb(forest, store, ds, "rel+reuse",
                                            ALGO, engine=engine)})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    trees = C.FAST_TREE_GRID if args.fast else C.TREE_GRID
    C.print_rows(run(trees=trees, scale=args.scale),
                 extra_cols=("file_kind",))


if __name__ == "__main__":
    main()
