"""Docs-consistency check: benchmark fields AND obs names must be documented.

Two contracts, one stdlib-only gate (CI runs it before any heavyweight
imports are warm):

  * ``docs/benchmarks.md`` is the contract for reading the benchmark
    trajectory files.  The check walks every ``BENCH_*.json`` at the
    repo root, collects EVERY dict key that occurs anywhere in the
    payload (top-level, ``env``, and per-record fields alike), and
    fails if any key is not mentioned — in backticks — in the doc.
    ``BENCH_obs.json`` is held against ``docs/observability.md``
    instead: the observability plane's fields belong with its span
    taxonomy, not in the generic benchmark contract.
  * ``docs/observability.md`` is the contract for the observability
    plane itself: every span / event / metric name the instrumentation
    can export (the catalog in ``src/repro/obs/names.py`` — imported
    here WITHOUT jax; ``repro.obs`` is stdlib-only by design) must
    appear, in backticks, in the doc.  Add an instrument without
    cataloging + documenting it and CI fails.

CI runs it right after the streaming smoke regenerates
``BENCH_stream.json``, so a new benchmark field cannot land without its
documentation:

    python benchmarks/check_docs.py
"""

from __future__ import annotations

import json
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC = ROOT / "docs" / "benchmarks.md"
OBS_DOC = ROOT / "docs" / "observability.md"

SERVE_DOC = ROOT / "docs" / "serving.md"
OPTIMIZER_DOC = ROOT / "docs" / "optimizer.md"
TRAIN_DOC = ROOT / "docs" / "training.md"

#: bench files whose field contract lives in a doc other than
#: docs/benchmarks.md
DOC_OVERRIDES = {"BENCH_obs.json": OBS_DOC,
                 "BENCH_serve.json": SERVE_DOC,
                 "BENCH_optimizer.json": OPTIMIZER_DOC,
                 "BENCH_train.json": TRAIN_DOC}

#: serving-plane names (obs catalog entries prefixed ``serve.``, plus
#: the row-level query span) must ALSO appear in docs/serving.md — the
#: plane's own contract, on top of the observability-catalog check
SERVE_NAME_PREFIXES = ("serve.", "query.infer_rows")

#: cost-based-optimizer names must ALSO appear in docs/optimizer.md
OPTIMIZER_NAME_PREFIXES = ("optimizer.",)

#: streamed-training names must ALSO appear in docs/training.md
TRAIN_NAME_PREFIXES = ("train.",)


def collect_keys(payload) -> set[str]:
    """Every dict key anywhere in the (nested) JSON payload."""
    keys: set[str] = set()

    def walk(obj):
        if isinstance(obj, dict):
            for k, v in obj.items():
                keys.add(k)
                walk(v)
        elif isinstance(obj, list):
            for v in obj:
                walk(v)

    walk(payload)
    return keys


def _backticked(doc: pathlib.Path) -> set[str]:
    return set(re.findall(r"`([A-Za-z0-9_.:]+)`", doc.read_text()))


def check_bench_files() -> bool:
    docs = {DOC, *DOC_OVERRIDES.values()}
    missing_docs = [d for d in docs if not d.exists()]
    if missing_docs:
        for d in missing_docs:
            print(f"FAIL: {d.relative_to(ROOT)} does not exist")
        return True
    documented = {d: _backticked(d) for d in docs}
    bench_files = sorted(ROOT.glob("BENCH_*.json"))
    if not bench_files:
        print("FAIL: no BENCH_*.json files found to check")
        return True
    failed = False
    for path in bench_files:
        doc = DOC_OVERRIDES.get(path.name, DOC)
        keys = collect_keys(json.loads(path.read_text()))
        missing = sorted(keys - documented[doc])
        if missing:
            failed = True
            print(f"FAIL {path.name}: keys missing from "
                  f"{doc.relative_to(ROOT)}: {', '.join(missing)}")
        else:
            print(f"OK   {path.name}: all {len(keys)} keys documented "
                  f"({doc.relative_to(ROOT)})")
    return failed


def check_obs_names() -> bool:
    """Every name in the obs catalog must appear in docs/observability.md.

    ``repro.obs.names`` is stdlib-only (the repo uses a namespace
    package under src/), so the import needs no jax — just the path.
    """
    if not OBS_DOC.exists():
        print(f"FAIL: {OBS_DOC.relative_to(ROOT)} does not exist")
        return True
    sys.path.insert(0, str(ROOT / "src"))
    try:
        from repro.obs import names as obs_names
    finally:
        sys.path.pop(0)
    documented = _backticked(OBS_DOC)
    failed = False
    for label, catalog in (("span", obs_names.SPAN_NAMES),
                           ("span-prefix", obs_names.SPAN_PREFIXES),
                           ("event", obs_names.EVENT_NAMES),
                           ("metric", obs_names.METRIC_NAMES)):
        missing = sorted(n for n in catalog
                         if n.rstrip(":") not in documented
                         and n not in documented)
        if missing:
            failed = True
            print(f"FAIL obs {label} names missing from "
                  f"{OBS_DOC.relative_to(ROOT)}: {', '.join(missing)}")
        else:
            print(f"OK   obs {label} names: all {len(catalog)} documented")
    return failed


def check_serve_names() -> bool:
    """Serving-plane span/event/metric names must also be documented in
    ``docs/serving.md`` — the serve plane's own contract doc (the
    observability catalog check above covers docs/observability.md)."""
    if not SERVE_DOC.exists():
        print(f"FAIL: {SERVE_DOC.relative_to(ROOT)} does not exist")
        return True
    sys.path.insert(0, str(ROOT / "src"))
    try:
        from repro.obs import names as obs_names
    finally:
        sys.path.pop(0)
    documented = _backticked(SERVE_DOC)
    serve_names = sorted(
        n for catalog in (obs_names.SPAN_NAMES, obs_names.EVENT_NAMES,
                          obs_names.METRIC_NAMES)
        for n in catalog if n.startswith(SERVE_NAME_PREFIXES))
    missing = sorted(n for n in serve_names if n not in documented)
    if missing:
        print(f"FAIL serve-plane names missing from "
              f"{SERVE_DOC.relative_to(ROOT)}: {', '.join(missing)}")
        return True
    print(f"OK   serve-plane names: all {len(serve_names)} documented "
          f"({SERVE_DOC.relative_to(ROOT)})")
    return False


def check_optimizer_names() -> bool:
    """Optimizer span/event/metric names must also be documented in
    ``docs/optimizer.md`` — the decision plane's own contract doc."""
    if not OPTIMIZER_DOC.exists():
        print(f"FAIL: {OPTIMIZER_DOC.relative_to(ROOT)} does not exist")
        return True
    sys.path.insert(0, str(ROOT / "src"))
    try:
        from repro.obs import names as obs_names
    finally:
        sys.path.pop(0)
    documented = _backticked(OPTIMIZER_DOC)
    opt_names = sorted(
        n for catalog in (obs_names.SPAN_NAMES, obs_names.EVENT_NAMES,
                          obs_names.METRIC_NAMES)
        for n in catalog if n.startswith(OPTIMIZER_NAME_PREFIXES))
    missing = sorted(n for n in opt_names if n not in documented)
    if missing:
        print(f"FAIL optimizer names missing from "
              f"{OPTIMIZER_DOC.relative_to(ROOT)}: {', '.join(missing)}")
        return True
    print(f"OK   optimizer names: all {len(opt_names)} documented "
          f"({OPTIMIZER_DOC.relative_to(ROOT)})")
    return False


def check_train_names() -> bool:
    """Streamed-training span/metric names must also be documented in
    ``docs/training.md`` — the training plane's own contract doc."""
    if not TRAIN_DOC.exists():
        print(f"FAIL: {TRAIN_DOC.relative_to(ROOT)} does not exist")
        return True
    sys.path.insert(0, str(ROOT / "src"))
    try:
        from repro.obs import names as obs_names
    finally:
        sys.path.pop(0)
    documented = _backticked(TRAIN_DOC)
    train_names = sorted(
        n for catalog in (obs_names.SPAN_NAMES, obs_names.EVENT_NAMES,
                          obs_names.METRIC_NAMES)
        for n in catalog if n.startswith(TRAIN_NAME_PREFIXES))
    missing = sorted(n for n in train_names if n not in documented)
    if missing:
        print(f"FAIL training names missing from "
              f"{TRAIN_DOC.relative_to(ROOT)}: {', '.join(missing)}")
        return True
    print(f"OK   training names: all {len(train_names)} documented "
          f"({TRAIN_DOC.relative_to(ROOT)})")
    return False


def main() -> int:
    failed = check_bench_files()
    failed = check_obs_names() or failed
    failed = check_serve_names() or failed
    failed = check_optimizer_names() or failed
    failed = check_train_names() or failed
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
