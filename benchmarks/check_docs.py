"""Docs-consistency check: every BENCH_*.json key must be documented.

``docs/benchmarks.md`` is the contract for reading the benchmark
trajectory files.  This check walks every ``BENCH_*.json`` at the repo
root, collects EVERY dict key that occurs anywhere in the payload
(top-level, ``env``, and per-record fields alike), and fails if any key
is not mentioned — in backticks — in ``docs/benchmarks.md``.  CI runs it
right after the streaming smoke regenerates ``BENCH_stream.json``, so a
new benchmark field cannot land without its documentation.

Stdlib only (CI runs it before any heavyweight imports are warm):

    python benchmarks/check_docs.py
"""

from __future__ import annotations

import json
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC = ROOT / "docs" / "benchmarks.md"


def collect_keys(payload) -> set[str]:
    """Every dict key anywhere in the (nested) JSON payload."""
    keys: set[str] = set()

    def walk(obj):
        if isinstance(obj, dict):
            for k, v in obj.items():
                keys.add(k)
                walk(v)
        elif isinstance(obj, list):
            for v in obj:
                walk(v)

    walk(payload)
    return keys


def main() -> int:
    if not DOC.exists():
        print(f"FAIL: {DOC.relative_to(ROOT)} does not exist")
        return 1
    documented = set(re.findall(r"`([A-Za-z0-9_.]+)`", DOC.read_text()))
    bench_files = sorted(ROOT.glob("BENCH_*.json"))
    if not bench_files:
        print("FAIL: no BENCH_*.json files found to check")
        return 1
    failed = False
    for path in bench_files:
        keys = collect_keys(json.loads(path.read_text()))
        missing = sorted(keys - documented)
        if missing:
            failed = True
            print(f"FAIL {path.name}: keys missing from "
                  f"docs/benchmarks.md: {', '.join(missing)}")
        else:
            print(f"OK   {path.name}: all {len(keys)} keys documented")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
